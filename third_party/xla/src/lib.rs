//! Compile-only stub of the `xla` crate (PJRT bindings).
//!
//! The real crate wraps libxla_extension's PJRT C API; it is not
//! available in offline build environments, so this stub mirrors the
//! exact API surface `overq::runtime::pjrt` uses and fails at runtime
//! with a clear error. Swap the `xla` path dependency in the workspace
//! `Cargo.toml` for the real crate (and build with `--features pjrt`)
//! to run the AOT HLO artifacts.

use std::fmt;
use std::path::Path;

/// Error for every stubbed runtime entry point.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what} unavailable (compile-only stub; link the real xla crate)"
    )))
}

/// Elements a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// A host-side tensor literal.
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("literal transfer")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("tuple unpack")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("array shape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("literal read")
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug, Default)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module.
#[derive(Clone, Debug, Default)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// An XLA computation ready to compile.
#[derive(Clone, Debug, Default)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
#[derive(Debug, Default)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

/// Compiled executable handle.
#[derive(Debug, Default)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `execute::<Literal>(&inputs)` → device buffers per output,
    /// per partition.
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<Literal>>> {
        unavailable("execution")
    }
}
