//! Minimal in-tree shim of the `anyhow` API.
//!
//! The real `anyhow` crate lives on crates.io; this workspace builds in
//! offline environments, so the subset the codebase actually uses is
//! reimplemented here behind the same names: [`Error`], [`Result`], the
//! [`Context`] extension trait and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics follow upstream where it matters:
//!
//! * `{}` displays the outermost message only; `{:#}` displays the whole
//!   cause chain joined by `": "` (the format the CLI prints).
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion does not conflict
//!   with the identity case.

use std::fmt;

/// Error type: an outermost message plus the chain of causes beneath it
/// (most recent context first, original error last).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The original (innermost) error message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result` — alias with the shim error as the default E.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring upstream.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("open config");
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), _> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "missing file");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("custom {}", 7);
        assert_eq!(format!("{e}"), "custom 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing file");
    }
}
