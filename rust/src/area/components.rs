//! Component-level area primitives (µm², 65 nm-class standard cells).
//!
//! Coefficients are calibrated so the baseline PE at (a=4, w=8) matches
//! the paper's Table 3 baseline column (multiply 128.74, add 135.13,
//! other 41.23) and the multiplier's bitwidth scaling matches the
//! paper's −7.17 % / −13.16 % "+1b/+2b" relative rows.

/// Array multiplier: per-bit partial-product cells + edge logic. The
/// constant folds the fixed 8-bit weight dimension (all experiments use
/// W8, like the paper's).
pub fn multiplier(act_bits: u32) -> f64 {
    // fit: area(4) = 128.74, area(4)/area(5) = 0.92834 (paper −7.17 %)
    const C0: f64 = 88.98;
    const C1: f64 = 9.94;
    C0 + C1 * act_bits as f64
}

/// [`multiplier`] generalized over the weight bitwidth. An array
/// multiplier has one partial-product row per weight bit, so area
/// scales linearly in `weight_bits`; the W8 point reproduces
/// [`multiplier`] exactly (the Table-3 calibration).
pub fn multiplier_w(act_bits: u32, weight_bits: u32) -> f64 {
    multiplier(act_bits) * weight_bits as f64 / 8.0
}

/// Ripple/compressor adder for the partial-sum chain: linear in psum
/// width. `psum_bits = act + weight + guard` (guard = log2 of max
/// accumulation depth, 8 here → 256-deep columns).
pub fn adder(psum_bits: u32) -> f64 {
    const PER_BIT: f64 = 6.7565; // 135.13 / 20 at (4 + 8 + 8) bits
    PER_BIT * psum_bits as f64
}

/// Pipeline/weight registers: per-bit flip-flop cost.
pub fn register(bits: u32) -> f64 {
    const PER_BIT: f64 = 2.30;
    PER_BIT * bits as f64
}

/// 2:1 mux, per bit. Calibrated against the paper's OverQ-RO
/// "other datapath" delta (80.07 − 41.23 µm²).
pub fn mux2(bits: u32) -> f64 {
    const PER_BIT: f64 = 0.9135;
    PER_BIT * bits as f64
}

/// Fixed-amount shifter (the OverQ left/right alignment): one mux level
/// for the first direction; the second direction shares the selects and
/// costs half a level (calibrated to the paper's Full − RO delta).
pub fn shifter(bits: u32, directions: u32) -> f64 {
    let levels = 1.0 + 0.5 * (directions.saturating_sub(1)) as f64;
    mux2(bits) * levels
}

/// Fixed control overhead per PE (clock gating, valid logic).
/// Calibrated so the baseline "other datapath" column matches 41.23 µm².
pub const CTRL: f64 = 9.03;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_calibration() {
        assert!((multiplier(4) - 128.74).abs() < 0.01);
        // paper: OverQ@4b is 7.17 % smaller than baseline@5b
        let rel = 1.0 - multiplier(4) / multiplier(5);
        assert!((rel - 0.0717).abs() < 0.002, "{rel}");
        let rel2 = 1.0 - multiplier(4) / multiplier(6);
        assert!((rel2 - 0.1316).abs() < 0.005, "{rel2}");
    }

    #[test]
    fn adder_calibration() {
        assert!((adder(20) - 135.13).abs() < 0.01);
    }

    #[test]
    fn monotone_in_bits() {
        assert!(multiplier(5) > multiplier(4));
        assert!(adder(21) > adder(20));
        assert!(register(9) > register(8));
    }
}
