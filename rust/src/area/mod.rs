//! Parametric ASIC area model (paper §5.3, Table 3).
//!
//! The paper synthesized a Verilog PE with Synopsys DC; without that
//! toolchain we use a calibrated gate-level model (DESIGN.md §2): each PE
//! component's area is a linear/bilinear function of bitwidths whose
//! coefficients are fit to the paper's baseline column, so the *relative*
//! overheads — the actual claim of Table 3 — are reproduced structurally:
//!
//! * multiplier: unchanged by OverQ (0 %);
//! * adder: +1 bit of partial-sum width (the shifted product's extra
//!   range bit) — small, bitwidth-amortized increase;
//! * "other datapath": state register, weight-copy mux, and the
//!   range/precision shifter — the dominant overhead, shrinking
//!   relatively as the baseline bitwidth grows (+1b/+2b rows).

pub mod components;
pub mod pe_area;

pub use pe_area::{pe_breakdown, pe_breakdown_w, PeAreas, PeVariant};
