//! PE-level area breakdown: baseline vs OverQ-RO vs OverQ-Full (Table 3).

use super::components as c;

/// PE flavours modelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeVariant {
    /// Plain weight-stationary MAC PE.
    Baseline,
    /// OverQ with range overwrite only (1 state bit, left shift).
    OverQRo,
    /// Full OverQ: range + precision overwrite (2 state bits, both
    /// shift directions).
    OverQFull,
}

/// Area breakdown in µm² (Table 3 columns).
#[derive(Clone, Copy, Debug)]
pub struct PeAreas {
    pub multiply: f64,
    pub add: f64,
    pub other: f64,
}

impl PeAreas {
    pub fn total(&self) -> f64 {
        self.multiply + self.add + self.other
    }
}

const WEIGHT_BITS: u32 = 8;
const GUARD_BITS: u32 = 8; // 256-deep accumulation columns

/// Compute the area breakdown for one PE variant at `act_bits`, with
/// the paper's fixed W8 weight datapath.
pub fn pe_breakdown(variant: PeVariant, act_bits: u32) -> PeAreas {
    pe_breakdown_w(variant, act_bits, WEIGHT_BITS)
}

/// [`pe_breakdown`] generalized over the weight bitwidth: the
/// multiplier's partial-product rows, the weight register/mux and the
/// partial-sum width all scale with `weight_bits`. `weight_bits = 8`
/// reproduces the Table-3 calibration exactly.
pub fn pe_breakdown_w(variant: PeVariant, act_bits: u32, weight_bits: u32) -> PeAreas {
    let psum = act_bits + weight_bits + GUARD_BITS;
    // baseline "other": activation pipe reg + weight reg + control
    let other_base =
        c::register(act_bits) + c::register(weight_bits) + c::CTRL + c::mux2(act_bits);
    match variant {
        PeVariant::Baseline => PeAreas {
            multiply: c::multiplier_w(act_bits, weight_bits),
            add: c::adder(psum),
            other: other_base,
        },
        PeVariant::OverQRo => PeAreas {
            multiply: c::multiplier_w(act_bits, weight_bits), // multiplier untouched
            add: c::adder(psum + 1), // +1 bit for the shifted range
            other: other_base
                + c::register(1)                         // state bit pipe
                + c::mux2(weight_bits)                   // weight-copy mux
                + c::shifter(act_bits + weight_bits, 1)  // left shift (MSB)
                + c::mux2(psum),                         // product-path select
        },
        PeVariant::OverQFull => PeAreas {
            multiply: c::multiplier_w(act_bits, weight_bits),
            add: c::adder(psum + 1),
            other: other_base
                + c::register(2)                         // 2-bit state pipe
                + c::mux2(weight_bits)
                + c::shifter(act_bits + weight_bits, 2)  // both directions
                + c::mux2(psum),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_paper_column() {
        let b = pe_breakdown(PeVariant::Baseline, 4);
        assert!((b.multiply - 128.74).abs() < 0.05, "{}", b.multiply);
        assert!((b.add - 135.13).abs() < 0.05, "{}", b.add);
        assert!((b.other - 41.23).abs() < 2.0, "{}", b.other);
    }

    #[test]
    fn overq_structure_matches_paper() {
        let base = pe_breakdown(PeVariant::Baseline, 4);
        let ro = pe_breakdown(PeVariant::OverQRo, 4);
        let full = pe_breakdown(PeVariant::OverQFull, 4);
        // multiplier untouched
        assert_eq!(ro.multiply, base.multiply);
        assert_eq!(full.multiply, base.multiply);
        // adder: small increase (~1 bit of 21)
        let add_oh = (ro.add - base.add) / base.add;
        assert!(add_oh > 0.0 && add_oh < 0.08, "{add_oh}");
        // other datapath: dominant overhead, full > ro
        assert!(ro.other > base.other * 1.5);
        assert!(full.other > ro.other);
        // total overhead in the paper's ballpark (≈15 % of PE)
        let tot_oh = (full.total() - base.total()) / base.total();
        assert!(tot_oh > 0.05 && tot_oh < 0.25, "{tot_oh}");
    }

    #[test]
    fn weight_bits_scale_the_pe() {
        // W8 is the calibration point: identical to the legacy model
        for v in [PeVariant::Baseline, PeVariant::OverQRo, PeVariant::OverQFull] {
            let a = pe_breakdown(v, 4);
            let b = pe_breakdown_w(v, 4, 8);
            assert_eq!(a.total(), b.total());
        }
        // narrower weights shrink every part of the PE, monotonically
        let w4 = pe_breakdown_w(PeVariant::OverQFull, 4, 4);
        let w6 = pe_breakdown_w(PeVariant::OverQFull, 4, 6);
        let w8 = pe_breakdown_w(PeVariant::OverQFull, 4, 8);
        assert!(w4.total() < w6.total() && w6.total() < w8.total());
        assert!(w4.multiply < w8.multiply && w4.add < w8.add && w4.other < w8.other);
        // the multiplier dominates the saving: one partial-product row
        // per weight bit → W4 multiplier is half the W8 one
        assert!((w4.multiply - w8.multiply / 2.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_shrinks_with_baseline_bits() {
        // the paper's "+1b/+2b" rows: OverQ@4b vs baseline@5b/6b
        let ovq4 = pe_breakdown(PeVariant::OverQFull, 4).total();
        let b4 = pe_breakdown(PeVariant::Baseline, 4).total();
        let b5 = pe_breakdown(PeVariant::Baseline, 5).total();
        let b6 = pe_breakdown(PeVariant::Baseline, 6).total();
        let oh0 = ovq4 / b4 - 1.0;
        let oh1 = ovq4 / b5 - 1.0;
        let oh2 = ovq4 / b6 - 1.0;
        assert!(oh1 < oh0 && oh2 < oh1, "{oh0} {oh1} {oh2}");
    }
}
