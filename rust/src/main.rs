//! `overq` CLI — experiment harnesses and the serving coordinator.
//!
//! Subcommands regenerate each paper artifact (see DESIGN.md §5) and run
//! the end-to-end serving path. All of them need `make artifacts` first
//! (except `table3`, which is pure modelling).

// the `cfg.field = ...` override pattern after `::default()` is the
// house style for harness configs; keep clippy (-D warnings in CI) quiet
#![allow(clippy::field_reassign_with_default)]

use anyhow::{Context, Result};

use overq::coordinator::batcher::BatchPolicy;
use overq::coordinator::{BanditConfig, Coordinator, RoutingPolicy, VariantSpec};
use overq::data::shapes;
use overq::harness::{calibrate, fig6a, fig6b, hwcmp, policy, table1, table2, table3};
use overq::models::zoo::LoadedModel;
use overq::models::{synth_model, Artifacts};
use overq::policy::{AutotuneConfig, DeploymentPlan};
use overq::util::cli::Args;

const USAGE: &str = "\
overq — OverQ paper reproduction CLI

USAGE: overq <command> [--options]

COMMANDS (paper artifacts):
  table1     cascading outlier coverage vs Eq.(1)      [--model resnet50m --std-t 3.0]
  table2     full accuracy grid (4 models x 4 methods) [--eval 512 --profile 256]
  table3     PE area breakdown                          [--bits 4]
  fig6a      accuracy vs clip threshold                 [--model resnet18m --eval 512]
  fig6b      quant error small/large breakdown          [--layer 4]
  hwcmp      systolic + OLAccel hardware comparison     [--rows 32 --cols 16]

COMMANDS (system):
  policy     two-stage mixed-precision autotuner: proxy-scored greedy
             search over (OverQ config × weight bits) per enc point
             under a PE-area budget, then optional measured-accuracy
             refinement on a held-out probe split (docs/autotuning.md);
             emits a deployment plan JSON
             [overq policy <model> --images 64 --std-t 4.0
              --bits 3,4,5,8 --cascades 1,2,3,4 --weight-bits 4,6,8
              --baseline-bits 4 --baseline-cascade 4
              --probe 128 --topk 4
              --budget <µm²> --name <plan> --out plans/<model>.plan.json]
             (models starting with \"synth\" need no artifacts;
              --probe 0 skips refinement and runs the proxy-only stage)
  serve      run the multi-model serving coordinator on synthetic traffic
             [--models m1,m2 | --model resnet18m] [--variant full_c4]
             [--plan plans/a.plan.json,plans/b.plan.json]
             [--split plan:a@0.9,plan:b@0.1] [--requests 64 --seed 4242]
             [--routing fixed|bandit --explore 0.05 --strategy thompson|ucb]
             [--watch-plans plans/ --watch-interval-ms 500]
             [--replicas 1] [--max-queue 4096] [--tenant-quota N]
             [--area-budget <µm²>]
             [--telemetry-addr 127.0.0.1:9185 --telemetry-linger-ms 0]
             [--tracing] [--trace-out trace.jsonl]
             each plan is registered on its model's shard; --split
             installs deterministic weighted A/B routing on the first
             model and reports per-variant p50/p95 (docs/serving.md);
             --routing bandit replaces the fixed weights with outcome-
             aware ones learned from live latency (control arm pinned at
             the exploration floor), and --watch-plans hot-reloads
             *.plan.json changes from disk (docs/operations.md);
             --telemetry-addr serves /metrics (Prometheus text),
             /snapshot.json and /trace over HTTP for the run (linger
             keeps it up after the traffic drains), and --tracing
             records queue/route/batch/execute/encode/decode spans
             (docs/observability.md); --replicas runs that many worker
             threads per model, --max-queue/--tenant-quota bound
             admission (overload sheds with typed errors), and
             --area-budget caps the summed PE area of all hosted
             models' plans (docs/serving.md "Fleet scaling")
  stats      one-screen serving + coverage summary from a live
             --telemetry-addr endpoint or a saved snapshot.json
             [overq stats <host:port | snapshot.json> [--drift]]
  trace      drain a live endpoint's span ring as JSONL on stdout
             [overq trace <host:port>]
  lint       static plan verifier: checks deployment plans against the
             OverQ invariants, the hardware area model, and (with
             --model) the model graph's enc points; also lints whole
             plan directories (duplicate aliases) and traffic splits
             (docs/static_analysis.md catalogs the OQ001.. codes)
             [overq lint <plan.json | plans-dir> [--model <name>]
              [--split <spec>] [--json] [--deny-warn]]
             [overq lint --codes]   lists every code
             [overq lint --explain <code>]   one code's catalog entry
             exit codes: 0 clean, 1 findings gate (Error-level, or any
             finding with --deny-warn), 2 usage/operational failure
  verify     static range & error certification: abstract interpretation
             over the model graph proves per-enc-point activation
             intervals and a worst-case Eq.(1) error bound from the
             weights alone (no profile data), then judges the plan's
             scales, cascades and drift baselines against the proof —
             the OQ020..OQ025 codes (docs/static_analysis.md)
             [overq verify <plan.json> --model <name>
              [--input-range lo:hi] [--error-budget <f>]
              [--json] [--deny-warn] [--explain <code>]]
             exit codes match lint: 0 clean, 1 findings gate, 2 usage/
             operational failure
  eval       native-engine accuracy for one config
             [--model resnet18m --bits 4 --cascade 4 --std-t 6 --mode full|ro|base]
  info       artifact manifest summary
  help       this text

Options: --csv <path> writes the table as CSV too.";

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table1" => {
            let arts = Artifacts::locate()?;
            let mut cfg = table1::Table1Config::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.std_t = args.get_f64("std-t", cfg.std_t);
            cfg.bits = args.get_usize("bits", cfg.bits as usize) as u32;
            emit(table1::run(&arts, &cfg)?, args)
        }
        "table2" => {
            let arts = Artifacts::locate()?;
            let mut cfg = table2::Table2Config::default();
            cfg.eval_images = args.get_usize("eval", cfg.eval_images);
            cfg.profile_images = args.get_usize("profile", cfg.profile_images);
            if let Some(m) = args.get("models") {
                cfg.models = m.split(',').map(|s| s.to_string()).collect();
            }
            emit(table2::run(&arts, &cfg)?, args)
        }
        "table3" => {
            let mut cfg = table3::Table3Config::default();
            cfg.act_bits = args.get_usize("bits", cfg.act_bits as usize) as u32;
            emit(table3::run(&cfg)?, args)
        }
        "fig6a" => {
            let arts = Artifacts::locate()?;
            let mut cfg = fig6a::Fig6aConfig::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.eval_images = args.get_usize("eval", cfg.eval_images);
            cfg.bits = args.get_usize("bits", cfg.bits as usize) as u32;
            emit(fig6a::run(&arts, &cfg)?, args)
        }
        "fig6b" => {
            let arts = Artifacts::locate()?;
            let mut cfg = fig6b::Fig6bConfig::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.layer = args.get_usize("layer", cfg.layer);
            emit(fig6b::run(&arts, &cfg)?, args)
        }
        "hwcmp" => {
            let arts = Artifacts::locate()?;
            let mut cfg = hwcmp::HwcmpConfig::default();
            cfg.rows = args.get_usize("rows", cfg.rows);
            cfg.cols = args.get_usize("cols", cfg.cols);
            cfg.layer = args.get_usize("layer", cfg.layer);
            emit(hwcmp::run(&arts, &cfg)?, args)
        }
        "lint" => lint_cmd(args),
        "verify" => verify_cmd(args),
        "policy" => policy_cmd(args),
        "serve" => serve(args),
        "stats" => stats_cmd(args),
        "trace" => trace_cmd(args),
        "eval" => eval_cmd(args),
        "info" => info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn emit(table: overq::util::bench::Table, args: &Args) -> Result<()> {
    table.print();
    if let Some(path) = args.get("csv") {
        table.write_csv(path)?;
        println!("(csv written to {path})");
    }
    Ok(())
}

fn info() -> Result<()> {
    let arts = Artifacts::locate()?;
    println!("artifacts at {}", arts.root.display());
    for name in arts.model_names() {
        let m = arts.load_model(&name)?;
        println!(
            "  {name:<12} fp32_acc {:.4}  enc_points {}",
            m.fp32_acc,
            m.enc_stats.len()
        );
    }
    for (model, variant, batch, path) in arts.hlo_entries() {
        println!(
            "  hlo {model}/{variant}/b{batch}  ({:.2} MB)",
            std::fs::metadata(&path).map(|m| m.len() as f64 / 1e6).unwrap_or(0.0)
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    use overq::overq::OverQConfig;
    use overq::quant::clip::ClipMethod;
    let arts = Artifacts::locate()?;
    let name = args.get_or("model", "resnet18m");
    let bits = args.get_usize("bits", 4) as u32;
    let cascade = args.get_usize("cascade", 4);
    let t = args.get_f64("std-t", 6.0);
    let n = args.get_usize("eval", 512);
    let mode = args.get_or("mode", "full");
    let ovq = match mode {
        "base" => OverQConfig::baseline(bits),
        "ro" => OverQConfig::ro(bits, cascade),
        _ => OverQConfig::full(bits, cascade),
    };
    let model = arts.load_model(name)?;
    let ev = arts.load_dataset("evalset")?;
    let pf = arts.load_dataset("profileset")?;
    let (pimg, _) = calibrate::subset(&pf, 256);
    let profile = calibrate::profile_acts(&model, &pimg, 4096)?;
    let (eimg, elab) = calibrate::subset(&ev, n);
    let qc = calibrate::quant_config(&profile, ClipMethod::StdMul(t), ovq);
    let accq = model.engine.accuracy_quant(&eimg, &elab, 64, &qc)?;
    let accf = model.engine.accuracy_f32(&eimg, &elab, 64)?;
    println!(
        "{name} A{bits} {mode} c={cascade} t={t}: quant {:.4}  fp32 {:.4}  (n={n})",
        accq, accf
    );
    Ok(())
}

/// Resolve a model: synthetic (artifact-free) when the name starts with
/// "synth", the AOT artifact zoo otherwise.
fn load_model_any(name: &str) -> Result<(LoadedModel, Option<Artifacts>)> {
    if name.starts_with("synth") {
        return Ok((synth_model(name, 42)?, None));
    }
    let arts = Artifacts::locate()?;
    let model = arts.load_model(name)?;
    Ok((model, Some(arts)))
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| t.trim().parse::<usize>().with_context(|| format!("bad list entry {t:?}")))
        .collect()
}

fn policy_cmd(args: &Args) -> Result<()> {
    use overq::overq::OverQConfig;
    use overq::policy::ProbeSplit;
    use overq::quant::clip::ClipMethod;

    let name = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("model"))
        .unwrap_or("synth-cnn")
        .to_string();
    let (model, arts) = load_model_any(&name)?;
    anyhow::ensure!(
        model.engine.graph.num_enc_points() > 0,
        "model {name:?} has no enc points (no quantized convs) — nothing to tune"
    );
    let n = args.get_usize("images", 64);
    let images = match &arts {
        Some(a) => calibrate::subset(&a.load_dataset("profileset")?, n).0,
        None => shapes::gen_batch(4242, 0, n).0,
    };

    let mut at = AutotuneConfig {
        clip: ClipMethod::StdMul(args.get_f64("std-t", 4.0)),
        baseline: OverQConfig::full(
            args.get_usize("baseline-bits", 4) as u32,
            args.get_usize("baseline-cascade", 4),
        ),
        plan_name: args.get("name").map(|s| s.to_string()),
        topk: args.get_usize("topk", 4),
        ..AutotuneConfig::default()
    };
    if let Some(b) = args.get("bits") {
        at.space.bits = parse_usize_list(b)?.into_iter().map(|b| b as u32).collect();
    }
    if let Some(c) = args.get("cascades") {
        at.space.cascades = parse_usize_list(c)?;
    }
    if let Some(w) = args.get("weight-bits") {
        // 0 = the default prepared (8-bit) weights; mixing it in keeps
        // the legacy datapath in the search space
        at.space.weight_bits = parse_usize_list(w)?.into_iter().map(|w| w as u32).collect();
    }
    if let Some(b) = args.get("budget") {
        at.budget_area = Some(b.parse::<f64>().context("--budget expects µm²")?);
    }

    // stage 2: measured-accuracy refinement on a held-out probe split
    let probe_n = args.get_usize("probe", 0);
    let result = if probe_n > 0 {
        let (pimg, plab) = match &arts {
            // the eval split is disjoint from the profiling split
            Some(a) => calibrate::subset(&a.load_dataset("evalset")?, probe_n),
            // synthetic: continue the stream past the profiling images
            None => shapes::gen_batch(4242, n as u64, probe_n),
        };
        let probe = ProbeSplit::new(pimg, plab)
            .context("building the probe split (is --probe larger than the eval set?)")?;
        let (layer_table, acc_table, measured) =
            policy::run_measured(&model, &images, &probe, &at)?;
        emit(layer_table, args)?;
        acc_table.print();
        // --csv captures the accuracy report too, next to the layer csv
        if let Some(path) = args.get("csv") {
            let acc_path = match path.rsplit_once('.') {
                Some((stem, ext)) => format!("{stem}.accuracy.{ext}"),
                None => format!("{path}.accuracy"),
            };
            acc_table.write_csv(&acc_path)?;
            println!("(accuracy csv written to {acc_path})");
        }
        println!(
            "probe accuracy: chosen {:.2}% | proxy-only {:.2}% | baseline {:.2}% \
             (n={}, proxy↔measured rank agreement {:.2})",
            measured.candidates[measured.chosen].measured_acc * 100.0,
            measured.proxy_acc * 100.0,
            measured.baseline_acc * 100.0,
            measured.probe_images,
            measured.rank_agreement,
        );
        measured.result
    } else {
        let (table, result) = policy::run(&model, &images, &at)?;
        emit(table, args)?;
        result
    };

    let default_out = format!("plans/{name}.plan.json");
    let out = args.get_or("out", &default_out);
    result.plan.save(std::path::Path::new(out))?;
    println!(
        "plan {:?} → {out}: coverage {:.1}% (baseline {:.1}%) at area {:.1} µm² (baseline {:.1}, budget {:.1})",
        result.plan.name,
        result.plan.mean_coverage * 100.0,
        result.plan.baseline_coverage * 100.0,
        result.total_area,
        result.baseline_area,
        at.budget_area.unwrap_or(result.baseline_area),
    );
    println!("serve it: overq serve --plan {out} --model {name}");
    Ok(())
}

/// `overq lint` — the CI-facing entry of the static analyzer. Never
/// returns: exits 0 (clean / warnings without --deny-warn), 1 (findings
/// gate) or 2 (usage or operational failure, e.g. the model won't load).
fn lint_cmd(args: &Args) -> Result<()> {
    use overq::analysis;

    if args.flag("codes") {
        for c in analysis::CODES {
            println!("{} [{}] {}: {}", c.code, c.severity, c.name, c.invariant);
        }
        std::process::exit(0);
    }

    if let Some(code) = args.get("explain") {
        explain_code(code);
    }

    let mut report = analysis::Report::default();
    let mut linted_anything = false;

    if let Some(spec) = args.get("split") {
        let text = if spec.starts_with("split:") {
            spec.to_string()
        } else {
            format!("split:{spec}")
        };
        report.merge(analysis::lint_split_text(&text));
        linted_anything = true;
    }

    if let Some(path) = args.positional.first() {
        let model = match args.get("model") {
            Some(name) => match load_model_any(name) {
                Ok((m, _)) => Some(m),
                Err(e) => {
                    eprintln!("error: load model {name:?}: {e:#}");
                    std::process::exit(2);
                }
            },
            None => None,
        };
        let p = std::path::Path::new(path);
        report.merge(if p.is_dir() {
            analysis::lint_dir(p, model.as_ref())
        } else {
            analysis::lint_file(p, model.as_ref())
        });
        linted_anything = true;
    }

    if !linted_anything {
        eprintln!("usage: overq lint <plan.json | plans-dir> [--model <name>] [--split <spec>] [--json] [--deny-warn]");
        std::process::exit(2);
    }

    if args.flag("json") {
        println!("{}", report.to_json().to_json());
    } else {
        print!("{}", report.render_human());
    }
    std::process::exit(report.exit_code(args.flag("deny-warn")));
}

/// `overq verify` — the static-certification entry (`analysis::absint`).
/// Shares lint's exit-code contract: 0 clean (or warnings without
/// `--deny-warn`), 1 findings gate, 2 usage or operational failure.
fn verify_cmd(args: &Args) -> Result<()> {
    use overq::analysis::absint;

    if let Some(code) = args.get("explain") {
        explain_code(code);
    }
    let usage = "usage: overq verify <plan.json> --model <name> \
                 [--input-range lo:hi] [--error-budget <f>] [--json] [--deny-warn]";
    let Some(path) = args.positional.first() else {
        eprintln!("{usage}");
        std::process::exit(2);
    };
    let Some(name) = args.get("model") else {
        eprintln!("{usage}");
        std::process::exit(2);
    };
    let model = match load_model_any(name) {
        Ok((m, _)) => m,
        Err(e) => {
            eprintln!("error: load model {name:?}: {e:#}");
            std::process::exit(2);
        }
    };
    let plan = match DeploymentPlan::load(std::path::Path::new(path)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: load plan {path:?}: {e:#}");
            std::process::exit(2);
        }
    };
    let input = match args.get("input-range") {
        Some(s) => match parse_input_range(s) {
            Ok(iv) => iv,
            Err(e) => {
                eprintln!("error: --input-range: {e:#}");
                std::process::exit(2);
            }
        },
        None => absint::DEFAULT_INPUT_RANGE,
    };
    let mut cfg = absint::AbsintConfig::default();
    if let Some(b) = args.get("error-budget") {
        match b.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => cfg.error_budget = Some(v),
            _ => {
                eprintln!("error: --error-budget expects a positive number, got {b:?}");
                std::process::exit(2);
            }
        }
    }
    let cert = match absint::verify_plan(&plan, &model, input, &cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if args.flag("json") {
        println!("{}", cert.to_json().to_json());
    } else {
        for c in &cert.encs {
            println!(
                "enc {}: fp32 range [{:.4}, {:.4}] | quant bound {:.4} vs capacity {:.4} | err <= {:.3e}",
                c.range.enc, c.range.lo, c.range.hi, c.quant_hi, c.capacity, c.err_bound
            );
        }
        print!("{}", cert.report.render_human());
    }
    std::process::exit(cert.report.exit_code(args.flag("deny-warn")));
}

/// Shared `--explain <code>` path of `lint` and `verify`: print one
/// code's catalog entry from the in-build registry (the single source
/// of truth the docs catalog mirrors) and exit.
fn explain_code(code: &str) -> ! {
    match overq::analysis::code_info(code) {
        Some(c) => {
            println!("{} [{}] {}", c.code, c.severity, c.name);
            println!("  invariant: {}", c.invariant);
            println!("  fix: {}", c.fix);
            std::process::exit(0);
        }
        None => {
            eprintln!("error: unknown diagnostic code {code:?} (see `overq lint --codes`)");
            std::process::exit(2);
        }
    }
}

/// Parse `--input-range lo:hi` into an interval.
fn parse_input_range(s: &str) -> Result<overq::analysis::Interval> {
    let (lo, hi) = s.split_once(':').context("expected lo:hi, e.g. -4.0:4.0")?;
    let lo: f64 = lo.trim().parse().context("bad lower bound")?;
    let hi: f64 = hi.trim().parse().context("bad upper bound")?;
    anyhow::ensure!(
        lo <= hi && lo.is_finite() && hi.is_finite(),
        "need finite lo <= hi, got {lo}:{hi}"
    );
    Ok(overq::analysis::Interval::new(lo, hi))
}

/// `overq stats` — one-screen serving + coverage summary from a live
/// `--telemetry-addr` endpoint or a saved `/snapshot.json` document.
fn stats_cmd(args: &Args) -> Result<()> {
    use overq::util::json::{parse, Value};

    let src = args
        .positional
        .first()
        .map(String::as_str)
        .context("usage: overq stats <host:port | snapshot.json> [--drift]")?;
    let text = if std::path::Path::new(src).is_file() {
        std::fs::read_to_string(src).with_context(|| format!("reading {src}"))?
    } else {
        overq::coordinator::telemetry::http_get(src, "/snapshot.json")?
    };
    let v = parse(&text).map_err(|e| anyhow::anyhow!("parsing snapshot: {e}"))?;

    let num = |p: &[&str]| v.at(p).as_f64().unwrap_or(0.0);
    println!(
        "requests {} | batches {} (mean {:.2}) | e2e p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms",
        num(&["requests"]),
        num(&["batches"]),
        num(&["mean_batch"]),
        num(&["p50_e2e_us"]) / 1e3,
        num(&["p95_e2e_us"]) / 1e3,
        num(&["p99_e2e_us"]) / 1e3,
    );
    println!(
        "{:<28} {:>8} {:>9} {:>9} {:>9} {:>9} {:>7} {:>7}",
        "variant", "requests", "p50 ms", "p95 ms", "p99 ms", "coverage", "pulls", "reward"
    );
    if let Value::Obj(pv) = v.at(&["per_variant"]) {
        for (key, vv) in pv {
            let f = |k: &str| vv.at(&[k]).as_f64().unwrap_or(0.0);
            let cv = v.at(&["coverage", key.as_str()]);
            let cov = if cv.at(&["outliers"]).as_f64().unwrap_or(0.0) > 0.0 {
                let c = cv.at(&["coverage"]).as_f64().unwrap_or(1.0);
                format!("{:.1}%", c * 100.0)
            } else {
                "-".to_string()
            };
            println!(
                "{key:<28} {:>8} {:>9.2} {:>9.2} {:>9.2} {cov:>9} {:>7} {:>7.3}",
                f("requests"),
                f("p50_e2e_us") / 1e3,
                f("p95_e2e_us") / 1e3,
                f("p99_e2e_us") / 1e3,
                f("pulls"),
                f("mean_reward"),
            );
        }
    }
    if args.flag("drift") {
        if let Value::Obj(cov) = v.at(&["coverage"]) {
            for (key, cv) in cov {
                let Value::Arr(enc) = cv.at(&["enc"]) else {
                    continue;
                };
                for e in enc {
                    let g = |k: &str| e.at(&[k]).as_f64().unwrap_or(0.0);
                    let base = e.at(&["baseline"]);
                    let b = |k: &str| base.at(&[k]).as_f64();
                    println!(
                        "  {key} enc {}: mean {:.4}{} var {:.4}{} clip {:.4}{}",
                        g("enc"),
                        g("act_mean"),
                        drift_baseline(b("mean")),
                        g("act_var"),
                        drift_baseline(b("var")),
                        g("clip_rate"),
                        drift_baseline(b("clip_rate")),
                    );
                }
            }
        }
    }
    if let Some(arm) = v.at(&["control_arm"]).as_str() {
        println!("control arm: {arm}");
    }
    println!(
        "plan swaps {} | watch errors {}{} | trace dropped {}",
        num(&["plan_swaps"]),
        num(&["watch_errors"]),
        v.at(&["last_watch_error"])
            .as_str()
            .map(|e| format!(" (last: {e})"))
            .unwrap_or_default(),
        num(&["trace_dropped"]),
    );
    Ok(())
}

/// Render a profile-time baseline next to its live drift value.
fn drift_baseline(b: Option<f64>) -> String {
    b.map(|x| format!(" (profile {x:.4})")).unwrap_or_default()
}

/// `overq trace` — drain a live endpoint's span ring to stdout (JSONL).
fn trace_cmd(args: &Args) -> Result<()> {
    let addr = args
        .positional
        .first()
        .map(String::as_str)
        .context("usage: overq trace <host:port>")?;
    print!("{}", overq::coordinator::telemetry::http_get(addr, "/trace")?);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let requests = args.get_usize("requests", 64);
    let seed = args.get_usize("seed", 4242) as u64;
    let std_t = args.get_f64("std-t", 6.0);

    // deployment plans to register (comma-separated files)
    let mut plans: Vec<DeploymentPlan> = Vec::new();
    if let Some(paths) = args.get("plan") {
        for p in paths.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            plans.push(DeploymentPlan::load(std::path::Path::new(p))?);
        }
    }

    // hosted models: --models a,b | --model | the plans' models | default
    let mut names: Vec<String> = match (args.get("models"), args.get("model")) {
        (Some(list), _) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        (None, Some(m)) => vec![m.to_string()],
        (None, None) => match plans.first() {
            Some(p) => vec![p.model.clone()],
            None => vec!["resnet18m".to_string()],
        },
    };
    for p in &plans {
        if !names.iter().any(|n| n == &p.model) {
            names.push(p.model.clone());
        }
    }
    anyhow::ensure!(!names.is_empty(), "--models gave no model names");

    // fleet knobs: defaults never shed the synthetic CI traffic
    let replicas = args.get_usize("replicas", 1).max(1);
    let mut builder = Coordinator::builder()
        .policy(BatchPolicy::default())
        .seed(seed)
        .max_queue(args.get_usize("max-queue", 4096));
    if let Some(q) = args.get("tenant-quota") {
        builder = builder.tenant_quota(q.parse().context("--tenant-quota expects a count")?);
    }
    if let Some(b) = args.get("area-budget") {
        builder = builder.area_budget(b.parse().context("--area-budget expects µm²")?);
    }
    for name in &names {
        if name.starts_with("synth") {
            builder = builder.model_local(synth_model(name, 42)?);
        } else {
            builder = builder.model(name);
            if let Ok(arts) = Artifacts::locate() {
                if let Ok(m) = arts.load_model(name) {
                    builder =
                        builder.act_scales(calibrate::scales_from_stats(&m.enc_stats, std_t, 4));
                }
            }
        }
        builder = builder.replicas(replicas);
    }
    let coord = builder.build()?;
    for plan in &plans {
        coord.model(&plan.model)?.register_plan(plan.clone())?;
    }

    // plan hot-reload: one watcher per hosted model on the same
    // directory; each shard applies only its own model's plan files.
    // Kept alive until the end of the run (dropping a watcher stops it).
    let mut watchers = Vec::new();
    if let Some(dir) = args.get("watch-plans") {
        let interval =
            std::time::Duration::from_millis(args.get_usize("watch-interval-ms", 500) as u64);
        for name in &names {
            watchers.push(coord.model(name)?.watch_plans(dir, interval)?);
        }
        println!(
            "watching {dir} for *.plan.json changes ({} model(s), every {} ms)",
            names.len(),
            interval.as_millis()
        );
    }

    // traffic goes to the first model: --routing bandit > --split >
    // --plan > --variant
    let target = names[0].clone();
    let handle = coord.model(&target)?;
    let routing = args.get_or("routing", "fixed");
    anyhow::ensure!(
        matches!(routing, "fixed" | "bandit"),
        "--routing expects fixed|bandit, got {routing:?}"
    );
    let spec: Option<VariantSpec> = if routing == "bandit" {
        anyhow::ensure!(
            args.get("split").is_none(),
            "--routing bandit and --split are mutually exclusive (the bandit \
             learns its own weights)"
        );
        // arms = every --plan tuned for the target model, quality prior =
        // probe accuracy when the refinement stage ran, mean coverage
        // otherwise; --watch-plans keeps swapping content behind these
        // aliases while the bandit routes across them
        let mut arms: Vec<(VariantSpec, f64)> = Vec::new();
        for p in plans.iter().filter(|p| p.model == target) {
            let quality = p
                .probe
                .map(|pr| pr.accuracy)
                .unwrap_or(p.mean_coverage)
                .clamp(0.0, 1.0);
            arms.push((VariantSpec::parse(&format!("plan:{}", p.name))?, quality));
        }
        anyhow::ensure!(
            !arms.is_empty(),
            "--routing bandit needs at least one --plan for model {target:?}"
        );
        // pinned control arm: the global-baseline plan for synthetic
        // models (harness::policy::baseline_plan), native fp32 otherwise
        let control = if target.starts_with("synth") {
            let model = synth_model(&target, 42)?;
            let (images, _) = shapes::gen_batch(4242, 0, 32);
            let base = policy::baseline_plan(
                &model,
                &images,
                &AutotuneConfig::default(),
                "baseline-control",
            )?;
            let quality = base.mean_coverage.clamp(0.0, 1.0);
            handle.register_plan(base)?;
            (VariantSpec::parse("plan:baseline-control")?, quality)
        } else {
            (VariantSpec::parse("native_fp32")?, 1.0)
        };
        let control_idx = arms.len();
        arms.push(control);
        let mut cfg = BanditConfig::new(arms, control_idx);
        cfg.explore_floor = args.get_f64("explore", cfg.explore_floor);
        cfg.strategy = args.get_or("strategy", "thompson").parse()?;
        cfg.seed = seed;
        println!(
            "bandit routing on {target}: {} arms, control pinned at floor {}",
            cfg.arms.len(),
            cfg.explore_floor
        );
        handle.set_routing_policy(RoutingPolicy::Bandit(cfg))?;
        None // routed through the bandit
    } else if let Some(split) = args.get("split") {
        // `--split plan:a@0.9,plan:b@0.1` — the `split:` prefix of the
        // VariantSpec grammar is implied (but also accepted)
        let text = if split.starts_with("split:") {
            split.to_string()
        } else {
            format!("split:{split}")
        };
        handle.set_traffic_split_spec(&VariantSpec::parse(&text)?)?;
        println!("traffic split on {target}: {split}");
        None // routed through the installed split
    } else if let Some(p) = plans.iter().find(|p| p.model == target) {
        Some(VariantSpec::parse(&format!("plan:{}", p.name))?)
    } else {
        let v = args.get_or("variant", "full_c4");
        let spec = VariantSpec::parse(v)?;
        let compile = handle.warmup(&spec, 8)?;
        println!("warmup/compile: {:.1} ms", compile.as_secs_f64() * 1e3);
        // keep warmup traffic out of the reported counts/latencies
        handle.reset_metrics();
        Some(spec)
    };
    let route = spec
        .as_ref()
        .map(|s| s.to_string())
        .unwrap_or_else(|| if routing == "bandit" { "bandit" } else { "split" }.to_string());

    // telemetry plane: spans on request, HTTP exporter on request
    if args.flag("tracing") {
        handle.set_tracing(true);
    }
    let telemetry = match args.get("telemetry-addr") {
        Some(addr) => {
            let t = overq::coordinator::telemetry::spawn(handle.clone(), addr)?;
            let at = t.addr();
            println!("telemetry on http://{at} — /metrics /snapshot.json /trace");
            Some(t)
        }
        None => None,
    };

    // the bandit learns from completed requests, so drive it in small
    // closed-loop windows; fixed routing keeps the open-loop firehose
    let window = if routing == "bandit" { 8 } else { requests };
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < requests {
        let take = window.min(requests - done);
        let mut pending = Vec::with_capacity(take);
        let mut labels = Vec::with_capacity(take);
        for i in done..done + take {
            let (img, label) = shapes::gen_image(seed, i as u64);
            labels.push(label);
            pending.push(match &spec {
                Some(s) => handle.submit(img, s)?,
                None => handle.submit_routed(img)?,
            });
        }
        for (k, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv()?.map_err(|e| anyhow::anyhow!("{e}"))?;
            let pred = resp
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            if pred == labels[k] {
                correct += 1;
            }
        }
        done += take;
    }
    let wall = t0.elapsed();
    let ms = handle.metrics();
    println!(
        "served {requests} requests ({target}/{route}) in {:.1} ms — {:.1} req/s",
        wall.as_secs_f64() * 1e3,
        requests as f64 / wall.as_secs_f64()
    );
    println!(
        "  accuracy (native load-gen) {:.3} | batches {} mean_batch {:.2} padded {} | exec {:.2} ms mean | e2e {:.2} ms mean, {:.2} ms p50, {:.2} ms p95",
        correct as f64 / requests as f64,
        ms.batches,
        ms.mean_batch,
        ms.padded_slots,
        ms.mean_exec_us / 1e3,
        ms.mean_e2e_us / 1e3,
        ms.p50_e2e_us / 1e3,
        ms.p95_e2e_us / 1e3,
    );
    let shed = ms.shed_queue_full + ms.shed_tenant_quota;
    if replicas > 1 || shed > 0 || ms.deadline_exceeded > 0 || ms.replica_failures > 0 {
        println!(
            "  fleet: {}/{} replicas alive | queue peak {} | admitted {} shed {} ({:.2}% rate) | deadline-exceeded {} | replica failures {}",
            ms.replicas_alive,
            ms.replicas_target,
            ms.queue_peak_depth,
            ms.admitted,
            shed,
            ms.shed_rate * 100.0,
            ms.deadline_exceeded,
            ms.replica_failures,
        );
    }
    for (variant, vs) in &ms.per_variant {
        println!(
            "  {variant:<28} {:>6} reqs | e2e {:.2} ms p50, {:.2} ms p95",
            vs.requests,
            vs.p50_e2e_us / 1e3,
            vs.p95_e2e_us / 1e3,
        );
    }
    for v in handle.obs_snapshot() {
        if v.outliers == 0 {
            continue;
        }
        println!(
            "  {:<28} coverage {:.1}% ({} outliers, {} dropped) | zero avail {:.1}%",
            v.variant,
            v.coverage * 100.0,
            v.outliers,
            v.dropped,
            v.zero_availability * 100.0,
        );
    }
    if let Some(arms) = handle.bandit_arms() {
        println!("  bandit arms (* = pinned control):");
        for a in &arms {
            println!(
                "  {}{:<27} {:>6} pulls | mean reward {:.3}",
                if a.is_control { "*" } else { " " },
                a.key,
                a.pulls,
                a.mean_reward,
            );
        }
        println!(
            "  regret vs control {:.3} (negative = the bandit beat the control arm)",
            ms.regret_vs_control
        );
    }
    if ms.plan_swaps > 0 || ms.watch_errors > 0 {
        println!(
            "  plan watch: {} swap(s), {} rejected file(s){}",
            ms.plan_swaps,
            ms.watch_errors,
            ms.last_watch_error
                .as_ref()
                .map(|e| format!(" — last: {e}"))
                .unwrap_or_default(),
        );
    }
    if let Some(t) = &telemetry {
        let linger = args.get_usize("telemetry-linger-ms", 0);
        if linger > 0 {
            println!("  telemetry lingering {linger} ms on http://{}", t.addr());
            std::thread::sleep(std::time::Duration::from_millis(linger as u64));
        }
    }
    if let Some(path) = args.get("trace-out") {
        let events = handle.drain_events();
        std::fs::write(path, overq::obs::span::events_jsonl(&events))?;
        println!("  trace: {} event(s) → {path}", events.len());
    }
    drop(telemetry); // stop the exporter before the shards go away
    drop(watchers); // stop the pollers before joining the workers
    coord.shutdown();
    Ok(())
}
