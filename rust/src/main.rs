//! `overq` CLI — experiment harnesses and the serving coordinator.
//!
//! Subcommands regenerate each paper artifact (see DESIGN.md §5) and run
//! the end-to-end serving path. All of them need `make artifacts` first
//! (except `table3`, which is pure modelling).

use anyhow::Result;

use overq::coordinator::batcher::BatchPolicy;
use overq::coordinator::{Server, ServerConfig};
use overq::data::shapes;
use overq::harness::{calibrate, fig6a, fig6b, hwcmp, table1, table2, table3};
use overq::models::Artifacts;
use overq::util::cli::Args;

const USAGE: &str = "\
overq — OverQ paper reproduction CLI

USAGE: overq <command> [--options]

COMMANDS (paper artifacts):
  table1     cascading outlier coverage vs Eq.(1)      [--model resnet50m --std-t 3.0]
  table2     full accuracy grid (4 models x 4 methods) [--eval 512 --profile 256]
  table3     PE area breakdown                          [--bits 4]
  fig6a      accuracy vs clip threshold                 [--model resnet18m --eval 512]
  fig6b      quant error small/large breakdown          [--layer 4]
  hwcmp      systolic + OLAccel hardware comparison     [--rows 32 --cols 16]

COMMANDS (system):
  serve      run the serving coordinator on synthetic traffic
             [--variant full_c4 --requests 64 --model resnet18m]
  eval       native-engine accuracy for one config
             [--model resnet18m --bits 4 --cascade 4 --std-t 6 --mode full|ro|base]
  info       artifact manifest summary
  help       this text

Options: --csv <path> writes the table as CSV too.";

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table1" => {
            let arts = Artifacts::locate()?;
            let mut cfg = table1::Table1Config::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.std_t = args.get_f64("std-t", cfg.std_t);
            cfg.bits = args.get_usize("bits", cfg.bits as usize) as u32;
            emit(table1::run(&arts, &cfg)?, args)
        }
        "table2" => {
            let arts = Artifacts::locate()?;
            let mut cfg = table2::Table2Config::default();
            cfg.eval_images = args.get_usize("eval", cfg.eval_images);
            cfg.profile_images = args.get_usize("profile", cfg.profile_images);
            if let Some(m) = args.get("models") {
                cfg.models = m.split(',').map(|s| s.to_string()).collect();
            }
            emit(table2::run(&arts, &cfg)?, args)
        }
        "table3" => {
            let mut cfg = table3::Table3Config::default();
            cfg.act_bits = args.get_usize("bits", cfg.act_bits as usize) as u32;
            emit(table3::run(&cfg)?, args)
        }
        "fig6a" => {
            let arts = Artifacts::locate()?;
            let mut cfg = fig6a::Fig6aConfig::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.eval_images = args.get_usize("eval", cfg.eval_images);
            cfg.bits = args.get_usize("bits", cfg.bits as usize) as u32;
            emit(fig6a::run(&arts, &cfg)?, args)
        }
        "fig6b" => {
            let arts = Artifacts::locate()?;
            let mut cfg = fig6b::Fig6bConfig::default();
            cfg.model = args.get_or("model", &cfg.model).to_string();
            cfg.layer = args.get_usize("layer", cfg.layer);
            emit(fig6b::run(&arts, &cfg)?, args)
        }
        "hwcmp" => {
            let arts = Artifacts::locate()?;
            let mut cfg = hwcmp::HwcmpConfig::default();
            cfg.rows = args.get_usize("rows", cfg.rows);
            cfg.cols = args.get_usize("cols", cfg.cols);
            cfg.layer = args.get_usize("layer", cfg.layer);
            emit(hwcmp::run(&arts, &cfg)?, args)
        }
        "serve" => serve(args),
        "eval" => eval_cmd(args),
        "info" => info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn emit(table: overq::util::bench::Table, args: &Args) -> Result<()> {
    table.print();
    if let Some(path) = args.get("csv") {
        table.write_csv(path)?;
        println!("(csv written to {path})");
    }
    Ok(())
}

fn info() -> Result<()> {
    let arts = Artifacts::locate()?;
    println!("artifacts at {}", arts.root.display());
    for name in arts.model_names() {
        let m = arts.load_model(&name)?;
        println!(
            "  {name:<12} fp32_acc {:.4}  enc_points {}",
            m.fp32_acc,
            m.enc_stats.len()
        );
    }
    for (model, variant, batch, path) in arts.hlo_entries() {
        println!(
            "  hlo {model}/{variant}/b{batch}  ({:.2} MB)",
            std::fs::metadata(&path).map(|m| m.len() as f64 / 1e6).unwrap_or(0.0)
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    use overq::overq::OverQConfig;
    use overq::quant::clip::ClipMethod;
    let arts = Artifacts::locate()?;
    let name = args.get_or("model", "resnet18m");
    let bits = args.get_usize("bits", 4) as u32;
    let cascade = args.get_usize("cascade", 4);
    let t = args.get_f64("std-t", 6.0);
    let n = args.get_usize("eval", 512);
    let mode = args.get_or("mode", "full");
    let ovq = match mode {
        "base" => OverQConfig::baseline(bits),
        "ro" => OverQConfig::ro(bits, cascade),
        _ => OverQConfig::full(bits, cascade),
    };
    let model = arts.load_model(name)?;
    let ev = arts.load_dataset("evalset")?;
    let pf = arts.load_dataset("profileset")?;
    let (pimg, _) = calibrate::subset(&pf, 256);
    let profile = calibrate::profile_acts(&model, &pimg, 4096)?;
    let (eimg, elab) = calibrate::subset(&ev, n);
    let qc = calibrate::quant_config(&profile, ClipMethod::StdMul(t), ovq);
    let accq = model.engine.accuracy_quant(&eimg, &elab, 64, &qc)?;
    let accf = model.engine.accuracy_f32(&eimg, &elab, 64)?;
    println!(
        "{name} A{bits} {mode} c={cascade} t={t}: quant {:.4}  fp32 {:.4}  (n={n})",
        accq, accf
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let arts = Artifacts::locate()?;
    let model = args.get_or("model", "resnet18m").to_string();
    let variant = args.get_or("variant", "full_c4").to_string();
    let requests = args.get_usize("requests", 64);
    let m = arts.load_model(&model)?;
    let scales = calibrate::scales_from_stats(&m.enc_stats, args.get_f64("std-t", 6.0), 4);
    let server = Server::start(ServerConfig {
        model: model.clone(),
        policy: BatchPolicy::default(),
        act_scales: scales,
    })?;
    let compile = server.warmup(&variant, &[16, 16, 3], 8)?;
    println!("warmup/compile: {:.1} ms", compile.as_secs_f64() * 1e3);
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut labels = Vec::new();
    for i in 0..requests {
        let (img, label) = shapes::gen_image(4242, i as u64);
        labels.push(label);
        pending.push(server.submit(img, &variant)?);
    }
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv()?;
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        if pred == labels[i] {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let ms = server.metrics();
    println!(
        "served {requests} requests ({model}/{variant}) in {:.1} ms — {:.1} req/s",
        wall.as_secs_f64() * 1e3,
        requests as f64 / wall.as_secs_f64()
    );
    println!(
        "  accuracy (native load-gen) {:.3} | batches {} mean_batch {:.2} padded {} | exec {:.2} ms mean | e2e {:.2} ms mean",
        correct as f64 / requests as f64,
        ms.batches,
        ms.mean_batch,
        ms.padded_slots,
        ms.mean_exec_us / 1e3,
        ms.mean_e2e_us / 1e3,
    );
    server.shutdown();
    Ok(())
}
