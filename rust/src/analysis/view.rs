//! Lenient plan reader for the lint rules.
//!
//! `policy::DeploymentPlan::from_json` is strict by design — it refuses
//! non-dense enc indices, unsupported wbits and unknown versions at load
//! time. A linter has the opposite requirement: it must *read past* such
//! violations so it can report each one under its stable code instead of
//! dying on the first parse error. [`PlanView`] reads every field as an
//! `Option` with no validation; the rules decide what each absence or
//! out-of-range value means.

use crate::policy::{DeploymentPlan, PLAN_VERSION};
use crate::util::json::Value;

/// One layer, as found (fields missing from the JSON are `None`).
#[derive(Clone, Debug, Default)]
pub struct LayerView {
    pub enc: Option<f64>,
    pub bits: Option<f64>,
    pub cascade: Option<f64>,
    pub ro: Option<bool>,
    pub pr: Option<bool>,
    pub scale: Option<f64>,
    pub wbits: Option<f64>,
    pub p0: Option<f64>,
    pub outlier_rate: Option<f64>,
    pub theory_coverage: Option<f64>,
    pub measured_coverage: Option<f64>,
    pub area: Option<f64>,
    pub macs: Option<f64>,
    /// Whether the layer carries a `drift` baseline block (OQ019 checks
    /// presence; the strict loader validates its contents).
    pub has_drift: bool,
}

/// Probe evidence, as found.
#[derive(Clone, Debug, Default)]
pub struct ProbeView {
    pub images: Option<f64>,
    pub accuracy: Option<f64>,
    pub baseline_accuracy: Option<f64>,
}

/// A deployment plan read without validation, for the rule engine.
#[derive(Clone, Debug, Default)]
pub struct PlanView {
    pub version: Option<f64>,
    pub name: Option<String>,
    pub model: Option<String>,
    pub layers: Vec<LayerView>,
    pub total_area: Option<f64>,
    pub probe: Option<ProbeView>,
}

impl PlanView {
    /// Read a parsed JSON document leniently. Fails only on shape
    /// violations no rule can see past: the document is not an object,
    /// or `layers` is present but not an array (both map to OQ018 at
    /// the caller).
    pub fn from_value(v: &Value) -> Result<PlanView, String> {
        let obj = v.as_obj().ok_or("plan document is not a JSON object")?;
        let layers_v = obj.get("layers");
        let layers = match layers_v {
            None => Vec::new(),
            Some(lv) => lv
                .as_arr()
                .ok_or("plan `layers` is not an array")?
                .iter()
                .map(|l| LayerView {
                    enc: l.at(&["enc"]).as_f64(),
                    bits: l.at(&["bits"]).as_f64(),
                    cascade: l.at(&["cascade"]).as_f64(),
                    ro: l.at(&["ro"]).as_bool(),
                    pr: l.at(&["pr"]).as_bool(),
                    scale: l.at(&["scale"]).as_f64(),
                    wbits: l.at(&["wbits"]).as_f64(),
                    p0: l.at(&["p0"]).as_f64(),
                    outlier_rate: l.at(&["outlier_rate"]).as_f64(),
                    theory_coverage: l.at(&["theory_coverage"]).as_f64(),
                    measured_coverage: l.at(&["measured_coverage"]).as_f64(),
                    area: l.at(&["area"]).as_f64(),
                    macs: l.at(&["macs"]).as_f64(),
                    has_drift: !matches!(l.at(&["drift"]), Value::Null),
                })
                .collect(),
        };
        let probe = match v.at(&["probe"]) {
            Value::Null => None,
            p => Some(ProbeView {
                images: p.at(&["images"]).as_f64(),
                accuracy: p.at(&["accuracy"]).as_f64(),
                baseline_accuracy: p.at(&["baseline_accuracy"]).as_f64(),
            }),
        };
        Ok(PlanView {
            version: v.at(&["version"]).as_f64(),
            name: v.at(&["name"]).as_str().map(str::to_string),
            model: v.at(&["model"]).as_str().map(str::to_string),
            layers,
            total_area: v.at(&["total_area"]).as_f64(),
            probe,
        })
    }

    /// View an in-memory plan (the `register_plan` / autotuner path —
    /// already typed, so every field is present).
    pub fn from_plan(p: &DeploymentPlan) -> PlanView {
        PlanView {
            version: Some(p.version as f64),
            name: Some(p.name.clone()),
            model: Some(p.model.clone()),
            layers: p
                .layers
                .iter()
                .map(|l| LayerView {
                    enc: Some(l.enc as f64),
                    bits: Some(l.overq.bits as f64),
                    cascade: Some(l.overq.cascade as f64),
                    ro: Some(l.overq.range_overwrite),
                    pr: Some(l.overq.precision_overwrite),
                    scale: Some(l.scale as f64),
                    wbits: Some(l.wbits as f64),
                    p0: Some(l.p0),
                    outlier_rate: Some(l.outlier_rate),
                    theory_coverage: Some(l.theory_coverage),
                    measured_coverage: Some(l.measured_coverage),
                    area: Some(l.area),
                    macs: Some(l.macs as f64),
                    has_drift: l.drift.is_some(),
                })
                .collect(),
            total_area: Some(p.total_area),
            probe: p.probe.as_ref().map(|pr| ProbeView {
                images: Some(pr.images as f64),
                accuracy: Some(pr.accuracy),
                baseline_accuracy: Some(pr.baseline_accuracy),
            }),
        }
    }

    /// Subject string for diagnostics: the plan name when present, a
    /// placeholder otherwise.
    pub fn subject(&self) -> String {
        self.name.clone().unwrap_or_else(|| "<unnamed plan>".to_string())
    }

    /// Whether the declared version is one this build can serve.
    pub fn version_supported(&self) -> bool {
        matches!(self.version, Some(v) if v.fract() == 0.0 && v >= 1.0 && v <= PLAN_VERSION as f64)
    }
}

/// `Some(x)` when `x` is a non-negative integer-valued number.
pub(crate) fn as_uint(x: Option<f64>) -> Option<u64> {
    match x {
        Some(v) if v.is_finite() && v.fract() == 0.0 && v >= 0.0 => Some(v as u64),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn reads_past_strict_loader_rejections() {
        // sparse enc + wbits 12 + version 99: from_json refuses all of
        // these; the view must read them anyway
        let text = r#"{
          "version": 99, "name": "x", "model": "m",
          "layers": [
            {"enc": 0, "bits": 4, "cascade": 2, "ro": true, "pr": false,
             "scale": 0.1, "wbits": 12, "area": 1.0, "macs": 10},
            {"enc": 5, "bits": 4, "cascade": 1, "ro": false, "pr": false,
             "scale": 0.1}
          ],
          "total_area": 1.0
        }"#;
        let v = PlanView::from_value(&parse(text).unwrap()).unwrap();
        assert!(!v.version_supported());
        assert_eq!(v.layers.len(), 2);
        assert_eq!(v.layers[0].wbits, Some(12.0));
        assert_eq!(v.layers[1].enc, Some(5.0));
        assert_eq!(v.layers[1].wbits, None);
        assert!(v.probe.is_none());
    }

    #[test]
    fn rejects_only_hopeless_shapes() {
        assert!(PlanView::from_value(&parse("[1,2]").unwrap()).is_err());
        assert!(PlanView::from_value(&parse(r#"{"layers": 3}"#).unwrap()).is_err());
        // missing layers is a readable (empty) plan — OQ014's job
        let v = PlanView::from_value(&parse(r#"{"name": "x"}"#).unwrap()).unwrap();
        assert!(v.layers.is_empty());
        assert_eq!(v.subject(), "x");
    }

    #[test]
    fn uint_reader() {
        assert_eq!(as_uint(Some(4.0)), Some(4));
        assert_eq!(as_uint(Some(4.5)), None);
        assert_eq!(as_uint(Some(-1.0)), None);
        assert_eq!(as_uint(Some(f64::NAN)), None);
        assert_eq!(as_uint(None), None);
    }
}
