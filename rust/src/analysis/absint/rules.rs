//! The static-certification rules (OQ020–OQ025), judged per enc point
//! from the two abstract tracks computed in [`super`].
//!
//! Rule ordering matters in two places: OQ020 (certain saturation)
//! suppresses the range-sizing warnings — a layer that clips everything
//! has no meaningful "coarse scale" story — and OQ022 (wasted cascade)
//! suppresses OQ021 (coarse scale), because when the proven range
//! already fits base-bit codes, dropping the cascade is the sharper
//! advice than shaving the scale.

use super::{AbsintConfig, EncCertificate};
use crate::analysis::diag::Report;
use crate::policy::plan::PlanLayer;

/// Representable activation max of one plan layer: `(B²-1)·scale` when
/// range overwrite lets codes cascade into a neighbor, `qmax·scale`
/// otherwise.
pub(super) fn capacity(l: &PlanLayer) -> f64 {
    let scale = l.scale as f64;
    if l.overq.range_overwrite {
        let b = l.overq.b() as f64;
        (b * b - 1.0) * scale
    } else {
        l.overq.qmax() as f64 * scale
    }
}

/// Run every static rule for one enc point and push the findings.
pub(super) fn check_enc(
    report: &mut Report,
    subject: &str,
    cfg: &AbsintConfig,
    layer: &PlanLayer,
    cert: &EncCertificate,
) {
    let e = layer.enc;
    let scale = layer.scale as f64;
    let qmax = layer.overq.qmax() as f64;
    let r = &cert.range;

    // OQ020 — statically certain saturation: the representable range is
    // a vanishing fraction of what provably reaches the encoder.
    if cert.quant_hi > 0.0 && cert.capacity / cert.quant_hi < cfg.saturation_ratio {
        report.push(
            "OQ020",
            subject,
            Some(e),
            format!(
                "representable max {:.3e} is {:.1e}x the proven activation bound \
                 {:.3e} — essentially every in-range input saturates past the \
                 cascade capacity (raise scale or bits)",
                cert.capacity,
                cert.capacity / cert.quant_hi,
                cert.quant_hi
            ),
        );
    } else if layer.overq.range_overwrite && r.hi > 0.0 && r.hi <= (qmax + 0.5) * scale {
        // OQ022 — the proven fp32 range already rounds into base-bit
        // codes, so the RO cascade hardware is provably idle.
        report.push(
            "OQ022",
            subject,
            Some(e),
            format!(
                "proven range [{:.4}, {:.4}] fits base-bit codes (qmax*scale = \
                 {:.4}) — range overwrite (cascade {}) is provably idle; \
                 disable ro and reclaim the PE area",
                r.lo,
                r.hi,
                qmax * scale,
                layer.overq.cascade
            ),
        );
    } else if r.hi > 0.0 && qmax * scale > cfg.coarse_factor * r.hi {
        // OQ021 — the code range overshoots the proven range so far
        // that most codes can never fire.
        report.push(
            "OQ021",
            subject,
            Some(e),
            format!(
                "qmax*scale = {:.4} exceeds {:.0}x the proven activation bound \
                 {:.4} — the top codes can provably never fire; lower the scale",
                qmax * scale,
                cfg.coarse_factor,
                r.hi
            ),
        );
    }

    // OQ023 — statically dead enc point or provably-zero source channels.
    if r.hi <= 0.0 {
        report.push(
            "OQ023",
            subject,
            Some(e),
            format!(
                "enc tensor is proven identically <= 0 under the declared input \
                 domain (range [{:.4}, {:.4}]) — this layer quantizes zeros",
                r.lo, r.hi
            ),
        );
    } else if r.dead_channels > 0 {
        report.push(
            "OQ023",
            subject,
            Some(e),
            format!(
                "{}/{} source channels are proven identically zero (pre-ReLU \
                 upper bound <= 0) — dead channels spend PE area on zeros",
                r.dead_channels, r.channels
            ),
        );
    }

    // OQ024 — a declared drift baseline outside the provable interval
    // cannot have come from this model on this input domain.
    if let Some(d) = &layer.drift {
        if !(r.lo..=r.hi).contains(&d.mean) {
            report.push(
                "OQ024",
                subject,
                Some(e),
                format!(
                    "declared drift baseline mean {:.4} lies outside the proven \
                     activation interval [{:.4}, {:.4}] — re-profile; the live \
                     telemetry would compare against an impossible baseline",
                    d.mean, r.lo, r.hi
                ),
            );
        }
    }

    // OQ025 — configurable budget on the propagated Eq.(1) error bound.
    if let Some(budget) = cfg.error_budget {
        if cert.rel_err > budget {
            report.push(
                "OQ025",
                subject,
                Some(e),
                format!(
                    "worst-case accumulated quantization error {:.3e} is {:.3e} \
                     of the representable signal — over the configured budget \
                     {budget:.3e}; spend more bits here or upstream",
                    cert.err_bound, cert.rel_err
                ),
            );
        }
    }
}
