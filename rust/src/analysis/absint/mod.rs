//! Abstract interpretation over the model graph: static range & error
//! certification.
//!
//! The linter ([`super::rules`]) checks a plan's *declared* facts; this
//! module proves facts about the *execution* without running a single
//! input. A dataflow walk propagates abstract values — intervals
//! `[lo, hi]` plus a worst-case accumulated quantization-error bound
//! (the paper's Eq. (1) proxy, statically evaluated) — through
//! [`crate::nn::Graph`] node by node:
//!
//! * **conv / dense** — per-output-channel weight-column L1 bounds
//!   ([`crate::nn::AffineBounds`]): with input elements in `[lo, hi]`,
//!   channel `j` lands in `[pos_j·lo + neg_j·hi + b_j,
//!   pos_j·hi + neg_j·lo + b_j]` (a BN folded into the weights is just
//!   another affine transform and needs no special case). Errors grow
//!   by the induced L∞ norm `max_j (pos_j - neg_j)`. SAME padding
//!   widens the input with `{0}` first — the im2col stream reads real
//!   zeros at the border.
//! * **ReLU** — meet with `[0, ∞)`; non-expansive for the error track.
//! * **residual add** — interval (Minkowski) sum; errors add.
//! * **concat** — interval join; errors take the max.
//! * **max/avg pool, global average pool** — outputs are means/maxima
//!   of genuine input values (`nn::engine::pool2` pads nothing), so the
//!   interval passes through unchanged; non-expansive for errors.
//!
//! Two tracks run over the same graph. The **fp32 track** ignores the
//! plan and bounds the reference [`crate::nn::Engine::forward_f32`]
//! execution — its per-enc-point [`StaticRange`] certificates are what
//! the soundness harness (`rust/tests/integration_absint.rs`) holds
//! profiled activations against. The **quant track** additionally
//! clamps at every enc point to the plan's representable range and
//! accrues rounding/clipping error, which is what saturation (OQ020)
//! and error budgets (OQ025) must be judged on — a saturating upstream
//! layer otherwise poisons every downstream bound.
//!
//! [`verify_plan`] runs both tracks and the OQ020–OQ025 rules,
//! returning a [`Certification`] whose [`Report`] shares the lint
//! exit-code contract. The same gate runs inside
//! `ModelHandle::register_plan` / `swap_plan` / `PlanWatch`, and
//! `policy::autotune` prunes provably-saturating candidates with
//! [`GraphBounds::quant_track_hi`] before spending proxy budget.

use anyhow::{bail, Result};

use super::diag::Report;
use crate::models::zoo::LoadedModel;
use crate::nn::{AffineBounds, Engine, Op};
use crate::policy::plan::DeploymentPlan;
use crate::util::json::Value;

mod domain;
mod rules;

pub use domain::{AbsVal, AbsintConfig, Interval, DEFAULT_INPUT_RANGE};

/// Transfer function of one graph node, with everything the abstract
/// walk needs pre-extracted from the engine.
#[derive(Clone, Debug)]
enum Transfer {
    /// The input placeholder: takes the declared input domain.
    Input,
    /// Conv or dense. `enc` is the consumed enc point for quantized
    /// convs; `pad_zero` marks SAME-padded convs whose im2col stream
    /// includes border zeros; `l1_max` is the induced L∞ norm.
    Affine {
        ab: AffineBounds,
        relu: bool,
        enc: Option<usize>,
        pad_zero: bool,
        l1_max: f64,
    },
    /// Elementwise residual add over all inputs.
    Add { relu: bool },
    /// Channel concatenation.
    Concat,
    /// Max or average pooling (2×2, unpadded).
    Pool,
    /// Global average pool.
    Gap,
}

#[derive(Clone, Debug)]
struct NodeBounds {
    inputs: Vec<usize>,
    transfer: Transfer,
}

/// Plan-independent abstract summary of one model graph: everything the
/// analyzer needs, extracted once from the [`Engine`] so repeated
/// verifications (serving gates, autotune pruning) don't re-walk the
/// weights.
#[derive(Clone, Debug)]
pub struct GraphBounds {
    /// Model name the bounds were extracted from.
    pub model: String,
    nodes: Vec<NodeBounds>,
    /// Per enc point: the node id producing the quantized tensor
    /// (`None` for holes a malformed graph might leave — lint OQ011's
    /// business, skipped here).
    enc_src: Vec<Option<usize>>,
}

/// Statically proven facts about one enc point under the fp32 reference
/// execution — the certificate the soundness harness checks profiled
/// activations against.
#[derive(Clone, Copy, Debug)]
pub struct StaticRange {
    /// Enc-point index.
    pub enc: usize,
    /// Graph node id producing the enc tensor.
    pub src: usize,
    /// Proven lower bound on every element of the enc tensor.
    pub lo: f64,
    /// Proven upper bound on every element of the enc tensor.
    pub hi: f64,
    /// Output channels of the source conv proven identically zero
    /// (pre-ReLU upper bound `<= 0`); 0 when the source is not a
    /// ReLU conv.
    pub dead_channels: usize,
    /// Output-channel count of the source conv (0 when not a conv).
    pub channels: usize,
}

/// Quant-track facts for one enc point under a concrete plan.
#[derive(Clone, Copy, Debug)]
struct EncQuant {
    /// Pre-clamp magnitude bound of the tensor reaching the encoder.
    hi: f64,
    /// Accumulated error bound after encoding (rounding + clipping +
    /// propagated upstream error).
    err: f64,
}

/// One enc point's combined certificate: fp32-track range plus
/// quant-track capacity/error facts under the verified plan.
#[derive(Clone, Copy, Debug)]
pub struct EncCertificate {
    /// fp32-track range certificate.
    pub range: StaticRange,
    /// Pre-clamp magnitude bound under the plan's quantized execution.
    pub quant_hi: f64,
    /// Representable activation max of the plan layer
    /// (`(B²-1)·scale` with range overwrite, `qmax·scale` without).
    pub capacity: f64,
    /// Worst-case accumulated quantization error entering the
    /// consuming convs.
    pub err_bound: f64,
    /// `err_bound` relative to the representable signal magnitude —
    /// what [`AbsintConfig::error_budget`] (OQ025) is compared against.
    pub rel_err: f64,
}

/// Result of statically verifying one plan against one model: per-enc
/// certificates plus the OQ020–OQ025 findings.
#[derive(Clone, Debug)]
pub struct Certification {
    /// Model the plan was verified against.
    pub model: String,
    /// Per-enc-point certificates, in plan-layer order.
    pub encs: Vec<EncCertificate>,
    /// Findings; shares the lint exit-code contract.
    pub report: Report,
}

impl Certification {
    /// Machine rendering (`overq verify --json`): the certificate array
    /// plus the report's sorted diagnostics, one stable object.
    pub fn to_json(&self) -> Value {
        use std::collections::BTreeMap;
        let encs: Vec<Value> = self
            .encs
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("enc".to_string(), Value::Num(c.range.enc as f64));
                m.insert("src".to_string(), Value::Num(c.range.src as f64));
                m.insert("lo".to_string(), Value::Num(c.range.lo));
                m.insert("hi".to_string(), Value::Num(c.range.hi));
                m.insert(
                    "dead_channels".to_string(),
                    Value::Num(c.range.dead_channels as f64),
                );
                m.insert("quant_hi".to_string(), Value::Num(c.quant_hi));
                m.insert("capacity".to_string(), Value::Num(c.capacity));
                m.insert("err_bound".to_string(), Value::Num(c.err_bound));
                m.insert("rel_err".to_string(), Value::Num(c.rel_err));
                Value::Obj(m)
            })
            .collect();
        let mut m = match self.report.to_json() {
            Value::Obj(m) => m,
            _ => BTreeMap::new(),
        };
        m.insert("model".to_string(), Value::Str(self.model.clone()));
        m.insert("certificate".to_string(), Value::Arr(encs));
        Value::Obj(m)
    }
}

/// Per-output-channel affine transfer: hull over channels plus the
/// count of channels whose upper bound is `<= 0` (dead after ReLU).
fn affine_iv(ab: &AffineBounds, x: Interval) -> (Interval, usize) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let mut dead = 0usize;
    for ((&p, &n), &b) in ab.pos.iter().zip(&ab.neg).zip(&ab.bias) {
        let lo_j = p * x.lo + n * x.hi + b;
        let hi_j = p * x.hi + n * x.lo + b;
        if hi_j <= 0.0 {
            dead += 1;
        }
        lo = lo.min(lo_j);
        hi = hi.max(hi_j);
    }
    if lo > hi {
        // zero output channels — degenerate but not unsound
        return (Interval::new(0.0, 0.0), 0);
    }
    (Interval::new(lo, hi), dead)
}

impl GraphBounds {
    /// Extract bounds from a loaded model's engine.
    pub fn from_model(model: &LoadedModel) -> Result<GraphBounds> {
        GraphBounds::from_engine(&model.engine)
    }

    /// Extract bounds from an engine: one [`Transfer`] per graph node.
    /// Fails only when a conv/dense node has no prepared weights —
    /// impossible for engines built through [`Engine::new`].
    pub fn from_engine(engine: &Engine) -> Result<GraphBounds> {
        let graph = &engine.graph;
        let mut nodes = Vec::with_capacity(graph.nodes.len());
        for node in &graph.nodes {
            let transfer = match &node.op {
                Op::Input => Transfer::Input,
                Op::Conv { relu, enc, .. } => {
                    let Some(ab) = engine.affine_bounds(node.id) else {
                        bail!("conv node {} has no prepared weights", node.id);
                    };
                    let l1_max = l1_max_of(&ab);
                    Transfer::Affine {
                        ab,
                        relu: *relu,
                        enc: *enc,
                        pad_zero: true,
                        l1_max,
                    }
                }
                Op::Dense { .. } => {
                    let Some(ab) = engine.affine_bounds(node.id) else {
                        bail!("dense node {} has no prepared weights", node.id);
                    };
                    let l1_max = l1_max_of(&ab);
                    Transfer::Affine {
                        ab,
                        relu: false,
                        enc: None,
                        pad_zero: false,
                        l1_max,
                    }
                }
                Op::Add { relu } => Transfer::Add { relu: *relu },
                Op::Concat => Transfer::Concat,
                Op::MaxPool | Op::AvgPool => Transfer::Pool,
                Op::Gap => Transfer::Gap,
            };
            nodes.push(NodeBounds {
                inputs: node.inputs.clone(),
                transfer,
            });
        }
        let enc_src = graph
            .enc_point_sources()
            .into_iter()
            .map(|s| if s == usize::MAX { None } else { Some(s) })
            .collect();
        Ok(GraphBounds {
            model: graph.name.clone(),
            nodes,
            enc_src,
        })
    }

    /// Number of enc points the graph declares.
    pub fn num_enc_points(&self) -> usize {
        self.enc_src.len()
    }

    /// fp32 track: proven per-enc-point ranges under `input` for the
    /// reference [`Engine::forward_f32`] execution. Entries appear in
    /// enc order; enc points without a resolvable source are omitted.
    pub fn analyze(&self, input: Interval) -> Vec<StaticRange> {
        let n = self.nodes.len();
        let mut vals: Vec<Interval> = Vec::with_capacity(n);
        let mut dead = vec![0usize; n];
        let mut channels = vec![0usize; n];
        for (id, node) in self.nodes.iter().enumerate() {
            let out = match &node.transfer {
                Transfer::Input => input,
                Transfer::Affine {
                    ab, relu, pad_zero, ..
                } => {
                    let mut x = vals[node.inputs[0]];
                    if *pad_zero {
                        x = x.with_zero();
                    }
                    let (iv, d) = affine_iv(ab, x);
                    channels[id] = ab.bias.len();
                    if *relu {
                        dead[id] = d;
                    }
                    if *relu {
                        iv.relu()
                    } else {
                        iv
                    }
                }
                Transfer::Add { relu } => {
                    let mut iv = vals[node.inputs[0]];
                    for &i in &node.inputs[1..] {
                        iv = iv.add(vals[i]);
                    }
                    if *relu {
                        iv.relu()
                    } else {
                        iv
                    }
                }
                Transfer::Concat => {
                    let mut iv = vals[node.inputs[0]];
                    for &i in &node.inputs[1..] {
                        iv = iv.join(vals[i]);
                    }
                    iv
                }
                Transfer::Pool | Transfer::Gap => vals[node.inputs[0]],
            };
            vals.push(out);
        }
        self.enc_src
            .iter()
            .enumerate()
            .filter_map(|(e, src)| {
                let src = (*src)?;
                Some(StaticRange {
                    enc: e,
                    src,
                    lo: vals[src].lo,
                    hi: vals[src].hi,
                    dead_channels: dead[src],
                    channels: channels[src],
                })
            })
            .collect()
    }

    /// Quant track: walk with per-enc clamping at `caps[e] = (capacity,
    /// scale)` and error accrual. Returns one [`EncQuant`] per enc
    /// point (zeros for unresolvable ones), recorded at the first
    /// consuming conv.
    fn quant_walk(&self, input: Interval, caps: &[Option<(f64, f64)>]) -> Vec<EncQuant> {
        let mut facts: Vec<Option<EncQuant>> = vec![None; self.enc_src.len()];
        let mut vals: Vec<AbsVal> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let out = match &node.transfer {
                Transfer::Input => AbsVal { iv: input, err: 0.0 },
                Transfer::Affine {
                    ab,
                    relu,
                    enc,
                    pad_zero,
                    l1_max,
                } => {
                    let mut x = vals[node.inputs[0]];
                    if *pad_zero {
                        x.iv = x.iv.with_zero();
                    }
                    if let Some(e) = enc {
                        let hi_in = x.iv.abs_max();
                        if let Some(Some((cap, scale))) = caps.get(*e).copied() {
                            // encoding at this point: half-step rounding
                            // plus worst-case clipping at the capacity
                            let err = x.err + 0.5 * scale + (hi_in - cap).max(0.0);
                            if facts[*e].is_none() {
                                facts[*e] = Some(EncQuant { hi: hi_in, err });
                            }
                            x = AbsVal {
                                iv: x.iv.clamp_abs(cap),
                                err,
                            };
                        } else if let Some(f) = facts.get_mut(*e) {
                            if f.is_none() {
                                *f = Some(EncQuant { hi: hi_in, err: x.err });
                            }
                        }
                    }
                    let (iv, _) = affine_iv(ab, x.iv);
                    AbsVal {
                        iv: if *relu { iv.relu() } else { iv },
                        err: l1_max * x.err,
                    }
                }
                Transfer::Add { relu } => {
                    let mut iv = vals[node.inputs[0]].iv;
                    let mut err = vals[node.inputs[0]].err;
                    for &i in &node.inputs[1..] {
                        iv = iv.add(vals[i].iv);
                        err += vals[i].err;
                    }
                    AbsVal {
                        iv: if *relu { iv.relu() } else { iv },
                        err,
                    }
                }
                Transfer::Concat => {
                    let mut iv = vals[node.inputs[0]].iv;
                    let mut err = vals[node.inputs[0]].err;
                    for &i in &node.inputs[1..] {
                        iv = iv.join(vals[i].iv);
                        err = err.max(vals[i].err);
                    }
                    AbsVal { iv, err }
                }
                Transfer::Pool | Transfer::Gap => vals[node.inputs[0]],
            };
            vals.push(out);
        }
        facts
            .into_iter()
            .map(|f| f.unwrap_or(EncQuant { hi: 0.0, err: 0.0 }))
            .collect()
    }

    /// Quant-track magnitude bound per enc point when each enc clamps
    /// at `caps[e]` — the scaffolding `policy::autotune` prunes
    /// candidate configs with: a candidate whose representable range is
    /// a vanishing fraction of this bound provably saturates, so its
    /// proxy score never needs computing. Entries of `caps` may be
    /// `f64::INFINITY` for "no clamp".
    pub fn quant_track_hi(&self, input: Interval, caps: &[f64]) -> Vec<f64> {
        let caps: Vec<Option<(f64, f64)>> = caps.iter().map(|&c| Some((c, 0.0))).collect();
        self.quant_walk(input, &caps).into_iter().map(|q| q.hi).collect()
    }
}

/// Induced L∞ matrix norm from the per-channel bounds:
/// `max_j Σ_i |w_ij|`.
fn l1_max_of(ab: &AffineBounds) -> f64 {
    ab.pos
        .iter()
        .zip(&ab.neg)
        .map(|(&p, &n)| p - n)
        .fold(0.0f64, f64::max)
}

/// Statically verify `plan` against `model` over the declared `input`
/// domain: run both abstract tracks and the OQ020–OQ025 rules.
pub fn verify_plan(
    plan: &DeploymentPlan,
    model: &LoadedModel,
    input: Interval,
    cfg: &AbsintConfig,
) -> Result<Certification> {
    let gb = GraphBounds::from_model(model)?;
    Ok(verify_plan_with_bounds(&gb, plan, input, cfg))
}

/// [`verify_plan`] against pre-extracted [`GraphBounds`] — the serving
/// gates keep bounds per shard and call this on every
/// register/swap/watch apply.
pub fn verify_plan_with_bounds(
    gb: &GraphBounds,
    plan: &DeploymentPlan,
    input: Interval,
    cfg: &AbsintConfig,
) -> Certification {
    let ranges = gb.analyze(input);
    // capacity/scale per enc point, from the plan's layer configs;
    // degenerate scales (lint OQ006's domain) leave the point unclamped
    let mut caps: Vec<Option<(f64, f64)>> = vec![None; gb.num_enc_points()];
    for l in &plan.layers {
        let scale = l.scale as f64;
        if l.enc < caps.len() && scale.is_finite() && scale > 0.0 {
            caps[l.enc] = Some((rules::capacity(l), scale));
        }
    }
    let quant = gb.quant_walk(input, &caps);

    let mut report = Report::default();
    let mut encs = Vec::new();
    for layer in &plan.layers {
        let Some(range) = ranges.iter().find(|r| r.enc == layer.enc).copied() else {
            continue; // dangling enc — lint OQ012's business
        };
        let Some((capacity, _)) = caps[layer.enc] else {
            continue; // degenerate scale — lint OQ006's business
        };
        let q = quant[layer.enc];
        let cert = EncCertificate {
            range,
            quant_hi: q.hi,
            capacity,
            err_bound: q.err,
            rel_err: q.err / q.hi.min(capacity).max(1e-12),
        };
        rules::check_enc(&mut report, &plan.name, cfg, layer, &cert);
        encs.push(cert);
    }
    Certification {
        model: plan.model.clone(),
        encs,
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth_model;

    #[test]
    fn affine_transfer_is_exact_on_a_known_matrix() {
        // bounds of w = [[1, -2], [3, 0.5]] (K=2 inputs, 2 channels),
        // bias [0, 1]: pos/neg are the column-wise signed sums
        let ab = crate::nn::AffineBounds {
            pos: vec![4.0, 0.5],
            neg: vec![0.0, -2.0],
            bias: vec![0.0, 1.0],
        };
        let (iv, dead) = affine_iv(&ab, Interval::new(-1.0, 2.0));
        // ch0: [4*-1+0, 4*2+0] = [-4, 8]; ch1: [0.5*-1 + -2*2 + 1,
        // 0.5*2 + -2*-1 + 1] = [-4.5, 4]; hull = [-4.5, 8]
        assert_eq!(iv, Interval::new(-4.5, 8.0));
        assert_eq!(dead, 0);
        assert!((l1_max_of(&ab) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn synth_models_analyze_with_finite_positive_ranges() {
        for name in ["synth-tiny", "synth-cnn"] {
            let model = synth_model(name, 42).unwrap();
            let gb = GraphBounds::from_model(&model).unwrap();
            let ranges = gb.analyze(DEFAULT_INPUT_RANGE);
            assert_eq!(ranges.len(), gb.num_enc_points(), "{name}: missing enc ranges");
            for r in &ranges {
                assert!(r.lo <= r.hi && r.hi.is_finite(), "{name} enc {}: bad range", r.enc);
                assert!(r.hi > 0.0, "{name} enc {}: dead enc in a live model", r.enc);
                assert_eq!(r.dead_channels, 0, "{name} enc {}: false dead channels", r.enc);
            }
        }
    }

    #[test]
    fn quant_track_clamps_downstream_growth() {
        let model = synth_model("synth-tiny", 42).unwrap();
        let gb = GraphBounds::from_model(&model).unwrap();
        let n = gb.num_enc_points();
        let unclamped = vec![f64::INFINITY; n];
        let tight = vec![1.0; n];
        let free = gb.quant_track_hi(DEFAULT_INPUT_RANGE, &unclamped);
        let clamped = gb.quant_track_hi(DEFAULT_INPUT_RANGE, &tight);
        // enc 0 sees the same (unclamped upstream) bound either way
        assert!((free[0] - clamped[0]).abs() < 1e-9);
        // a tight clamp at enc 0 must shrink what reaches enc 1
        assert!(
            clamped[1] < free[1],
            "clamp at enc 0 did not propagate: {} !< {}",
            clamped[1],
            free[1]
        );
        // and the fp32 track agrees with the unclamped quant track
        let ranges = gb.analyze(DEFAULT_INPUT_RANGE);
        for r in &ranges {
            let m = r.lo.abs().max(r.hi.abs());
            assert!((free[r.enc] - m).abs() <= 1e-9 * m.max(1.0));
        }
    }
}
