//! The abstract domain: closed `f64` intervals plus a worst-case
//! accumulated quantization-error bound.
//!
//! An abstract value tracks two facts about every element of a tensor:
//! the interval `[lo, hi]` it provably lies in, and an upper bound on
//! how far the quantized execution can have drifted from the fp32
//! reference at that point (the static analogue of the paper's Eq. (1)
//! error proxy — rounding half-steps plus worst-case clipping, pushed
//! through each layer's induced L∞ norm).

/// Closed interval `[lo, hi]` over `f64`. Invariant: `lo <= hi`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    /// Lower bound (inclusive).
    pub lo: f64,
    /// Upper bound (inclusive).
    pub hi: f64,
}

impl Interval {
    /// Build an interval; panics when `lo > hi` (analyzer bug, not input).
    pub fn new(lo: f64, hi: f64) -> Interval {
        assert!(lo <= hi, "empty interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// Least upper bound: the hull of both intervals (concat / join).
    pub fn join(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo.min(other.lo),
            hi: self.hi.max(other.hi),
        }
    }

    /// Minkowski sum — the residual-add transfer function.
    pub fn add(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo + other.lo,
            hi: self.hi + other.hi,
        }
    }

    /// ReLU transfer: meet with `[0, inf)`, i.e. max-with-0 on both ends.
    pub fn relu(self) -> Interval {
        Interval {
            lo: self.lo.max(0.0),
            hi: self.hi.max(0.0),
        }
    }

    /// Widen to include 0. SAME-padded convs read genuine zeros at the
    /// border (see `nn::conv::im2col`), so the value stream entering the
    /// GEMM is the input interval hulled with `{0}`.
    pub fn with_zero(self) -> Interval {
        Interval {
            lo: self.lo.min(0.0),
            hi: self.hi.max(0.0),
        }
    }

    /// Clamp into `[-bound, bound]` — what an enc point's representable
    /// range does to every value flowing past it on the quant track.
    pub fn clamp_abs(self, bound: f64) -> Interval {
        Interval {
            lo: self.lo.clamp(-bound, bound),
            hi: self.hi.clamp(-bound, bound),
        }
    }

    /// Largest magnitude contained in the interval.
    pub fn abs_max(self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Membership with relative slack: the engine accumulates in `f32`
    /// while the analyzer tracks `f64`, so soundness checks allow
    /// `tol`-relative rounding headroom.
    pub fn contains(self, v: f64, tol: f64) -> bool {
        let slack = tol * self.abs_max().max(1.0);
        v >= self.lo - slack && v <= self.hi + slack
    }
}

/// Abstract value: value interval plus the accumulated per-element
/// L∞ error bound of the quant track relative to fp32.
#[derive(Clone, Copy, Debug)]
pub struct AbsVal {
    /// Proven value interval.
    pub iv: Interval,
    /// Worst-case accumulated quantization error (`>= 0`).
    pub err: f64,
}

/// Input domain assumed when the caller doesn't state one
/// (`overq verify --input-range` overrides it). Generously covers the
/// normalized pixel range of `data::shapes` (mean 0.28 / std 0.27 over
/// clamped `[0, 1]` pixels lands in roughly `[-1.04, 2.67]`).
pub const DEFAULT_INPUT_RANGE: Interval = Interval { lo: -4.0, hi: 4.0 };

/// Thresholds for the static-certification rules (OQ020–OQ025).
#[derive(Clone, Copy, Debug)]
pub struct AbsintConfig {
    /// OQ020 fires (Error) when `capacity / proven quant-track bound`
    /// falls below this — essentially every in-range input saturates.
    pub saturation_ratio: f64,
    /// OQ021 fires (Warn) when `qmax * scale` exceeds this factor times
    /// the proven fp32 bound — most codes can provably never be used.
    pub coarse_factor: f64,
    /// OQ025 fires (Warn) when the relative propagated error bound at an
    /// enc point exceeds this budget; `None` disables the check.
    pub error_budget: Option<f64>,
}

impl Default for AbsintConfig {
    fn default() -> AbsintConfig {
        AbsintConfig {
            saturation_ratio: 1e-3,
            coarse_factor: 16.0,
            error_budget: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_algebra() {
        let a = Interval::new(-1.0, 2.0);
        let b = Interval::new(0.5, 3.0);
        assert_eq!(a.join(b), Interval::new(-1.0, 3.0));
        assert_eq!(a.add(b), Interval::new(-0.5, 5.0));
        assert_eq!(a.relu(), Interval::new(0.0, 2.0));
        assert_eq!(Interval::new(-3.0, -2.0).relu(), Interval::new(0.0, 0.0));
        assert_eq!(Interval::new(1.0, 2.0).with_zero(), Interval::new(0.0, 2.0));
        assert_eq!(a.clamp_abs(0.5), Interval::new(-0.5, 0.5));
        assert_eq!(a.abs_max(), 2.0);
        assert!(a.contains(2.0, 0.0) && !a.contains(2.1, 1e-6));
        assert!(a.contains(2.0001, 1e-3), "relative slack not applied");
    }
}
