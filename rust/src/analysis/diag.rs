//! Diagnostics framework: stable lint codes, severities, and the
//! [`Report`] both the CLI and the serving wiring consume.
//!
//! Every rule in [`super::rules`] emits [`Diagnostic`]s tagged with a
//! stable code from [`CODES`] — codes are append-only API (CI greps
//! them, `last_watch_error` surfaces them, docs/static_analysis.md
//! catalogs them), so a rule may be retired but its code is never
//! reused with a different meaning.

use std::fmt;

use crate::util::json::Value;

/// How bad a finding is. `Error` findings make a plan unservable (the
/// serving layer refuses it); `Warn` findings are accounting/evidence
/// drift that serves fine but should be fixed; `Info` is advisory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        })
    }
}

/// Registry entry for one lint code.
#[derive(Clone, Copy, Debug)]
pub struct CodeInfo {
    /// Stable code, `OQ001..` — never reused once assigned.
    pub code: &'static str,
    pub severity: Severity,
    /// Short kebab-case rule name.
    pub name: &'static str,
    /// The invariant the rule enforces (one line, shown in `--explain`
    /// style listings and docs/static_analysis.md).
    pub invariant: &'static str,
    /// The canonical remediation (one line). `overq lint --explain
    /// <code>` prints it, and the docs catalog's "example fix" column
    /// mirrors it — this registry is the single source of truth.
    pub fix: &'static str,
}

/// Every lint code this build knows, in code order. The catalog in
/// `docs/static_analysis.md` is generated from the same facts.
pub const CODES: &[CodeInfo] = &[
    CodeInfo {
        code: "OQ001",
        severity: Severity::Error,
        name: "plan-name",
        invariant: "plan and model names are non-empty and fit the \
                    `plan:<name>` variant charset [A-Za-z0-9_.-]",
        fix: "rename the plan (`overq policy --name my-plan.v2`); spaces \
              and `!` cannot be routed to",
    },
    CodeInfo {
        code: "OQ002",
        severity: Severity::Error,
        name: "enc-dense",
        invariant: "layer enc indices are dense 0..n with no duplicates or holes",
        fix: "regenerate the plan; hand-edited files usually hit this by \
              deleting a layer without renumbering",
    },
    CodeInfo {
        code: "OQ003",
        severity: Severity::Error,
        name: "act-bits",
        invariant: "activation bitwidth is an integer in 2..=8",
        fix: "clamp `bits` to the supported range; 1-bit and >8-bit \
              activations have no PE datapath",
    },
    CodeInfo {
        code: "OQ004",
        severity: Severity::Error,
        name: "cascade-zero",
        invariant: "cascade factor is an integer >= 1 (adjacent-only RO is cascade 1)",
        fix: "set `cascade: 1` — zero would mean \"overwrite into no neighbor\"",
    },
    CodeInfo {
        code: "OQ005",
        severity: Severity::Error,
        name: "cascade-no-ro",
        invariant: "cascade > 1 requires range overwrite (cascading is an RO \
                    rescale-unit feature; per overq::state it has no effect without RO)",
        fix: "enable `ro: true` or drop `cascade` to 1",
    },
    CodeInfo {
        code: "OQ006",
        severity: Severity::Error,
        name: "scale",
        invariant: "activation scale is finite and > 0",
        fix: "recalibrate; a zero/NaN scale quantizes everything to 0",
    },
    CodeInfo {
        code: "OQ007",
        severity: Severity::Error,
        name: "wbits",
        invariant: "weight bitwidth is 0 (prepared 8-bit default) or 2..=8 \
                    (the engine's MMSE requant cache range)",
        fix: "pick a `wbits` the engine can prepare; 1-bit weights are \
              outside the requant cache",
    },
    CodeInfo {
        code: "OQ008",
        severity: Severity::Warn,
        name: "area-drift",
        invariant: "declared per-layer PE area and total_area match the \
                    Table-3 model (area::pe_area_w, MAC-weighted mean)",
        fix: "re-save the plan with the current area model (re-run `overq policy`)",
    },
    CodeInfo {
        code: "OQ009",
        severity: Severity::Warn,
        name: "evidence",
        invariant: "evidence statistics (p0, outlier_rate, coverages, probe \
                    accuracies) lie in [0,1] and the probe split is non-empty",
        fix: "re-profile; out-of-range evidence means the stats were edited \
              or mis-merged",
    },
    CodeInfo {
        code: "OQ010",
        severity: Severity::Warn,
        name: "schema-v1",
        invariant: "plan file uses the current schema version (v1 still loads; \
                    re-save to stamp v2)",
        fix: "load + `save()` once to migrate; v1 files serve with \
              backward-compatible defaults",
    },
    CodeInfo {
        code: "OQ011",
        severity: Severity::Error,
        name: "enc-missing",
        invariant: "every enc point of the model graph is configured by the plan",
        fix: "retune against this model; a partial plan would serve some \
              layers unconfigured",
    },
    CodeInfo {
        code: "OQ012",
        severity: Severity::Error,
        name: "enc-dangling",
        invariant: "no plan layer targets an enc point beyond the model's count",
        fix: "the plan was tuned for a different (larger) model — check the \
              `model` field",
    },
    CodeInfo {
        code: "OQ013",
        severity: Severity::Warn,
        name: "macs-drift",
        invariant: "declared per-layer MACs match a static recompute over the \
                    graph (OCS-expanded input channels included, as in policy::profile)",
        fix: "re-profile; drifted MACs skew the MAC-weighted area/coverage \
              accounting",
    },
    CodeInfo {
        code: "OQ014",
        severity: Severity::Error,
        name: "empty",
        invariant: "a plan configures at least one enc point",
        fix: "an empty `layers` array serves nothing; regenerate",
    },
    CodeInfo {
        code: "OQ015",
        severity: Severity::Error,
        name: "dup-alias",
        invariant: "no two files in a watched plan directory claim the same \
                    (model, name) alias — the later apply would silently win",
        fix: "rename one plan; otherwise the later poll apply silently wins \
              the serving slot",
    },
    CodeInfo {
        code: "OQ016",
        severity: Severity::Error,
        name: "split",
        invariant: "traffic splits have >= 1 non-nested arm with positive finite \
                    weights and no duplicate arms",
        fix: "deduplicate arms / fix weights; a degenerate split makes A/B \
              metrics unattributable",
    },
    CodeInfo {
        code: "OQ017",
        severity: Severity::Warn,
        name: "control-starved",
        invariant: "every split arm keeps a non-negligible traffic share \
                    (>= 1% of the total weight)",
        fix: "raise the starved arm's weight; a starved control arm cannot \
              anchor the comparison (see docs/operations.md)",
    },
    CodeInfo {
        code: "OQ018",
        severity: Severity::Error,
        name: "unreadable",
        invariant: "the file parses as JSON, is a plan object, and declares a \
                    supported schema version",
        fix: "fix truncation/corruption; OQ018 also covers unreadable paths \
              and empty watch dirs",
    },
    CodeInfo {
        code: "OQ019",
        severity: Severity::Warn,
        name: "drift-baseline",
        invariant: "every layer stores the profile-time drift baseline \
                    (mean/var/clip_rate) the live telemetry compares against; \
                    re-profile plans tuned before it existed",
        fix: "re-run `overq policy` — plans tuned before the telemetry \
              subsystem serve fine but cannot be watched for distribution \
              shift until re-profiled",
    },
    // OQ020.. are the static-certification rules (analysis::absint):
    // abstract interpretation over the model graph proves them from
    // weights and the declared input domain alone — no profile data.
    CodeInfo {
        code: "OQ020",
        severity: Severity::Error,
        name: "static-saturation",
        invariant: "the representable activation range at each enc point \
                    covers a non-negligible fraction of the statically \
                    proven activation bound (capacity/bound >= 1e-3)",
        fix: "raise the activation scale or bits — abstract interpretation \
              proves essentially every in-range input saturates this \
              layer's cascade capacity",
    },
    CodeInfo {
        code: "OQ021",
        severity: Severity::Warn,
        name: "static-coarse-scale",
        invariant: "the quantization range is not provably oversized: \
                    qmax*scale stays within 16x the statically proven \
                    activation bound",
        fix: "lower the scale (recalibrate); codes above the proven range \
              can never fire, so the layer wastes resolution",
    },
    CodeInfo {
        code: "OQ022",
        severity: Severity::Warn,
        name: "static-wasted-cascade",
        invariant: "range overwrite is only enabled where the statically \
                    proven range can exceed base-bit codes (otherwise the \
                    cascade hardware is provably idle)",
        fix: "disable `ro`/cascade for this layer and reclaim the PE area — \
              the proven range already fits base-bit codes",
    },
    CodeInfo {
        code: "OQ023",
        severity: Severity::Warn,
        name: "static-dead",
        invariant: "no enc point or source channel is statically proven \
                    identically zero under the declared input domain",
        fix: "strip provably-dead channels from the model (or widen \
              `--input-range`); dead enc points spend PE area quantizing zeros",
    },
    CodeInfo {
        code: "OQ024",
        severity: Severity::Warn,
        name: "static-drift-domain",
        invariant: "every declared drift-baseline mean lies inside the \
                    statically proven activation interval",
        fix: "re-profile — a baseline mean outside the provable interval can \
              only come from a different model, input domain, or a stats bug",
    },
    CodeInfo {
        code: "OQ025",
        severity: Severity::Warn,
        name: "static-error-budget",
        invariant: "the worst-case accumulated quantization error (the \
                    Eq.(1) proxy propagated through the graph) stays within \
                    the configured per-layer relative budget",
        fix: "spend more bits on this layer or its upstream layers (raise \
              `bits`, enable `pr`) to bring the propagated error bound \
              under budget",
    },
];

/// Look up a code's registry entry.
pub fn code_info(code: &str) -> Option<&'static CodeInfo> {
    CODES.iter().find(|c| c.code == code)
}

/// One finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable code from [`CODES`].
    pub code: &'static str,
    pub severity: Severity,
    /// What was linted: a plan name, a file path, or a split spec.
    pub subject: String,
    /// Enc-point index the finding anchors to, when layer-scoped.
    pub enc: Option<usize>,
    /// Human-readable statement of the violation.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic for `code`, taking the severity from the
    /// registry. Panics on unknown codes — rule bugs, not inputs.
    pub fn new(code: &str, subject: &str, enc: Option<usize>, message: String) -> Diagnostic {
        let info = code_info(code).unwrap_or_else(|| panic!("unknown lint code {code}"));
        Diagnostic {
            code: info.code,
            severity: info.severity,
            subject: subject.to_string(),
            enc,
            message,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}] {}", self.severity, self.code, self.subject)?;
        if let Some(e) = self.enc {
            write!(f, " enc {e}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Findings of one lint run, with the CLI/CI presentation logic.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn push(&mut self, code: &str, subject: &str, enc: Option<usize>, message: String) {
        self.diagnostics.push(Diagnostic::new(code, subject, enc, message));
    }

    /// Append another report's findings.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    pub fn warn_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// First Error-level finding — what the serving layer surfaces when
    /// it refuses a plan.
    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.errors().next()
    }

    /// True when nothing was found at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// CI exit code: 0 clean (or warnings without `deny_warn`),
    /// 1 for lint findings that gate. Operational failures (unreadable
    /// paths etc.) are reported as OQ018 errors, so they gate too.
    pub fn exit_code(&self, deny_warn: bool) -> i32 {
        if self.has_errors() || (deny_warn && self.warn_count() > 0) {
            1
        } else {
            0
        }
    }

    /// Human rendering, one line per finding plus a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "{} error(s), {} warning(s)\n",
            self.error_count(),
            self.warn_count()
        ));
        out
    }

    /// Machine rendering (`overq lint --json`). Diagnostics are sorted
    /// by (code, enc, subject, message) so the output is byte-stable
    /// across runs and diffable in CI artifacts regardless of rule
    /// evaluation order. The human rendering keeps push order (it reads
    /// as a narrative of what each rule saw).
    pub fn to_json(&self) -> Value {
        use std::collections::BTreeMap;
        let mut sorted: Vec<&Diagnostic> = self.diagnostics.iter().collect();
        sorted.sort_by(|a, b| {
            (a.code, a.enc, &a.subject, &a.message).cmp(&(b.code, b.enc, &b.subject, &b.message))
        });
        let diags: Vec<Value> = sorted
            .into_iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("code".to_string(), Value::Str(d.code.to_string()));
                m.insert("severity".to_string(), Value::Str(d.severity.to_string()));
                m.insert("subject".to_string(), Value::Str(d.subject.clone()));
                if let Some(e) = d.enc {
                    m.insert("enc".to_string(), Value::Num(e as f64));
                }
                m.insert("message".to_string(), Value::Str(d.message.clone()));
                Value::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("diagnostics".to_string(), Value::Arr(diags));
        m.insert("errors".to_string(), Value::Num(self.error_count() as f64));
        m.insert("warnings".to_string(), Value::Num(self.warn_count() as f64));
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_ordered() {
        for w in CODES.windows(2) {
            assert!(w[0].code < w[1].code, "{} !< {}", w[0].code, w[1].code);
        }
        assert!(code_info("OQ001").is_some());
        assert!(code_info("OQ999").is_none());
    }

    #[test]
    fn report_accounting_and_exit_codes() {
        let mut r = Report::default();
        assert_eq!(r.exit_code(true), 0);
        r.push("OQ008", "p", Some(1), "area drift".into());
        assert_eq!(r.error_count(), 0);
        assert_eq!(r.warn_count(), 1);
        assert_eq!(r.exit_code(false), 0);
        assert_eq!(r.exit_code(true), 1);
        r.push("OQ004", "p", Some(0), "cascade 0".into());
        assert!(r.has_errors());
        assert_eq!(r.exit_code(false), 1);
        assert_eq!(r.first_error().unwrap().code, "OQ004");
        let text = r.render_human();
        assert!(text.contains("error [OQ004] p enc 0"));
        assert!(text.contains("1 error(s), 1 warning(s)"));
        let json = r.to_json().to_json();
        assert!(json.contains("\"OQ008\"") && json.contains("\"OQ004\""));
    }

    #[test]
    fn every_code_carries_a_fix() {
        for c in CODES {
            assert!(!c.fix.trim().is_empty(), "{} has no fix text", c.code);
            assert!(!c.invariant.trim().is_empty(), "{} has no invariant", c.code);
        }
        // the static-certification family is registered
        for code in ["OQ020", "OQ021", "OQ022", "OQ023", "OQ024", "OQ025"] {
            assert!(code_info(code).is_some(), "{code} missing from CODES");
        }
        assert_eq!(code_info("OQ020").unwrap().severity, Severity::Error);
    }

    #[test]
    fn json_output_is_sorted_and_push_order_independent() {
        let mut a = Report::default();
        a.push("OQ013", "p", Some(1), "macs".into());
        a.push("OQ004", "p", Some(1), "cascade".into());
        a.push("OQ004", "p", Some(0), "cascade".into());
        a.push("OQ004", "p", None, "cascade".into());
        let mut b = Report::default();
        b.push("OQ004", "p", Some(0), "cascade".into());
        b.push("OQ004", "p", None, "cascade".into());
        b.push("OQ013", "p", Some(1), "macs".into());
        b.push("OQ004", "p", Some(1), "cascade".into());
        let (ja, jb) = (a.to_json().to_json(), b.to_json().to_json());
        assert_eq!(ja, jb, "JSON output depends on rule evaluation order");
        let first = ja.find("\"OQ004\"").unwrap();
        let last = ja.rfind("\"OQ013\"").unwrap();
        assert!(first < last, "diagnostics not sorted by code");
        // human rendering still narrates in push order
        assert!(a.render_human().starts_with("warn [OQ013]"));
    }
}
