//! Diagnostics framework: stable lint codes, severities, and the
//! [`Report`] both the CLI and the serving wiring consume.
//!
//! Every rule in [`super::rules`] emits [`Diagnostic`]s tagged with a
//! stable code from [`CODES`] — codes are append-only API (CI greps
//! them, `last_watch_error` surfaces them, docs/static_analysis.md
//! catalogs them), so a rule may be retired but its code is never
//! reused with a different meaning.

use std::fmt;

use crate::util::json::Value;

/// How bad a finding is. `Error` findings make a plan unservable (the
/// serving layer refuses it); `Warn` findings are accounting/evidence
/// drift that serves fine but should be fixed; `Info` is advisory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        })
    }
}

/// Registry entry for one lint code.
#[derive(Clone, Copy, Debug)]
pub struct CodeInfo {
    /// Stable code, `OQ001..` — never reused once assigned.
    pub code: &'static str,
    pub severity: Severity,
    /// Short kebab-case rule name.
    pub name: &'static str,
    /// The invariant the rule enforces (one line, shown in `--explain`
    /// style listings and docs/static_analysis.md).
    pub invariant: &'static str,
}

/// Every lint code this build knows, in code order. The catalog in
/// `docs/static_analysis.md` is generated from the same facts.
pub const CODES: &[CodeInfo] = &[
    CodeInfo {
        code: "OQ001",
        severity: Severity::Error,
        name: "plan-name",
        invariant: "plan and model names are non-empty and fit the \
                    `plan:<name>` variant charset [A-Za-z0-9_.-]",
    },
    CodeInfo {
        code: "OQ002",
        severity: Severity::Error,
        name: "enc-dense",
        invariant: "layer enc indices are dense 0..n with no duplicates or holes",
    },
    CodeInfo {
        code: "OQ003",
        severity: Severity::Error,
        name: "act-bits",
        invariant: "activation bitwidth is an integer in 2..=8",
    },
    CodeInfo {
        code: "OQ004",
        severity: Severity::Error,
        name: "cascade-zero",
        invariant: "cascade factor is an integer >= 1 (adjacent-only RO is cascade 1)",
    },
    CodeInfo {
        code: "OQ005",
        severity: Severity::Error,
        name: "cascade-no-ro",
        invariant: "cascade > 1 requires range overwrite (cascading is an RO \
                    rescale-unit feature; per overq::state it has no effect without RO)",
    },
    CodeInfo {
        code: "OQ006",
        severity: Severity::Error,
        name: "scale",
        invariant: "activation scale is finite and > 0",
    },
    CodeInfo {
        code: "OQ007",
        severity: Severity::Error,
        name: "wbits",
        invariant: "weight bitwidth is 0 (prepared 8-bit default) or 2..=8 \
                    (the engine's MMSE requant cache range)",
    },
    CodeInfo {
        code: "OQ008",
        severity: Severity::Warn,
        name: "area-drift",
        invariant: "declared per-layer PE area and total_area match the \
                    Table-3 model (area::pe_area_w, MAC-weighted mean)",
    },
    CodeInfo {
        code: "OQ009",
        severity: Severity::Warn,
        name: "evidence",
        invariant: "evidence statistics (p0, outlier_rate, coverages, probe \
                    accuracies) lie in [0,1] and the probe split is non-empty",
    },
    CodeInfo {
        code: "OQ010",
        severity: Severity::Warn,
        name: "schema-v1",
        invariant: "plan file uses the current schema version (v1 still loads; \
                    re-save to stamp v2)",
    },
    CodeInfo {
        code: "OQ011",
        severity: Severity::Error,
        name: "enc-missing",
        invariant: "every enc point of the model graph is configured by the plan",
    },
    CodeInfo {
        code: "OQ012",
        severity: Severity::Error,
        name: "enc-dangling",
        invariant: "no plan layer targets an enc point beyond the model's count",
    },
    CodeInfo {
        code: "OQ013",
        severity: Severity::Warn,
        name: "macs-drift",
        invariant: "declared per-layer MACs match a static recompute over the \
                    graph (OCS-expanded input channels included, as in policy::profile)",
    },
    CodeInfo {
        code: "OQ014",
        severity: Severity::Error,
        name: "empty",
        invariant: "a plan configures at least one enc point",
    },
    CodeInfo {
        code: "OQ015",
        severity: Severity::Error,
        name: "dup-alias",
        invariant: "no two files in a watched plan directory claim the same \
                    (model, name) alias — the later apply would silently win",
    },
    CodeInfo {
        code: "OQ016",
        severity: Severity::Error,
        name: "split",
        invariant: "traffic splits have >= 1 non-nested arm with positive finite \
                    weights and no duplicate arms",
    },
    CodeInfo {
        code: "OQ017",
        severity: Severity::Warn,
        name: "control-starved",
        invariant: "every split arm keeps a non-negligible traffic share \
                    (>= 1% of the total weight)",
    },
    CodeInfo {
        code: "OQ018",
        severity: Severity::Error,
        name: "unreadable",
        invariant: "the file parses as JSON, is a plan object, and declares a \
                    supported schema version",
    },
    CodeInfo {
        code: "OQ019",
        severity: Severity::Warn,
        name: "drift-baseline",
        invariant: "every layer stores the profile-time drift baseline \
                    (mean/var/clip_rate) the live telemetry compares against; \
                    re-profile plans tuned before it existed",
    },
];

/// Look up a code's registry entry.
pub fn code_info(code: &str) -> Option<&'static CodeInfo> {
    CODES.iter().find(|c| c.code == code)
}

/// One finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable code from [`CODES`].
    pub code: &'static str,
    pub severity: Severity,
    /// What was linted: a plan name, a file path, or a split spec.
    pub subject: String,
    /// Enc-point index the finding anchors to, when layer-scoped.
    pub enc: Option<usize>,
    /// Human-readable statement of the violation.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic for `code`, taking the severity from the
    /// registry. Panics on unknown codes — rule bugs, not inputs.
    pub fn new(code: &str, subject: &str, enc: Option<usize>, message: String) -> Diagnostic {
        let info = code_info(code).unwrap_or_else(|| panic!("unknown lint code {code}"));
        Diagnostic {
            code: info.code,
            severity: info.severity,
            subject: subject.to_string(),
            enc,
            message,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}] {}", self.severity, self.code, self.subject)?;
        if let Some(e) = self.enc {
            write!(f, " enc {e}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Findings of one lint run, with the CLI/CI presentation logic.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn push(&mut self, code: &str, subject: &str, enc: Option<usize>, message: String) {
        self.diagnostics.push(Diagnostic::new(code, subject, enc, message));
    }

    /// Append another report's findings.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    pub fn warn_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// First Error-level finding — what the serving layer surfaces when
    /// it refuses a plan.
    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.errors().next()
    }

    /// True when nothing was found at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// CI exit code: 0 clean (or warnings without `deny_warn`),
    /// 1 for lint findings that gate. Operational failures (unreadable
    /// paths etc.) are reported as OQ018 errors, so they gate too.
    pub fn exit_code(&self, deny_warn: bool) -> i32 {
        if self.has_errors() || (deny_warn && self.warn_count() > 0) {
            1
        } else {
            0
        }
    }

    /// Human rendering, one line per finding plus a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "{} error(s), {} warning(s)\n",
            self.error_count(),
            self.warn_count()
        ));
        out
    }

    /// Machine rendering (`overq lint --json`).
    pub fn to_json(&self) -> Value {
        use std::collections::BTreeMap;
        let diags: Vec<Value> = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("code".to_string(), Value::Str(d.code.to_string()));
                m.insert("severity".to_string(), Value::Str(d.severity.to_string()));
                m.insert("subject".to_string(), Value::Str(d.subject.clone()));
                if let Some(e) = d.enc {
                    m.insert("enc".to_string(), Value::Num(e as f64));
                }
                m.insert("message".to_string(), Value::Str(d.message.clone()));
                Value::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("diagnostics".to_string(), Value::Arr(diags));
        m.insert("errors".to_string(), Value::Num(self.error_count() as f64));
        m.insert("warnings".to_string(), Value::Num(self.warn_count() as f64));
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_ordered() {
        for w in CODES.windows(2) {
            assert!(w[0].code < w[1].code, "{} !< {}", w[0].code, w[1].code);
        }
        assert!(code_info("OQ001").is_some());
        assert!(code_info("OQ999").is_none());
    }

    #[test]
    fn report_accounting_and_exit_codes() {
        let mut r = Report::default();
        assert_eq!(r.exit_code(true), 0);
        r.push("OQ008", "p", Some(1), "area drift".into());
        assert_eq!(r.error_count(), 0);
        assert_eq!(r.warn_count(), 1);
        assert_eq!(r.exit_code(false), 0);
        assert_eq!(r.exit_code(true), 1);
        r.push("OQ004", "p", Some(0), "cascade 0".into());
        assert!(r.has_errors());
        assert_eq!(r.exit_code(false), 1);
        assert_eq!(r.first_error().unwrap().code, "OQ004");
        let text = r.render_human();
        assert!(text.contains("error [OQ004] p enc 0"));
        assert!(text.contains("1 error(s), 1 warning(s)"));
        let json = r.to_json().to_json();
        assert!(json.contains("\"OQ008\"") && json.contains("\"OQ004\""));
    }
}
