//! The lint rules: OverQ invariants, weight-side checks, area-budget
//! conformance, model coverage, and serving-level split checks.
//!
//! Every rule reads the lenient [`PlanView`] so one malformed field
//! yields one diagnostic under its stable code instead of masking the
//! rest of the plan. Severities live in the code registry
//! ([`super::diag::CODES`]) — rules only decide *whether* a code fires.

use std::collections::HashSet;

use crate::coordinator::VariantSpec;
use crate::models::LoadedModel;
use crate::nn::conv::same_out;
use crate::nn::graph::Op;
use crate::nn::WBITS_DEFAULT;
use crate::overq::OverQConfig;
use crate::policy::pe_area_w;

use super::diag::Report;
use super::view::{as_uint, LayerView, PlanView};

/// Activation bitwidths the engine/PE model supports.
pub const ACT_BITS_RANGE: std::ops::RangeInclusive<u64> = 2..=8;

/// Weight bitwidths the engine's MMSE requant cache can prepare
/// (besides [`WBITS_DEFAULT`] = the prepared 8-bit weights).
pub const WBITS_RANGE: std::ops::RangeInclusive<u64> = 2..=8;

/// Input image dims (H, W, C) assumed for the static MAC recompute when
/// the caller has no batch to take them from — the synth-model and
/// coordinator default.
pub const DEFAULT_INPUT_DIMS: [usize; 3] = [16, 16, 3];

/// Relative tolerance for OQ008/OQ013 recompute comparisons. Plan
/// producers and the linter share the exact same formulas
/// (`policy::pe_area_w`, `DeploymentPlan::from_layers`) and JSON
/// round-trips f64 losslessly, so honest plans agree to the last bit;
/// the tolerance only absorbs cross-platform libm noise.
const RTOL: f64 = 1e-6;

fn drifted(declared: f64, expected: f64) -> bool {
    let denom = expected.abs().max(1e-12);
    !declared.is_finite() || ((declared - expected).abs() / denom) > RTOL
}

/// Plan-only rules (no model needed): OQ001..OQ010, OQ014, OQ018.
pub fn lint_view(v: &PlanView) -> Report {
    let mut r = Report::default();
    let subject = v.subject();

    // OQ018: version gate — the strict loader refuses these files, so
    // nothing downstream of lint could ever serve them
    match v.version {
        None => r.push(
            "OQ018",
            &subject,
            None,
            "plan declares no schema version".to_string(),
        ),
        Some(ver) if !v.version_supported() => r.push(
            "OQ018",
            &subject,
            None,
            format!(
                "unsupported schema version {ver} (this build reads 1..={})",
                crate::policy::PLAN_VERSION
            ),
        ),
        Some(ver) if ver == 1.0 => r.push(
            "OQ010",
            &subject,
            None,
            "schema v1 plan: loads with default weight fields, but re-save \
             to stamp the current schema"
                .to_string(),
        ),
        _ => {}
    }

    // OQ001: names must produce a servable `plan:<name>` alias
    for (field, value) in [("name", &v.name), ("model", &v.model)] {
        match value {
            None => r.push(
                "OQ001",
                &subject,
                None,
                format!("plan {field} is missing"),
            ),
            Some(s) if s.is_empty() => r.push(
                "OQ001",
                &subject,
                None,
                format!("plan {field} is empty"),
            ),
            Some(s)
                if field == "name"
                    && !s
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')) =>
            {
                r.push(
                    "OQ001",
                    &subject,
                    None,
                    format!(
                        "plan name {s:?} has characters outside [A-Za-z0-9_.-] — \
                         the `plan:{s}` variant cannot be parsed"
                    ),
                )
            }
            _ => {}
        }
    }

    // OQ014: an empty plan covers no enc point of any model
    if v.layers.is_empty() {
        r.push(
            "OQ014",
            &subject,
            None,
            "plan has no layers — it configures no enc point".to_string(),
        );
        return r;
    }

    // OQ002: enc indices dense 0..n
    let mut encs: Vec<Option<u64>> = Vec::with_capacity(v.layers.len());
    for (i, l) in v.layers.iter().enumerate() {
        let e = as_uint(l.enc);
        if e.is_none() {
            r.push(
                "OQ002",
                &subject,
                None,
                format!("layer {i}: enc index missing or not a non-negative integer"),
            );
        }
        encs.push(e);
    }
    {
        let present: Vec<u64> = encs.iter().flatten().copied().collect();
        let uniq: HashSet<u64> = present.iter().copied().collect();
        if uniq.len() < present.len() {
            r.push(
                "OQ002",
                &subject,
                None,
                "duplicate enc indices — one enc point configured twice".to_string(),
            );
        } else if present.len() == v.layers.len() {
            for want in 0..v.layers.len() as u64 {
                if !uniq.contains(&want) {
                    r.push(
                        "OQ002",
                        &subject,
                        Some(want as usize),
                        format!("enc indices not dense (missing enc {want})"),
                    );
                }
            }
        }
    }

    for (i, l) in v.layers.iter().enumerate() {
        let enc = encs[i].map(|e| e as usize);
        lint_layer(&mut r, &subject, enc.unwrap_or(i), l);
    }

    // OQ008 (total): total_area must be the MAC-weighted mean of the
    // declared layer areas (the `DeploymentPlan::from_layers` convention)
    let all_declared = v
        .layers
        .iter()
        .all(|l| l.area.is_some() && as_uint(l.macs).is_some());
    if let (Some(total), true) = (v.total_area, all_declared) {
        let total_macs: f64 = v
            .layers
            .iter()
            .map(|l| l.macs.unwrap())
            .sum::<f64>()
            .max(1.0);
        let expect: f64 = v
            .layers
            .iter()
            .map(|l| l.area.unwrap() * l.macs.unwrap() / total_macs)
            .sum();
        if drifted(total, expect) {
            r.push(
                "OQ008",
                &subject,
                None,
                format!(
                    "total_area {total} != MAC-weighted mean of layer areas {expect} \
                     — re-derive with DeploymentPlan::from_layers"
                ),
            );
        }
    }

    // OQ009: probe evidence block
    if let Some(p) = &v.probe {
        match as_uint(p.images) {
            Some(0) | None => r.push(
                "OQ009",
                &subject,
                None,
                "probe evidence with zero/invalid image count".to_string(),
            ),
            _ => {}
        }
        for (field, value) in [
            ("probe accuracy", p.accuracy),
            ("probe baseline_accuracy", p.baseline_accuracy),
        ] {
            if !matches!(value, Some(a) if (0.0..=1.0).contains(&a)) {
                r.push(
                    "OQ009",
                    &subject,
                    None,
                    format!("{field} missing or outside [0,1]: {value:?}"),
                );
            }
        }
    }

    r
}

/// Per-layer rules: OQ003..OQ009, layer-scoped OQ018.
fn lint_layer(r: &mut Report, subject: &str, enc: usize, l: &LayerView) {
    let e = Some(enc);

    let bits = as_uint(l.bits).filter(|b| ACT_BITS_RANGE.contains(b));
    if bits.is_none() {
        r.push(
            "OQ003",
            subject,
            e,
            format!(
                "activation bits {:?} outside the supported range {}..={}",
                l.bits,
                ACT_BITS_RANGE.start(),
                ACT_BITS_RANGE.end()
            ),
        );
    }

    let cascade = as_uint(l.cascade).filter(|&c| c >= 1);
    if cascade.is_none() {
        r.push(
            "OQ004",
            subject,
            e,
            format!(
                "cascade {:?} invalid — the hardware rescale unit needs an \
                 integer >= 1 (1 = adjacent-only)",
                l.cascade
            ),
        );
    }

    // missing mode flags make the plan unloadable by the strict parser
    for (field, flag) in [("ro", l.ro), ("pr", l.pr)] {
        if flag.is_none() {
            r.push(
                "OQ018",
                subject,
                e,
                format!("mode flag {field:?} missing — the plan loader refuses this file"),
            );
        }
    }
    if let (Some(c), Some(false)) = (cascade, l.ro) {
        if c > 1 {
            r.push(
                "OQ005",
                subject,
                e,
                format!(
                    "cascade {c} with range overwrite off — cascading only \
                     exists in the RO rescale unit (overq::state)"
                ),
            );
        }
    }

    if !matches!(l.scale, Some(s) if s.is_finite() && s > 0.0) {
        r.push(
            "OQ006",
            subject,
            e,
            format!("activation scale {:?} is not finite-positive", l.scale),
        );
    }

    // v1 plans omit wbits entirely (→ the default prepared weights);
    // a present value must be preparable by the MMSE requant cache
    let wbits_ok = match l.wbits {
        None => Some(WBITS_DEFAULT),
        Some(_) => match as_uint(l.wbits) {
            Some(w) if w == WBITS_DEFAULT as u64 || WBITS_RANGE.contains(&w) => Some(w as u32),
            _ => None,
        },
    };
    if wbits_ok.is_none() {
        r.push(
            "OQ007",
            subject,
            e,
            format!(
                "weight bits {:?} not preparable — the engine's MMSE requant \
                 cache serves 0 (prepared 8-bit default) or {}..={}",
                l.wbits,
                WBITS_RANGE.start(),
                WBITS_RANGE.end()
            ),
        );
    }

    // OQ008 (layer): declared area vs the Table-3 recompute; only when
    // the config fields above are valid enough to recompute from
    if let (Some(b), Some(c), Some(ro), Some(pr), Some(w)) =
        (bits, cascade, l.ro, l.pr, wbits_ok)
    {
        let cfg = OverQConfig {
            bits: b as u32,
            cascade: c as usize,
            range_overwrite: ro,
            precision_overwrite: pr,
        };
        let expect = pe_area_w(&cfg, w);
        match l.area {
            Some(a) if !drifted(a, expect) => {}
            Some(a) => r.push(
                "OQ008",
                subject,
                e,
                format!(
                    "declared PE area {a} != Table-3 model {expect} for this \
                     config (area::pe_area_w)"
                ),
            ),
            None => r.push(
                "OQ008",
                subject,
                e,
                format!("no declared PE area (Table-3 model says {expect})"),
            ),
        }
    }

    // OQ009: evidence statistics are probabilities
    for (field, value) in [
        ("p0", l.p0),
        ("outlier_rate", l.outlier_rate),
        ("theory_coverage", l.theory_coverage),
        ("measured_coverage", l.measured_coverage),
    ] {
        if let Some(x) = value {
            if !x.is_finite() || !(0.0..=1.0).contains(&x) {
                r.push(
                    "OQ009",
                    subject,
                    e,
                    format!("{field} = {x} outside [0,1]"),
                );
            }
        }
    }

    // OQ019: drift-detection needs the profile-time baseline; plans
    // tuned before the telemetry subsystem serve fine but can't be
    // watched for distribution shift until re-profiled
    if !l.has_drift {
        r.push(
            "OQ019",
            subject,
            e,
            "no drift baseline block — live mean/var/clip-rate telemetry \
             has nothing to compare against; re-run the autotuner to \
             store profile-time statistics"
                .to_string(),
        );
    }
}

/// Static per-enc-point MAC recompute over the model graph — the same
/// accounting as `policy::profile::profile_enc_points`, but from shape
/// inference instead of a real forward: conv cost at the spatial size of
/// its input tap, over the channels the hardware actually sees
/// (OCS-expanded via `Engine::conv_in_channels`). `input_dims` is the
/// (H, W, C) of one request image ([`DEFAULT_INPUT_DIMS`] for the synth
/// convention).
pub fn enc_point_macs(model: &LoadedModel, input_dims: &[usize]) -> Vec<u64> {
    let graph = &model.engine.graph;
    // (h, w, c) per node, walked in SSA order
    let mut dims: Vec<(usize, usize, usize)> = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let d = match &node.op {
            Op::Input => (input_dims[0], input_dims[1], input_dims[2]),
            Op::Conv { stride, cout, .. } => {
                let (h, w, _) = dims[node.inputs[0]];
                (same_out(h, *stride), same_out(w, *stride), *cout)
            }
            Op::Add { .. } => dims[node.inputs[0]],
            Op::Concat => {
                let (h, w, _) = dims[node.inputs[0]];
                (h, w, node.inputs.iter().map(|&i| dims[i].2).sum())
            }
            Op::MaxPool | Op::AvgPool => {
                let (h, w, c) = dims[node.inputs[0]];
                (h / 2, w / 2, c)
            }
            Op::Gap => {
                let (_, _, c) = dims[node.inputs[0]];
                (1, 1, c)
            }
            Op::Dense { cout, .. } => (1, 1, *cout),
        };
        dims.push(d);
    }
    let mut macs = vec![0u64; graph.num_enc_points()];
    for node in &graph.nodes {
        if let Op::Conv {
            kh,
            kw,
            stride,
            cin,
            cout,
            quant: true,
            enc: Some(e),
            ..
        } = &node.op
        {
            let (h, w, _) = dims[node.inputs[0]];
            let (oh, ow) = (same_out(h, *stride), same_out(w, *stride));
            let cin_eff = model.engine.conv_in_channels(node.id).unwrap_or(*cin);
            macs[*e] += (kh * kw * cin_eff * cout * oh * ow) as u64;
        }
    }
    for m in macs.iter_mut() {
        *m = (*m).max(1);
    }
    macs
}

/// Model-aware rules on top of [`lint_view`]: OQ011, OQ012, OQ013.
pub fn lint_view_with_model(
    v: &PlanView,
    model: &LoadedModel,
    input_dims: &[usize],
) -> Report {
    let mut r = lint_view(v);
    let subject = v.subject();
    let n_model = model.engine.graph.num_enc_points();

    let configured: HashSet<u64> = v.layers.iter().filter_map(|l| as_uint(l.enc)).collect();
    // OQ012: dangling layers (enc beyond the model)
    for l in &v.layers {
        if let Some(e) = as_uint(l.enc) {
            if e as usize >= n_model {
                r.push(
                    "OQ012",
                    &subject,
                    Some(e as usize),
                    format!(
                        "layer targets enc {e}, but model {:?} has only {n_model} \
                         enc point(s)",
                        model.name
                    ),
                );
            }
        }
    }
    // OQ011: model enc points the plan leaves unconfigured
    for e in 0..n_model as u64 {
        if !configured.contains(&e) {
            r.push(
                "OQ011",
                &subject,
                Some(e as usize),
                format!(
                    "model {:?} enc point {e} is not configured — \
                     `forward_quant` would refuse this plan",
                    model.name
                ),
            );
        }
    }

    // OQ013: declared MACs vs the static recompute (OCS-expanded)
    let expect = enc_point_macs(model, input_dims);
    for l in &v.layers {
        let Some(e) = as_uint(l.enc) else { continue };
        let Some(want) = expect.get(e as usize) else { continue };
        match as_uint(l.macs) {
            Some(m) if m == *want => {}
            declared => r.push(
                "OQ013",
                &subject,
                Some(e as usize),
                format!(
                    "declared MACs {declared:?} != static recompute {want} at \
                     input dims {input_dims:?} (policy::profile convention, \
                     OCS-expanded channels included)"
                ),
            ),
        }
    }

    r
}

/// Serving-level split checks: OQ016 (degenerate) / OQ017 (starved arm).
/// `subject` names the split in diagnostics (e.g. the spec string).
pub fn lint_split(spec: &VariantSpec, subject: &str) -> Report {
    let mut r = Report::default();
    let VariantSpec::Split(arms) = spec else {
        r.push(
            "OQ016",
            subject,
            None,
            format!("not a traffic split: {spec}"),
        );
        return r;
    };
    if let Err(e) = VariantSpec::validate_split(arms) {
        r.push("OQ016", subject, None, format!("{e:#}"));
        return r;
    }
    let mut seen: HashSet<String> = HashSet::new();
    for (arm, _) in arms {
        if !seen.insert(arm.key()) {
            r.push(
                "OQ016",
                subject,
                None,
                format!("duplicate split arm {arm} — reward/metrics keys would collide"),
            );
        }
    }
    let total: f64 = arms.iter().map(|(_, w)| w).sum();
    if total > 0.0 {
        for (arm, w) in arms {
            let share = w / total;
            if share < 0.01 {
                r.push(
                    "OQ017",
                    subject,
                    None,
                    format!(
                        "arm {arm} holds {:.3}% of traffic — a control/canary \
                         this starved yields no usable comparison",
                        share * 100.0
                    ),
                );
            }
        }
    }
    r
}
