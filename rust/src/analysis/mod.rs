//! Static analysis over deployment plans: the `overq lint` subsystem.
//!
//! Everything that serves goes through here. The linter statically
//! checks a [`crate::policy::DeploymentPlan`] — alone, against a loaded
//! model's `nn::graph`, or as a whole watched directory — and reports
//! findings under stable codes (`OQ001..`) with CI-friendly exit codes:
//!
//! - **enc-point coverage** — every graph enc point configured exactly
//!   once, no dangling plan layers (OQ002, OQ011, OQ012, OQ014)
//! - **OverQ invariants** — bits within the supported range, cascade
//!   only with range overwrite, PR/RO legality per `overq::state`
//!   (OQ003..OQ006)
//! - **weight-side checks** — `wbits` preparable by the engine's MMSE
//!   requant cache, MAC accounting consistent with `policy::profile`
//!   including OCS-expanded channels (OQ007, OQ013)
//! - **area-budget conformance** — `area::pe_area_w` recomputed vs
//!   declared cost, v1→v2 schema drift (OQ008, OQ010)
//! - **serving-level checks** — duplicate aliases in a plan directory,
//!   degenerate traffic splits, starved control arms (OQ015..OQ017)
//!
//! Error-level findings make a plan unservable: `register_plan`, plan
//! watching (`PlanWatch::poll`) and the autotuner's plan emission all
//! refuse them, surfacing the lint code in the returned error /
//! `last_watch_error`. Warn-level findings never block serving; the
//! `overq lint --deny-warn` CI gate is where they bite.
//!
//! A second static layer sits underneath the linter: [`absint`] runs
//! abstract interpretation over the model graph itself — intervals plus
//! a propagated Eq.(1) error bound — and certifies per-enc-point
//! activation ranges without any profile data. Its rules (OQ020–OQ025,
//! the `overq verify` subcommand) share this module's diagnostics
//! framework, codes, and exit-code contract.

pub mod absint;
pub mod diag;
pub mod rules;
pub mod view;

use std::path::Path;

pub use absint::{
    verify_plan, AbsintConfig, Certification, EncCertificate, GraphBounds, Interval, StaticRange,
    DEFAULT_INPUT_RANGE,
};
pub use diag::{code_info, CodeInfo, Diagnostic, Report, Severity, CODES};
pub use rules::{enc_point_macs, lint_split, DEFAULT_INPUT_DIMS};
pub use view::PlanView;

use crate::coordinator::VariantSpec;
use crate::models::LoadedModel;
use crate::policy::DeploymentPlan;
use crate::util::json;

/// Lint an in-memory plan without a model (the `register_plan` path).
pub fn lint_plan(plan: &DeploymentPlan) -> Report {
    rules::lint_view(&PlanView::from_plan(plan))
}

/// Lint an in-memory plan against a loaded model. `input_dims` is one
/// request image's (H, W, C) for the static MAC recompute
/// ([`DEFAULT_INPUT_DIMS`] when unknown).
pub fn lint_plan_with_model(
    plan: &DeploymentPlan,
    model: &LoadedModel,
    input_dims: &[usize],
) -> Report {
    rules::lint_view_with_model(&PlanView::from_plan(plan), model, input_dims)
}

/// Lint a parsed JSON document leniently (reads past violations the
/// strict loader refuses, so each lands under its own code).
pub fn lint_value(v: &json::Value, subject: &str, model: Option<&LoadedModel>) -> Report {
    match PlanView::from_value(v) {
        Ok(view) => {
            let mut view = view;
            if view.name.is_none() {
                // anchor diagnostics to the file when the plan is anonymous
                view.name = Some(subject.to_string());
            }
            match model {
                Some(m) => rules::lint_view_with_model(&view, m, &DEFAULT_INPUT_DIMS),
                None => rules::lint_view(&view),
            }
        }
        Err(e) => {
            let mut r = Report::default();
            r.push("OQ018", subject, None, e);
            r
        }
    }
}

/// Lint one plan file. Unreadable / unparseable files become OQ018.
pub fn lint_file(path: &Path, model: Option<&LoadedModel>) -> Report {
    let subject = path.display().to_string();
    match json::parse_file(path) {
        Ok(v) => lint_value(&v, &subject, model),
        Err(e) => {
            let mut r = Report::default();
            r.push("OQ018", &subject, None, format!("{e:#}"));
            r
        }
    }
}

/// Lint every `*.json` plan in a watched directory, plus the
/// directory-level OQ015 duplicate-alias check: two files claiming the
/// same (model, name) alias would race for the same serving slot, the
/// later apply silently winning.
pub fn lint_dir(dir: &Path, model: Option<&LoadedModel>) -> Report {
    let mut r = Report::default();
    let subject = dir.display().to_string();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            r.push("OQ018", &subject, None, format!("unreadable directory: {e}"));
            return r;
        }
    };
    let mut files: Vec<std::path::PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
        .collect();
    files.sort();
    let mut aliases: std::collections::HashMap<(String, String), String> =
        std::collections::HashMap::new();
    for path in &files {
        r.merge(lint_file(path, model));
        if let Ok(v) = json::parse_file(path) {
            if let Ok(view) = PlanView::from_value(&v) {
                if let (Some(m), Some(n)) = (view.model, view.name) {
                    let here = path.display().to_string();
                    if let Some(prev) = aliases.insert((m.clone(), n.clone()), here.clone()) {
                        r.push(
                            "OQ015",
                            &here,
                            None,
                            format!(
                                "duplicate alias plan:{n} for model {m:?} — also \
                                 claimed by {prev}; the later poll apply silently wins"
                            ),
                        );
                    }
                }
            }
        }
    }
    if files.is_empty() {
        r.push(
            "OQ018",
            &subject,
            None,
            "no *.json plan files found".to_string(),
        );
    }
    r
}

/// Lint a traffic-split spec string (e.g.
/// `split:plan:a@0.9,fp32@0.1`). Parse failures land under OQ016.
pub fn lint_split_text(spec: &str) -> Report {
    match VariantSpec::parse(spec) {
        Ok(v) => rules::lint_split(&v, spec),
        Err(e) => {
            let mut r = Report::default();
            r.push("OQ016", spec, None, format!("{e:#}"));
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{DeploymentPlan, PlanLayer};

    fn valid_plan(n: usize) -> DeploymentPlan {
        let layers: Vec<PlanLayer> = (0..n)
            .map(|e| {
                let overq = crate::overq::OverQConfig::full(4, 1);
                PlanLayer {
                    enc: e,
                    overq,
                    scale: 0.05,
                    wbits: 0,
                    p0: 0.9,
                    outlier_rate: 0.05,
                    theory_coverage: 0.99,
                    measured_coverage: 0.98,
                    area: crate::policy::pe_area_w(&crate::overq::OverQConfig::full(4, 1), 0),
                    macs: 1000,
                    drift: Some(crate::obs::counters::DriftBaseline {
                        mean: 0.1,
                        var: 0.04,
                        clip_rate: 0.05,
                    }),
                }
            })
            .collect();
        let base = crate::policy::pe_area_w(&crate::overq::OverQConfig::baseline(8), 0);
        DeploymentPlan::from_layers("t", "synth2", layers, base, 1.0)
    }

    #[test]
    fn valid_plan_is_clean() {
        let r = lint_plan(&valid_plan(2));
        assert!(r.is_clean(), "{}", r.render_human());
    }

    #[test]
    fn each_broken_field_fires_its_code() {
        let mut p = valid_plan(2);
        p.layers[1].enc = 0; // duplicate
        assert_eq!(lint_plan(&p).first_error().unwrap().code, "OQ002");

        let mut p = valid_plan(1);
        p.layers[0].overq.bits = 9;
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ003"));

        let mut p = valid_plan(1);
        p.layers[0].overq.cascade = 0;
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ004"));

        let mut p = valid_plan(1);
        p.layers[0].overq.cascade = 2;
        p.layers[0].overq.range_overwrite = false;
        // area changes with config, so OQ008 fires too; OQ005 must be there
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ005"));

        let mut p = valid_plan(1);
        p.layers[0].scale = -1.0;
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ006"));

        let mut p = valid_plan(1);
        p.layers[0].wbits = 1;
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ007"));

        let mut p = valid_plan(1);
        p.layers[0].area *= 2.0;
        let r = lint_plan(&p);
        assert!(!r.has_errors());
        assert!(r.diagnostics.iter().any(|d| d.code == "OQ008"));

        let mut p = valid_plan(1);
        p.layers[0].p0 = 1.5;
        let r = lint_plan(&p);
        assert!(!r.has_errors());
        assert!(r.diagnostics.iter().any(|d| d.code == "OQ009"));

        let mut p = valid_plan(1);
        p.layers[0].drift = None;
        let r = lint_plan(&p);
        assert!(!r.has_errors(), "missing drift baseline must not gate serving");
        assert!(r.diagnostics.iter().any(|d| d.code == "OQ019"));

        let mut p = valid_plan(1);
        p.name = "bad name!".into();
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ001"));

        let p = valid_plan(0);
        assert!(lint_plan(&p).errors().any(|d| d.code == "OQ014"));
    }

    #[test]
    fn split_lint() {
        assert!(lint_split_text("split:plan:a@0.9,fp32@0.1").is_clean());
        // duplicate arm
        let r = lint_split_text("split:fp32@0.5,fp32@0.5");
        assert!(r.errors().any(|d| d.code == "OQ016"));
        // starved control
        let r = lint_split_text("split:plan:a@0.999,fp32@0.001");
        assert!(!r.has_errors());
        assert!(r.diagnostics.iter().any(|d| d.code == "OQ017"));
        // not a split at all
        assert!(lint_split_text("fp32").errors().any(|d| d.code == "OQ016"));
    }
}
