//! Exact log-bucketed histograms — the percentile substrate for
//! serving metrics.
//!
//! [`Hist`] replaces the capped reservoir that used to live inside
//! [`crate::util::stats::Summary`]: a reservoir under-weights the tail
//! once it caps (a p99 over 4096 retained samples of a million-sample
//! stream is a p99 of the *reservoir*, not the stream), while a
//! log-bucketed histogram is exact within its bucket for every sample
//! ever added, at constant memory per occupied bucket.
//!
//! Buckets grow geometrically with [`SUB_BUCKETS`] sub-buckets per
//! octave (factor `2^(1/8)` ≈ 1.09), so any reported percentile is
//! within ~4.4% of the true sample value — and the representative
//! value is clamped into the observed `[min, max]`, so extreme ranks
//! (p0, p100) are exact. Histograms from different shards [`Hist::merge`]
//! losslessly: the bucket lattice is global (anchored at 1.0), not
//! per-instance.

/// Sub-buckets per octave (power of two). 8 gives a worst-case
/// relative error of `2^(1/16) - 1` ≈ 4.4% at the geometric midpoint.
pub const SUB_BUCKETS: u32 = 8;

/// Lattice indices are clamped to this many sub-buckets on either side
/// of 1.0 (covers `2^-64 .. 2^64` — far beyond any latency in µs).
const MAX_IDX: i64 = 64 * SUB_BUCKETS as i64;

/// Lattice bucket index of a positive value: bucket `i` covers
/// `[2^(i/8), 2^((i+1)/8))`.
#[inline]
fn lattice_idx(v: f64) -> i64 {
    let i = (v.log2() * SUB_BUCKETS as f64).floor() as i64;
    i.clamp(-MAX_IDX, MAX_IDX)
}

/// Geometric midpoint of lattice bucket `i` (the representative value
/// reported for ranks that land in it).
#[inline]
fn lattice_mid(i: i64) -> f64 {
    ((i as f64 + 0.5) / SUB_BUCKETS as f64).exp2()
}

/// Upper bound of lattice bucket `i` (exclusive; the Prometheus `le`).
#[inline]
fn lattice_upper(i: i64) -> f64 {
    ((i as f64 + 1.0) / SUB_BUCKETS as f64).exp2()
}

/// An exact log-bucketed histogram over non-negative samples.
///
/// Exact count/sum/min/max; percentiles are nearest-rank over the
/// bucket counts, reported at the bucket's geometric midpoint clamped
/// into `[min, max]`. Values `<= 0` (and non-finite values) land in a
/// dedicated zero bucket whose representative is 0.
#[derive(Clone, Debug, Default)]
pub struct Hist {
    /// Lattice index of `counts[0]`.
    base: i64,
    counts: Vec<u64>,
    /// Values `<= 0` or non-finite.
    zeros: u64,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Add one sample.
    pub fn add(&mut self, v: f64) {
        self.n += 1;
        if v.is_finite() {
            self.sum += v;
            if self.n == 1 {
                self.min = v;
                self.max = v;
            } else {
                if v < self.min {
                    self.min = v;
                }
                if v > self.max {
                    self.max = v;
                }
            }
        }
        if !(v > 0.0 && v.is_finite()) {
            self.zeros += 1;
            return;
        }
        let idx = lattice_idx(v);
        if self.counts.is_empty() {
            self.base = idx;
            self.counts.push(1);
            return;
        }
        if idx < self.base {
            let pad = (self.base - idx) as usize;
            let mut grown = vec![0u64; pad + self.counts.len()];
            grown[pad..].copy_from_slice(&self.counts);
            self.counts = grown;
            self.base = idx;
        } else if (idx - self.base) as usize >= self.counts.len() {
            self.counts.resize((idx - self.base) as usize + 1, 0);
        }
        self.counts[(idx - self.base) as usize] += 1;
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of all (finite) samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest sample seen (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample seen (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Mean of all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Nearest-rank percentile (`p` in 0..=100), reported at the
    /// owning bucket's geometric midpoint clamped into `[min, max]`.
    /// 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * (self.n - 1) as f64).round() as u64;
        if rank < self.zeros {
            return self.clamp_rep(0.0);
        }
        let mut cum = self.zeros;
        for (k, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank < cum {
                return self.clamp_rep(lattice_mid(self.base + k as i64));
            }
        }
        self.max
    }

    #[inline]
    fn clamp_rep(&self, rep: f64) -> f64 {
        rep.clamp(self.min, self.max)
    }

    /// Merge another histogram into this one. Lossless: both share the
    /// global bucket lattice.
    pub fn merge(&mut self, other: &Hist) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.sum += other.sum;
        self.n += other.n;
        self.zeros += other.zeros;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (k, &c) in other.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let idx = other.base + k as i64;
            if self.counts.is_empty() {
                self.base = idx;
                self.counts.push(c);
                continue;
            }
            if idx < self.base {
                let pad = (self.base - idx) as usize;
                let mut grown = vec![0u64; pad + self.counts.len()];
                grown[pad..].copy_from_slice(&self.counts);
                self.counts = grown;
                self.base = idx;
            } else if (idx - self.base) as usize >= self.counts.len() {
                self.counts.resize((idx - self.base) as usize + 1, 0);
            }
            self.counts[(idx - self.base) as usize] += c;
        }
    }

    /// Occupied buckets as `(upper_bound, count)` pairs in increasing
    /// bound order — the zero bucket (bound 0) first when occupied.
    /// This is the non-cumulative form; exporters accumulate for the
    /// Prometheus `le` convention.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        if self.zeros > 0 {
            out.push((0.0, self.zeros));
        }
        for (k, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                out.push((lattice_upper(self.base + k as i64), c));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn empty_is_zeroes() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn uniform_stream_percentiles_land_in_bucket() {
        let mut h = Hist::new();
        for v in 1..=100 {
            h.add(v as f64);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 100.0);
        // exact-within-bucket: the true p50 of 1..=100 is 50/51; the
        // owning bucket's midpoint is within the 2^(1/16) error bound
        let p50 = h.percentile(50.0);
        assert!((49.0..=53.0).contains(&p50), "p50={p50}");
        let p95 = h.percentile(95.0);
        assert!((91.0..=99.0).contains(&p95), "p95={p95}");
        // rank 100 falls in the top bucket, clamped to the exact max
        assert_eq!(h.percentile(100.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0);
    }

    #[test]
    fn zero_and_subunit_values() {
        let mut h = Hist::new();
        h.add(0.0);
        h.add(0.0);
        h.add(0.25);
        h.add(4.0);
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(100.0), 4.0);
        // rank 1 (of 0..=3) is still a zero
        assert_eq!(h.percentile(34.0), 0.0);
    }

    #[test]
    fn buckets_cover_every_sample() {
        let mut h = Hist::new();
        for v in [0.0, 0.5, 1.0, 3.0, 3.1, 1000.0] {
            h.add(v);
        }
        let total: u64 = h.buckets().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, h.count());
        // bounds strictly increase
        let bounds: Vec<f64> = h.buckets().iter().map(|&(b, _)| b).collect();
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "{bounds:?}");
    }

    #[test]
    fn prop_merge_equals_single_stream_and_percentile_bounded() {
        check("hist merge/percentile", 200, |rng: &mut Rng| {
            let n = 1 + rng.index(400);
            let mut all = Vec::with_capacity(n);
            let (mut a, mut b, mut whole) = (Hist::new(), Hist::new(), Hist::new());
            for i in 0..n {
                // spread over ~6 orders of magnitude plus exact zeros
                let v = if rng.bool(0.1) {
                    0.0
                } else {
                    rng.f64() * 10f64.powi(rng.index(6) as i32)
                };
                all.push(v);
                whole.add(v);
                if i % 2 == 0 {
                    a.add(v);
                } else {
                    b.add(v);
                }
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count());
            assert_eq!(a.min(), whole.min());
            assert_eq!(a.max(), whole.max());
            assert!((a.sum() - whole.sum()).abs() <= 1e-9 * whole.sum().abs().max(1.0));

            all.sort_by(|x, y| x.partial_cmp(y).unwrap());
            for &p in &[0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                let got_merged = a.percentile(p);
                let got_whole = whole.percentile(p);
                // merged and single-stream histograms agree exactly
                assert_eq!(got_merged, got_whole, "p{p} merged vs whole");
                // exact-within-bucket: within one bucket growth factor
                // of the true nearest-rank sample
                let rank = ((p / 100.0) * (n - 1) as f64).round() as usize;
                let truth = all[rank];
                if truth <= 0.0 {
                    assert_eq!(got_whole, 0.0, "p{p} of zero sample");
                } else {
                    let ratio = got_whole / truth;
                    let tol = 2f64.powf(1.0 / SUB_BUCKETS as f64) + 1e-12;
                    assert!(
                        (1.0 / tol..=tol).contains(&ratio),
                        "p{p}: got {got_whole}, true {truth}, ratio {ratio}"
                    );
                }
            }
        });
    }
}
