//! OverQ-native serving counters: live outlier coverage, cascade
//! depths, zero availability and activation-drift statistics,
//! aggregated per (variant, enc point).
//!
//! The paper's headline claim — "with modest cascading we handle over
//! 90% of outliers" — is checked offline by `overq::coverage`; these
//! counters make the same quantities observable on *live traffic*, per
//! deployed plan. The engine cannot see the serving layer (and its
//! signatures must not grow a metrics parameter), so the worker pins a
//! [`VariantObs`] handle to its thread with [`set_ctx`] around each
//! batch; [`record`] then merges encode-level samples into it (and is
//! a no-op on any thread without a context — offline autotuning and
//! accuracy loops pay one thread-local read, nothing else).
//!
//! A [`Registry`] is owned per model shard by the coordinator, so
//! counters never leak between coordinators (or between tests). It is
//! lock-sharded by variant key; per-variant state is behind its own
//! mutex, so two workers serving different variants never contend.
//!
//! Drift: each enc point keeps a running mean/variance (Welford) of the
//! raw pre-quantization activations plus the live clip rate
//! (outliers / values). A deployment plan tuned after this subsystem
//! landed stores the matching profile-time numbers per layer
//! ([`DriftBaseline`], `drift` block in the plan JSON; lint OQ019 nudges
//! plans that lack it) — the exporter reports both sides, which is the
//! trigger signal for ROADMAP item 5's retune daemon.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::util::sync::{lock, Arc, Mutex};

/// Number of mutex shards in a [`Registry`].
const SHARDS: usize = 8;

/// Cascade-depth histogram buckets: depth `d` (1 = adjacent zero) is
/// counted at index `min(d, CASCADE_BUCKETS) - 1`.
pub const CASCADE_BUCKETS: usize = 16;

/// Profile-time activation statistics stored in a deployment plan
/// (per layer) for drift detection against the live counters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftBaseline {
    /// Mean of the raw (pre-quantization) activations at profile time.
    pub mean: f64,
    /// Variance of the raw activations at profile time.
    pub var: f64,
    /// Fraction of values whose integer code exceeded `qmax` (the
    /// plan's `outlier_rate` at its chosen scale).
    pub clip_rate: f64,
}

/// One batch worth of encode-level observations at one enc point —
/// built by the engine from the raw tensor and the encoder's state
/// lane, then merged into the registry via [`record`].
#[derive(Clone, Copy, Debug, Default)]
pub struct EncSample {
    /// Activation slots seen.
    pub values: u64,
    /// Exact-zero slots (the overwrite opportunity supply).
    pub zeros: u64,
    /// Slots whose integer code exceeded `qmax` (outliers seen).
    pub outliers: u64,
    /// Outliers whose MSBs landed in a claimed zero (range overwrite).
    pub covered_ro: u64,
    /// In-range values that parked extra LSBs in a neighboring zero
    /// (precision overwrite).
    pub covered_pr: u64,
    /// Outliers clamped to `qmax` (no zero inside the cascade window).
    pub dropped: u64,
    /// Cascade-depth histogram of the covered outliers.
    pub cascade: [u64; CASCADE_BUCKETS],
    /// Welford state over the raw activations: count, mean, M2.
    pub act_n: u64,
    /// Mean of the raw activations in this sample.
    pub act_mean: f64,
    /// Sum of squared deviations (M2) in this sample.
    pub act_m2: f64,
}

/// Running totals for one enc point of one variant.
#[derive(Clone, Debug, Default)]
pub struct EncObs {
    /// Encode-level totals (see [`EncSample`] for field meanings).
    pub sample: EncSample,
    /// MAC-lane slot occupancy from the overwrite GEMM:
    /// `[NORM, MSB, SHIFT, LSB]` counts over the im2col'd state lane.
    pub mac_slots: [u64; 4],
}

impl EncObs {
    fn merge_sample(&mut self, s: &EncSample) {
        let t = &mut self.sample;
        t.values += s.values;
        t.zeros += s.zeros;
        t.outliers += s.outliers;
        t.covered_ro += s.covered_ro;
        t.covered_pr += s.covered_pr;
        t.dropped += s.dropped;
        for (a, b) in t.cascade.iter_mut().zip(&s.cascade) {
            *a += b;
        }
        // Chan et al. parallel Welford merge
        if s.act_n > 0 {
            let (na, nb) = (t.act_n as f64, s.act_n as f64);
            let delta = s.act_mean - t.act_mean;
            let n = na + nb;
            t.act_mean += delta * nb / n;
            t.act_m2 += s.act_m2 + delta * delta * na * nb / n;
            t.act_n += s.act_n;
        }
    }
}

/// Live counters for every enc point of one served variant.
#[derive(Clone, Debug, Default)]
pub struct VariantObs {
    /// Indexed by enc-point id (grown on first touch).
    pub enc: Vec<EncObs>,
}

impl VariantObs {
    fn at(&mut self, enc: usize) -> &mut EncObs {
        if enc >= self.enc.len() {
            self.enc.resize(enc + 1, EncObs::default());
        }
        &mut self.enc[enc]
    }
}

/// Point-in-time view of one enc point (see [`Registry::snapshot`]).
#[derive(Clone, Debug)]
pub struct EncSnapshot {
    /// Enc-point id.
    pub enc: usize,
    /// Encode-level totals.
    pub totals: EncSample,
    /// Live outlier coverage: `covered_ro / outliers` (1 when no
    /// outliers were seen — nothing needed covering).
    pub coverage: f64,
    /// Exact-zero fraction of all slots (the overwrite supply).
    pub zero_availability: f64,
    /// Occupied cascade-depth buckets as `(depth, count)`.
    pub cascade: Vec<(usize, u64)>,
    /// MAC-lane slot occupancy `[NORM, MSB, SHIFT, LSB]`.
    pub mac_slots: [u64; 4],
    /// Live mean of the raw activations.
    pub act_mean: f64,
    /// Live variance of the raw activations.
    pub act_var: f64,
    /// Live clip rate (`outliers / values`).
    pub clip_rate: f64,
    /// Profile-time baseline from the plan's `drift` block, if stored.
    pub baseline: Option<DriftBaseline>,
}

/// Point-in-time view of one variant's counters.
#[derive(Clone, Debug)]
pub struct VariantObsSnapshot {
    /// Variant key (matches the per-variant serving metrics).
    pub variant: String,
    /// Aggregate outlier coverage across enc points
    /// (`Σ covered_ro / Σ outliers`; 1 when no outliers were seen).
    pub coverage: f64,
    /// Total outliers seen across enc points.
    pub outliers: u64,
    /// Total outliers covered via range overwrite.
    pub covered_ro: u64,
    /// Total precision-overwrite LSB parks.
    pub covered_pr: u64,
    /// Total outliers clamped.
    pub dropped: u64,
    /// Aggregate zero availability across enc points.
    pub zero_availability: f64,
    /// Per-enc-point detail, in enc order.
    pub enc: Vec<EncSnapshot>,
}

/// Per-shard counter registry: variant key → live counters, plus the
/// drift baselines installed with each plan.
#[derive(Default)]
pub struct Registry {
    shards: Vec<Mutex<HashMap<String, Arc<Mutex<VariantObs>>>>>,
    baselines: Mutex<HashMap<String, Vec<Option<DriftBaseline>>>>,
}

fn shard_of(key: &str) -> usize {
    // FNV-1a over the key, folded into the shard count
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) % SHARDS
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            baselines: Mutex::new(HashMap::new()),
        })
    }

    /// The live-counter handle for `variant`, created on first use.
    /// The handle is what workers pin to their thread ([`set_ctx`]).
    pub fn variant(&self, key: &str) -> Arc<Mutex<VariantObs>> {
        let mut shard = lock(&self.shards[shard_of(key)]);
        shard
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(VariantObs::default())))
            .clone()
    }

    /// Install per-enc drift baselines for `variant` (what
    /// `register_plan`/`swap_plan` do with a plan's `drift` blocks).
    /// Baselines are configuration, not counters: they survive
    /// [`Registry::reset`].
    pub fn set_baselines(&self, variant: &str, per_enc: Vec<Option<DriftBaseline>>) {
        lock(&self.baselines).insert(variant.to_string(), per_enc);
    }

    /// Zero every counter; keep installed drift baselines.
    pub fn reset(&self) {
        for s in &self.shards {
            // handles may be pinned by worker threads — zero in place
            for v in lock(s).values() {
                lock(v).enc.clear();
            }
        }
    }

    /// Snapshot every variant's counters, sorted by variant key.
    pub fn snapshot(&self) -> Vec<VariantObsSnapshot> {
        let baselines = lock(&self.baselines);
        let mut out = Vec::new();
        for s in &self.shards {
            for (key, v) in lock(s).iter() {
                let v = lock(v);
                let base = baselines.get(key);
                let mut enc_snaps = Vec::with_capacity(v.enc.len());
                let (mut outliers, mut ro, mut pr, mut dropped) = (0u64, 0u64, 0u64, 0u64);
                let (mut values, mut zeros) = (0u64, 0u64);
                for (i, e) in v.enc.iter().enumerate() {
                    let t = e.sample;
                    outliers += t.outliers;
                    ro += t.covered_ro;
                    pr += t.covered_pr;
                    dropped += t.dropped;
                    values += t.values;
                    zeros += t.zeros;
                    enc_snaps.push(EncSnapshot {
                        enc: i,
                        totals: t,
                        coverage: ratio_or_one(t.covered_ro, t.outliers),
                        zero_availability: ratio_or_zero(t.zeros, t.values),
                        cascade: t
                            .cascade
                            .iter()
                            .enumerate()
                            .filter(|&(_, &c)| c > 0)
                            .map(|(d, &c)| (d + 1, c))
                            .collect(),
                        mac_slots: e.mac_slots,
                        act_mean: t.act_mean,
                        act_var: if t.act_n > 1 {
                            t.act_m2 / (t.act_n - 1) as f64
                        } else {
                            0.0
                        },
                        clip_rate: ratio_or_zero(t.outliers, t.values),
                        baseline: base.and_then(|b| b.get(i).copied().flatten()),
                    });
                }
                out.push(VariantObsSnapshot {
                    variant: key.clone(),
                    coverage: ratio_or_one(ro, outliers),
                    outliers,
                    covered_ro: ro,
                    covered_pr: pr,
                    dropped,
                    zero_availability: ratio_or_zero(zeros, values),
                    enc: enc_snaps,
                });
            }
        }
        out.sort_by(|a, b| a.variant.cmp(&b.variant));
        out
    }
}

fn ratio_or_one(num: u64, den: u64) -> f64 {
    if den == 0 {
        1.0
    } else {
        num as f64 / den as f64
    }
}

fn ratio_or_zero(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

thread_local! {
    static CTX: RefCell<Option<Arc<Mutex<VariantObs>>>> = const { RefCell::new(None) };
}

/// Pin `obs` as this thread's counter sink for the guard's lifetime.
/// The worker wraps each batch execution in one of these; everything
/// the engine [`record`]s in between lands on the right variant.
pub fn set_ctx(obs: Arc<Mutex<VariantObs>>) -> CtxGuard {
    let prev = CTX.with(|c| c.replace(Some(obs)));
    CtxGuard { prev }
}

/// Guard from [`set_ctx`]; restores the previous context on drop.
pub struct CtxGuard {
    prev: Option<Arc<Mutex<VariantObs>>>,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CTX.with(|c| *c.borrow_mut() = prev);
    }
}

/// Is a counter context pinned to this thread? The engine checks this
/// before doing any observation work, so offline paths (autotune
/// probes, accuracy sweeps, tests) skip the scan entirely.
#[inline]
pub fn active() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Merge one encode-level sample into the pinned variant's counters.
/// No-op without a pinned context.
pub fn record(enc: usize, sample: &EncSample) {
    CTX.with(|c| {
        if let Some(obs) = &*c.borrow() {
            lock(obs).at(enc).merge_sample(sample);
        }
    });
}

/// Add MAC-lane slot occupancy (`[NORM, MSB, SHIFT, LSB]`) for one enc
/// point. No-op without a pinned context.
pub fn record_mac_slots(enc: usize, slots: [u64; 4]) {
    CTX.with(|c| {
        if let Some(obs) = &*c.borrow() {
            let mut v = lock(obs);
            let dst = &mut v.at(enc).mac_slots;
            for (a, b) in dst.iter_mut().zip(&slots) {
                *a += b;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_is_inert_without_ctx() {
        assert!(!active());
        record(0, &EncSample::default()); // must not panic or allocate state anywhere visible
    }

    #[test]
    fn ctx_routes_samples_and_reset_keeps_baselines() {
        let reg = Registry::new();
        reg.set_baselines(
            "plan:p",
            vec![Some(DriftBaseline {
                mean: 1.0,
                var: 2.0,
                clip_rate: 0.01,
            })],
        );
        {
            let _g = set_ctx(reg.variant("plan:p"));
            assert!(active());
            let mut s = EncSample {
                values: 100,
                zeros: 40,
                outliers: 10,
                covered_ro: 9,
                covered_pr: 5,
                dropped: 1,
                act_n: 100,
                act_mean: 0.5,
                act_m2: 25.0,
                ..EncSample::default()
            };
            s.cascade[0] = 6;
            s.cascade[2] = 3;
            record(0, &s);
            record(0, &s);
            record_mac_slots(0, [90, 9, 3, 5]);
        }
        assert!(!active());

        let snaps = reg.snapshot();
        assert_eq!(snaps.len(), 1);
        let v = &snaps[0];
        assert_eq!(v.variant, "plan:p");
        assert_eq!(v.outliers, 20);
        assert_eq!(v.covered_ro, 18);
        assert!((v.coverage - 0.9).abs() < 1e-12);
        let e = &v.enc[0];
        assert_eq!(e.totals.values, 200);
        assert!((e.zero_availability - 0.4).abs() < 1e-12);
        assert_eq!(e.cascade, vec![(1, 12), (3, 6)]);
        assert_eq!(e.mac_slots, [90, 9, 3, 5]);
        // two identical Welford halves merge to the same mean
        assert!((e.act_mean - 0.5).abs() < 1e-12);
        assert_eq!(e.baseline.unwrap().clip_rate, 0.01);

        reg.reset();
        let snaps = reg.snapshot();
        assert_eq!(snaps[0].outliers, 0, "counters must zero");
        // baselines survive reset (they are plan config, not traffic)
        assert!(lock(&reg.baselines).contains_key("plan:p"));
    }

    #[test]
    fn no_outliers_means_full_coverage() {
        let reg = Registry::new();
        {
            let _g = set_ctx(reg.variant("fp32"));
            record(
                0,
                &EncSample {
                    values: 10,
                    ..EncSample::default()
                },
            );
        }
        assert_eq!(reg.snapshot()[0].coverage, 1.0);
    }
}
