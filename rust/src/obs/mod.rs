//! Telemetry subsystem: structured tracing, OverQ-native serving
//! counters, and exact log-bucketed histograms.
//!
//! Three dependency-free pieces, each usable on its own:
//!
//! * [`span`] — lightweight request tracing. The coordinator owns a
//!   [`span::Ring`] per model shard; the serving path records
//!   `queue → route → batch → execute → execute.layer → encode/decode`
//!   stage spans into it, exportable as JSONL (`overq trace`,
//!   `ModelHandle::drain_events`).
//! * [`counters`] — per-(variant, enc point) live outlier coverage,
//!   cascade-depth histograms, zero availability and activation-drift
//!   statistics, emitted from the engine's quantized forward pass and
//!   compared against the profile-time [`counters::DriftBaseline`]
//!   stored in each deployment plan.
//! * [`hist`] — the exact log-bucketed [`hist::Hist`] backing every
//!   latency percentile in [`crate::util::stats::Summary`] and the
//!   Prometheus histogram exposition.
//!
//! The exporters live with the data they export:
//! `coordinator::metrics::MetricsSnapshot::render_prometheus` renders
//! the Prometheus text format, `overq serve --telemetry-addr` serves
//! it. docs/observability.md catalogs the metric names and the span
//! taxonomy.

pub mod counters;
pub mod hist;
pub mod span;
