//! Dependency-free structured tracing: spans into a lock-sharded
//! bounded ring buffer.
//!
//! A [`Ring`] is owned by whoever wants a trace (the coordinator keeps
//! one per model shard); [`Ring::span`] hands out an RAII [`Span`] that
//! measures wall time between creation and drop and records one
//! [`Event`] — but only while the ring is enabled, so an idle ring
//! costs one relaxed atomic load per span site. Events land in one of
//! a few mutex-sharded bounded deques (shard picked by thread, so
//! worker threads never contend); when a shard is full the oldest
//! event is dropped and counted, never blocking the request path.
//!
//! The serving path names its stages `queue`, `route`, `batch`,
//! `execute`, `execute.layer`, `encode` and `decode`
//! (docs/observability.md has the full taxonomy). Deep code like
//! [`crate::nn::Engine::forward_quant`] can't see the shard's ring, so
//! the worker pins it to the thread with [`set_sink`]; [`here`] then
//! records into whatever ring is pinned (or does nothing).
//!
//! # Example
//!
//! ```
//! use overq::obs::span::Ring;
//!
//! let ring = Ring::new(256);
//! ring.set_enabled(true);
//! {
//!     let _span = ring.span("execute", "variant=fp32");
//!     // ... the traced stage runs here ...
//! }
//! let events = ring.drain();
//! assert_eq!(events.len(), 1);
//! assert_eq!(events[0].name, "execute");
//! println!("{}", events[0].to_jsonl());
//! ```

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Value;
use crate::util::sync::{lock, Arc, Mutex};

/// Number of mutex shards in a ring. Power of two; small, because a
/// shard is only contended when two threads hash onto it.
const SHARDS: usize = 8;

/// One recorded span.
#[derive(Clone, Debug)]
pub struct Event {
    /// Microseconds since the ring was created (monotonic).
    pub ts_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
    /// Stage name (`queue`, `batch`, `execute`, `execute.layer`, ...).
    pub name: String,
    /// Free-form context: variant, enc point, batch size.
    pub detail: String,
}

impl Event {
    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        Value::Obj(
            [
                ("ts_us".to_string(), Value::Num(self.ts_us as f64)),
                ("dur_us".to_string(), Value::Num(self.dur_us as f64)),
                ("name".to_string(), Value::Str(self.name.clone())),
                ("detail".to_string(), Value::Str(self.detail.clone())),
            ]
            .into_iter()
            .collect(),
        )
        .to_json()
    }
}

/// Render a batch of events as JSONL (one event per line).
pub fn events_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_jsonl());
        out.push('\n');
    }
    out
}

/// A lock-sharded bounded ring buffer of trace [`Event`]s.
pub struct Ring {
    epoch: Instant,
    enabled: AtomicBool,
    shards: Vec<Mutex<VecDeque<Event>>>,
    cap_per_shard: usize,
    dropped: AtomicU64,
}

impl Ring {
    /// A disabled ring holding at most `capacity` events (split across
    /// the internal shards; at least one slot per shard).
    pub fn new(capacity: usize) -> Arc<Ring> {
        Arc::new(Ring {
            epoch: Instant::now(),
            enabled: AtomicBool::new(false),
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            cap_per_shard: capacity.div_ceil(SHARDS).max(1),
            dropped: AtomicU64::new(0),
        })
    }

    /// Is tracing on? One relaxed load — this is the entire cost of a
    /// span site while tracing is off.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn tracing on or off. Buffered events survive a disable.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Events dropped to the bound so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one finished span. Callers normally go through
    /// [`Ring::span`]; this is the low-level entry for spans whose
    /// start predates the call site (e.g. queue time measured from a
    /// request's enqueue timestamp).
    /// Record a zero-duration point event (shed, expiry, replica
    /// death): timestamped now, no span to measure.
    pub fn record_now(&self, name: &str, detail: String) {
        if !self.enabled() {
            return;
        }
        let now = Instant::now();
        self.record(name, detail, now, now);
    }

    pub fn record(&self, name: &str, detail: String, start: Instant, end: Instant) {
        if !self.enabled() {
            return;
        }
        let ts_us = start
            .saturating_duration_since(self.epoch)
            .as_micros()
            .min(u64::MAX as u128) as u64;
        let dur_us = end
            .saturating_duration_since(start)
            .as_micros()
            .min(u64::MAX as u128) as u64;
        let ev = Event {
            ts_us,
            dur_us,
            name: name.to_string(),
            detail,
        };
        // shard by thread so concurrent workers don't contend
        let tid = std::thread::current().id();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::hash::Hash::hash(&tid, &mut h);
        let shard = (std::hash::Hasher::finish(&h) as usize) % SHARDS;
        let mut q = lock(&self.shards[shard]);
        if q.len() >= self.cap_per_shard {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(ev);
    }

    /// Start a span; it records itself into this ring on drop. When
    /// tracing is off the guard is inert (no clock read).
    pub fn span(self: &Arc<Self>, name: &'static str, detail: impl Into<String>) -> Span {
        if !self.enabled() {
            return Span { active: None };
        }
        Span {
            active: Some(SpanInner {
                ring: self.clone(),
                name,
                detail: detail.into(),
                start: Instant::now(),
            }),
        }
    }

    /// Drain all buffered events, oldest first.
    pub fn drain(&self) -> Vec<Event> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(lock(s).drain(..));
        }
        out.sort_by_key(|e| e.ts_us);
        out
    }
}

struct SpanInner {
    ring: Arc<Ring>,
    name: &'static str,
    detail: String,
    start: Instant,
}

/// RAII span guard from [`Ring::span`] or [`here`]. Records one
/// [`Event`] when dropped (if the ring was enabled at creation).
pub struct Span {
    active: Option<SpanInner>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.active.take() {
            let end = Instant::now();
            s.ring.record(s.name, s.detail, s.start, end);
        }
    }
}

thread_local! {
    static SINK: RefCell<Option<Arc<Ring>>> = const { RefCell::new(None) };
}

/// Pin `ring` as this thread's span sink for the guard's lifetime, so
/// code that can't see the ring ([`here`]) still records into it.
/// Nesting restores the previous sink on drop.
pub fn set_sink(ring: Arc<Ring>) -> SinkGuard {
    let prev = SINK.with(|s| s.replace(Some(ring)));
    SinkGuard { prev }
}

/// Guard from [`set_sink`]; restores the previous sink on drop.
pub struct SinkGuard {
    prev: Option<Arc<Ring>>,
}

impl Drop for SinkGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        SINK.with(|s| *s.borrow_mut() = prev);
    }
}

/// Start a span against the thread's pinned sink (see [`set_sink`]).
/// Inert — not even a clock read — when no sink is pinned or tracing
/// is off.
pub fn here(name: &'static str, detail: impl Into<String>) -> Span {
    SINK.with(|s| match &*s.borrow() {
        Some(ring) => ring.span(name, detail),
        None => Span { active: None },
    })
}
