//! Deployment plans — the serializable output of the autotuner.
//!
//! A plan maps every enc point of a model to the OverQ configuration the
//! policy engine chose for it, together with the evidence (coverage,
//! area, zero/outlier statistics) backing the choice. Plans round-trip
//! through JSON (`util::json`, see docs/deployment_plan.md for the
//! format) so they can be versioned next to the AOT artifacts and
//! registered with the serving coordinator as `plan:<name>` variants.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::{LayerQuant, QuantConfig, WBITS_DEFAULT};
use crate::obs::counters::DriftBaseline;
use crate::overq::OverQConfig;
use crate::util::json::{parse_file, Value};

/// Current plan file format version. Version 1 (pre-weight-bitwidth)
/// plans still load: the `wbits` layer field defaults to
/// [`WBITS_DEFAULT`] and the `probe` evidence block to absent, which
/// reproduces v1 serving behavior exactly.
pub const PLAN_VERSION: u32 = 2;

/// Measured-accuracy evidence attached by the refinement stage of the
/// autotuner (`policy::autotune_measured`): how the plan and the global
/// baseline scored on the held-out probe split.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProbeEvidence {
    /// Probe-split size (images).
    pub images: usize,
    /// Measured top-1 accuracy of this plan on the probe split.
    pub accuracy: f64,
    /// Measured top-1 accuracy of the global baseline config.
    pub baseline_accuracy: f64,
}

/// One enc point's chosen configuration + evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanLayer {
    /// Enc-point id (dense, 0-based).
    pub enc: usize,
    /// Chosen OverQ mode.
    pub overq: OverQConfig,
    /// Activation scale (clip / qmax at `overq.bits`).
    pub scale: f32,
    /// Weight bitwidth for convs reading this enc point;
    /// [`WBITS_DEFAULT`] (0) = the engine's prepared 8-bit weights.
    pub wbits: u32,
    /// Exact-zero fraction measured at profiling time.
    pub p0: f64,
    /// Outlier fraction at the chosen scale.
    pub outlier_rate: f64,
    /// Eq. (1) coverage prediction at `p0` / cascade.
    pub theory_coverage: f64,
    /// Coverage measured with `overq::coverage_stats` on the tap.
    pub measured_coverage: f64,
    /// PE area (µm²) the config costs (Table-3 model).
    pub area: f64,
    /// MACs per image through this enc point (cost weight).
    pub macs: u64,
    /// Profile-time activation statistics (mean/var/clip rate) the live
    /// telemetry compares against for drift detection. Absent in plans
    /// tuned before the telemetry subsystem existed (lint OQ019 nudges
    /// a re-profile).
    pub drift: Option<DriftBaseline>,
}

/// A per-layer mixed-precision deployment plan for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentPlan {
    /// File-format version this plan was loaded from / will be saved
    /// as (see [`PLAN_VERSION`]).
    pub version: u32,
    /// Plan name; the serving layer exposes it as variant `plan:<name>`.
    pub name: String,
    /// Model the plan was tuned for.
    pub model: String,
    /// Per-enc-point choices, sorted by `enc` (dense).
    pub layers: Vec<PlanLayer>,
    /// MAC-weighted mean PE area of the plan (area-time proxy).
    pub total_area: f64,
    /// Same metric for the global baseline config it was tuned against.
    pub baseline_area: f64,
    /// Outlier-weighted mean measured coverage of the plan.
    pub mean_coverage: f64,
    /// Same metric for the global baseline config.
    pub baseline_coverage: f64,
    /// Probe-split accuracy evidence, when the accuracy-refinement
    /// stage ran (absent in proxy-only and v1 plans).
    pub probe: Option<ProbeEvidence>,
}

impl DeploymentPlan {
    /// Assemble a plan from per-layer choices, deriving the MAC-weighted
    /// mean PE area and the outlier-weighted mean coverage in one place.
    /// These are the conventions every plan producer must share: a
    /// layer's deployment cost is its area × MAC share, and layers with
    /// no outliers count as fully covered but carry no coverage weight.
    pub fn from_layers(
        name: &str,
        model: &str,
        layers: Vec<PlanLayer>,
        baseline_area: f64,
        baseline_coverage: f64,
    ) -> DeploymentPlan {
        let total_macs: f64 = layers
            .iter()
            .map(|l| l.macs as f64)
            .sum::<f64>()
            .max(1.0);
        let total_area: f64 = layers
            .iter()
            .map(|l| l.area * l.macs as f64 / total_macs)
            .sum();
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for l in &layers {
            num += l.measured_coverage * l.outlier_rate * l.macs as f64;
            den += l.outlier_rate * l.macs as f64;
        }
        let mean_coverage = if den > 0.0 { num / den } else { 1.0 };
        DeploymentPlan {
            version: PLAN_VERSION,
            name: name.to_string(),
            model: model.to_string(),
            layers,
            total_area,
            baseline_area,
            mean_coverage,
            baseline_coverage,
            probe: None,
        }
    }

    /// Engine-ready per-enc-point quantization config.
    pub fn to_quant_config(&self) -> QuantConfig {
        QuantConfig {
            layers: self
                .layers
                .iter()
                .map(|l| LayerQuant {
                    overq: l.overq,
                    scale: l.scale,
                    wbits: l.wbits,
                })
                .collect(),
        }
    }

    /// Serialize to the documented JSON shape (docs/deployment_plan.md).
    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let mut lfields = vec![
                    ("enc", Value::Num(l.enc as f64)),
                    ("bits", Value::Num(l.overq.bits as f64)),
                    ("cascade", Value::Num(l.overq.cascade as f64)),
                    ("ro", Value::Bool(l.overq.range_overwrite)),
                    ("pr", Value::Bool(l.overq.precision_overwrite)),
                    ("scale", Value::Num(l.scale as f64)),
                    ("wbits", Value::Num(l.wbits as f64)),
                    ("p0", Value::Num(l.p0)),
                    ("outlier_rate", Value::Num(l.outlier_rate)),
                    ("theory_coverage", Value::Num(l.theory_coverage)),
                    ("measured_coverage", Value::Num(l.measured_coverage)),
                    ("area", Value::Num(l.area)),
                    ("macs", Value::Num(l.macs as f64)),
                ];
                if let Some(d) = l.drift {
                    lfields.push((
                        "drift",
                        obj(&[
                            ("mean", Value::Num(d.mean)),
                            ("var", Value::Num(d.var)),
                            ("clip_rate", Value::Num(d.clip_rate)),
                        ]),
                    ));
                }
                obj(&lfields)
            })
            .collect();
        let mut fields = vec![
            // always stamp the current version: the serialized shape is
            // the current schema regardless of what file this plan was
            // loaded from (a v1-loaded plan re-saves as v2)
            ("version", Value::Num(PLAN_VERSION as f64)),
            ("name", Value::Str(self.name.clone())),
            ("model", Value::Str(self.model.clone())),
            ("layers", Value::Arr(layers)),
            ("total_area", Value::Num(self.total_area)),
            ("baseline_area", Value::Num(self.baseline_area)),
            ("mean_coverage", Value::Num(self.mean_coverage)),
            ("baseline_coverage", Value::Num(self.baseline_coverage)),
        ];
        if let Some(p) = &self.probe {
            fields.push((
                "probe",
                obj(&[
                    ("images", Value::Num(p.images as f64)),
                    ("accuracy", Value::Num(p.accuracy)),
                    ("baseline_accuracy", Value::Num(p.baseline_accuracy)),
                ]),
            ));
        }
        obj(&fields)
    }

    /// Parse any supported plan version (1..=[`PLAN_VERSION`]); fields
    /// newer than the file's version get backward-compatible defaults.
    pub fn from_json(v: &Value) -> Result<DeploymentPlan> {
        let version = v.at(&["version"]).as_usize().context("plan version")? as u32;
        anyhow::ensure!(
            (1..=PLAN_VERSION).contains(&version),
            "unsupported plan version {version} (this build reads 1..={PLAN_VERSION})"
        );
        let mut layers = Vec::new();
        for l in v.at(&["layers"]).as_arr().context("plan layers")? {
            layers.push(PlanLayer {
                enc: l.at(&["enc"]).as_usize().context("layer enc")?,
                overq: OverQConfig {
                    bits: l.at(&["bits"]).as_usize().context("layer bits")? as u32,
                    cascade: l.at(&["cascade"]).as_usize().context("layer cascade")?,
                    // mode flags change the numerics — a missing key is
                    // a malformed plan, not a default
                    range_overwrite: l.at(&["ro"]).as_bool().context("layer ro")?,
                    precision_overwrite: l.at(&["pr"]).as_bool().context("layer pr")?,
                },
                scale: l.at(&["scale"]).as_f64().context("layer scale")? as f32,
                // absent in v1 plans → the default prepared-weight path;
                // a *present* value must be a valid width — fail at load
                // time, not on every serve request
                wbits: match l.at(&["wbits"]) {
                    Value::Null => WBITS_DEFAULT,
                    v => {
                        let w = v.as_f64().context("layer wbits must be a number")?;
                        anyhow::ensure!(
                            w.fract() == 0.0 && w >= 0.0 && w <= 8.0,
                            "layer wbits {w} is not an integer in 0..=8"
                        );
                        let w = w as u32;
                        anyhow::ensure!(
                            w == WBITS_DEFAULT || (2..=8).contains(&w),
                            "layer wbits {w} outside the engine's supported \
                             range (0 = default, or 2..=8)"
                        );
                        w
                    }
                },
                p0: l.at(&["p0"]).as_f64().unwrap_or(0.0),
                outlier_rate: l.at(&["outlier_rate"]).as_f64().unwrap_or(0.0),
                theory_coverage: l.at(&["theory_coverage"]).as_f64().unwrap_or(0.0),
                measured_coverage: l.at(&["measured_coverage"]).as_f64().unwrap_or(0.0),
                area: l.at(&["area"]).as_f64().unwrap_or(0.0),
                macs: l.at(&["macs"]).as_f64().unwrap_or(0.0) as u64,
                // absent in plans tuned before the telemetry subsystem;
                // a *present* block must be complete — a drift baseline
                // with silently-zeroed fields would fire false alarms
                drift: match l.at(&["drift"]) {
                    Value::Null => None,
                    d => Some(DriftBaseline {
                        mean: d.at(&["mean"]).as_f64().context("drift mean")?,
                        var: d.at(&["var"]).as_f64().context("drift var")?,
                        clip_rate: d
                            .at(&["clip_rate"])
                            .as_f64()
                            .context("drift clip_rate")?,
                    }),
                },
            });
        }
        layers.sort_by_key(|l| l.enc);
        for (i, l) in layers.iter().enumerate() {
            anyhow::ensure!(l.enc == i, "plan enc points not dense (missing enc {i})");
        }
        let probe = match v.at(&["probe"]) {
            Value::Null => None,
            p => Some(ProbeEvidence {
                images: p.at(&["images"]).as_usize().context("probe images")?,
                accuracy: p.at(&["accuracy"]).as_f64().context("probe accuracy")?,
                baseline_accuracy: p
                    .at(&["baseline_accuracy"])
                    .as_f64()
                    .context("probe baseline_accuracy")?,
            }),
        };
        Ok(DeploymentPlan {
            version,
            name: v.at(&["name"]).as_str().context("plan name")?.to_string(),
            model: v.at(&["model"]).as_str().context("plan model")?.to_string(),
            layers,
            total_area: v.at(&["total_area"]).as_f64().unwrap_or(0.0),
            baseline_area: v.at(&["baseline_area"]).as_f64().unwrap_or(0.0),
            mean_coverage: v.at(&["mean_coverage"]).as_f64().unwrap_or(0.0),
            baseline_coverage: v.at(&["baseline_coverage"]).as_f64().unwrap_or(0.0),
            probe,
        })
    }

    /// Write the plan as JSON, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json().to_json())
            .with_context(|| format!("write {}", path.display()))
    }

    /// Read + parse a `*.plan.json` file ([`DeploymentPlan::from_json`]).
    pub fn load(path: &Path) -> Result<DeploymentPlan> {
        DeploymentPlan::from_json(&parse_file(path)?)
            .with_context(|| format!("parse plan {}", path.display()))
    }
}

fn obj(fields: &[(&str, Value)]) -> Value {
    Value::Obj(
        fields
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect::<BTreeMap<_, _>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_plan() -> DeploymentPlan {
        DeploymentPlan {
            version: PLAN_VERSION,
            name: "toy-a4".into(),
            model: "toy".into(),
            layers: vec![
                PlanLayer {
                    enc: 0,
                    overq: OverQConfig::full(4, 2),
                    scale: 0.031,
                    wbits: 4,
                    p0: 0.52,
                    outlier_rate: 0.013,
                    theory_coverage: 0.77,
                    measured_coverage: 0.81,
                    area: 350.25,
                    macs: 884_736,
                    drift: Some(DriftBaseline {
                        mean: 0.42,
                        var: 1.3,
                        clip_rate: 0.013,
                    }),
                },
                PlanLayer {
                    enc: 1,
                    overq: OverQConfig::baseline(8),
                    scale: 0.0011,
                    wbits: WBITS_DEFAULT,
                    p0: 0.48,
                    outlier_rate: 0.0,
                    theory_coverage: 0.0,
                    measured_coverage: 1.0,
                    area: 410.5,
                    macs: 442_368,
                    drift: None,
                },
            ],
            total_area: 370.3,
            baseline_area: 380.0,
            mean_coverage: 0.87,
            baseline_coverage: 0.8,
            probe: Some(ProbeEvidence {
                images: 128,
                accuracy: 0.71,
                baseline_accuracy: 0.68,
            }),
        }
    }

    #[test]
    fn from_layers_derives_weighted_aggregates() {
        let p = sample_plan();
        let rebuilt = DeploymentPlan::from_layers("x", "toy", p.layers.clone(), 1.0, 0.5);
        assert_eq!(rebuilt.name, "x");
        assert_eq!(rebuilt.model, "toy");
        // enc1 has outlier_rate 0 → carries no coverage weight
        assert!((rebuilt.mean_coverage - 0.81).abs() < 1e-12);
        let tm = (884_736u64 + 442_368) as f64;
        let want_area = 350.25 * 884_736.0 / tm + 410.5 * 442_368.0 / tm;
        assert!((rebuilt.total_area - want_area).abs() < 1e-9);
        assert_eq!(rebuilt.baseline_area, 1.0);
        assert_eq!(rebuilt.baseline_coverage, 0.5);
    }

    #[test]
    fn json_roundtrip() {
        let plan = sample_plan();
        let text = plan.to_json().to_json();
        let back = DeploymentPlan::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn file_roundtrip() {
        let plan = sample_plan();
        let dir = std::env::temp_dir().join("overq_plan_test");
        let path = dir.join("toy.plan.json");
        plan.save(&path).unwrap();
        let back = DeploymentPlan::load(&path).unwrap();
        assert_eq!(plan, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn to_quant_config_order() {
        let qc = sample_plan().to_quant_config();
        assert_eq!(qc.num_enc_points(), 2);
        assert_eq!(qc.layers[0].overq.bits, 4);
        assert_eq!(qc.layers[0].wbits, 4);
        assert_eq!(qc.layers[1].overq.bits, 8);
        assert_eq!(qc.layers[1].wbits, WBITS_DEFAULT);
        assert!((qc.layers[1].scale - 0.0011).abs() < 1e-9);
    }

    #[test]
    fn v1_plans_load_with_default_weight_fields() {
        // a pre-weight-bitwidth (PR-2 era) plan file: version 1, no
        // `wbits` layer fields, no `probe` block
        let v1 = r#"{
          "version": 1,
          "name": "legacy",
          "model": "toy",
          "layers": [
            {"enc": 0, "bits": 4, "cascade": 2, "ro": true, "pr": true,
             "scale": 0.031, "p0": 0.52, "outlier_rate": 0.013,
             "theory_coverage": 0.77, "measured_coverage": 0.81,
             "area": 350.25, "macs": 884736},
            {"enc": 1, "bits": 8, "cascade": 1, "ro": false, "pr": false,
             "scale": 0.0011, "p0": 0.48, "outlier_rate": 0.0,
             "theory_coverage": 0.0, "measured_coverage": 1.0,
             "area": 410.5, "macs": 442368}
          ],
          "total_area": 370.3,
          "baseline_area": 380.0,
          "mean_coverage": 0.87,
          "baseline_coverage": 0.8
        }"#;
        let plan = DeploymentPlan::from_json(&parse(v1).unwrap()).unwrap();
        assert_eq!(plan.version, 1);
        assert!(plan.layers.iter().all(|l| l.wbits == WBITS_DEFAULT));
        assert_eq!(plan.probe, None);
        // engine-ready on the default prepared-weight path
        let qc = plan.to_quant_config();
        assert!(qc.layers.iter().all(|l| l.wbits == WBITS_DEFAULT));
        // re-saving stamps the current schema version; everything else
        // survives without loss
        let back =
            DeploymentPlan::from_json(&parse(&plan.to_json().to_json()).unwrap()).unwrap();
        let mut expect = plan.clone();
        expect.version = PLAN_VERSION;
        assert_eq!(back, expect);
    }

    #[test]
    fn v2_probe_evidence_roundtrips() {
        let plan = sample_plan();
        assert!(plan.probe.is_some());
        let back = DeploymentPlan::from_json(&parse(&plan.to_json().to_json()).unwrap()).unwrap();
        assert_eq!(back.probe, plan.probe);
        // absent probe stays absent
        let mut bare = sample_plan();
        bare.probe = None;
        let text = bare.to_json().to_json();
        assert!(!text.contains("probe"));
        let back = DeploymentPlan::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.probe, None);
    }

    #[test]
    fn drift_baseline_roundtrips_and_stays_optional() {
        // layer 0 carries a drift block, layer 1 does not — both
        // round-trip (json_roundtrip covers equality; check the shape)
        let plan = sample_plan();
        let text = plan.to_json().to_json();
        assert!(text.contains("\"drift\""));
        assert!(text.contains("\"clip_rate\""));
        let back = DeploymentPlan::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.layers[0].drift, plan.layers[0].drift);
        assert_eq!(back.layers[1].drift, None);

        // an incomplete drift block is rejected at load time
        let text = text.replace("\"clip_rate\":0.013,", "");
        assert!(!text.contains("clip_rate"), "splice missed: {text}");
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());
    }

    #[test]
    fn rejects_sparse_or_wrong_version() {
        let mut plan = sample_plan();
        plan.layers[1].enc = 3; // hole at 1
        let text = plan.to_json().to_json();
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());

        // to_json stamps PLAN_VERSION, so splice a bad version into the
        // text to exercise the loader's version gate
        let text = sample_plan()
            .to_json()
            .to_json()
            .replace(&format!("\"version\":{PLAN_VERSION}"), "\"version\":99");
        assert!(
            text.contains("\"version\":99"),
            "version splice missed: {text}"
        );
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());

        // unservable weight bitwidths are rejected at load time
        let mut plan = sample_plan();
        plan.layers[0].wbits = 1;
        let text = plan.to_json().to_json();
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());
        plan.layers[0].wbits = 12;
        let text = plan.to_json().to_json();
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());

        // malformed wbits values must fail loudly, not coerce to the
        // default path (the plan would silently serve other numerics)
        let good = sample_plan().to_json().to_json();
        for bad in ["\"wbits\":-4", "\"wbits\":4.5", "\"wbits\":\"4\""] {
            let text = good.replace("\"wbits\":4", bad);
            assert!(
                text.contains(bad),
                "wbits splice missed for {bad}: {text}"
            );
            assert!(
                DeploymentPlan::from_json(&parse(&text).unwrap()).is_err(),
                "malformed {bad} was accepted"
            );
        }
    }
}
