//! Deployment plans — the serializable output of the autotuner.
//!
//! A plan maps every enc point of a model to the OverQ configuration the
//! policy engine chose for it, together with the evidence (coverage,
//! area, zero/outlier statistics) backing the choice. Plans round-trip
//! through JSON (`util::json`, see docs/deployment_plan.md for the
//! format) so they can be versioned next to the AOT artifacts and
//! registered with the serving coordinator as `plan:<name>` variants.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::{LayerQuant, QuantConfig};
use crate::overq::OverQConfig;
use crate::util::json::{parse_file, Value};

/// Current plan file format version.
pub const PLAN_VERSION: u32 = 1;

/// One enc point's chosen configuration + evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanLayer {
    /// Enc-point id (dense, 0-based).
    pub enc: usize,
    /// Chosen OverQ mode.
    pub overq: OverQConfig,
    /// Activation scale (clip / qmax at `overq.bits`).
    pub scale: f32,
    /// Exact-zero fraction measured at profiling time.
    pub p0: f64,
    /// Outlier fraction at the chosen scale.
    pub outlier_rate: f64,
    /// Eq. (1) coverage prediction at `p0` / cascade.
    pub theory_coverage: f64,
    /// Coverage measured with `overq::coverage_stats` on the tap.
    pub measured_coverage: f64,
    /// PE area (µm²) the config costs (Table-3 model).
    pub area: f64,
    /// MACs per image through this enc point (cost weight).
    pub macs: u64,
}

/// A per-layer mixed-precision deployment plan for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentPlan {
    pub version: u32,
    /// Plan name; the serving layer exposes it as variant `plan:<name>`.
    pub name: String,
    /// Model the plan was tuned for.
    pub model: String,
    /// Per-enc-point choices, sorted by `enc` (dense).
    pub layers: Vec<PlanLayer>,
    /// MAC-weighted mean PE area of the plan (area-time proxy).
    pub total_area: f64,
    /// Same metric for the global baseline config it was tuned against.
    pub baseline_area: f64,
    /// Outlier-weighted mean measured coverage of the plan.
    pub mean_coverage: f64,
    /// Same metric for the global baseline config.
    pub baseline_coverage: f64,
}

impl DeploymentPlan {
    /// Assemble a plan from per-layer choices, deriving the MAC-weighted
    /// mean PE area and the outlier-weighted mean coverage in one place.
    /// These are the conventions every plan producer must share: a
    /// layer's deployment cost is its area × MAC share, and layers with
    /// no outliers count as fully covered but carry no coverage weight.
    pub fn from_layers(
        name: &str,
        model: &str,
        layers: Vec<PlanLayer>,
        baseline_area: f64,
        baseline_coverage: f64,
    ) -> DeploymentPlan {
        let total_macs: f64 = layers
            .iter()
            .map(|l| l.macs as f64)
            .sum::<f64>()
            .max(1.0);
        let total_area: f64 = layers
            .iter()
            .map(|l| l.area * l.macs as f64 / total_macs)
            .sum();
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for l in &layers {
            num += l.measured_coverage * l.outlier_rate * l.macs as f64;
            den += l.outlier_rate * l.macs as f64;
        }
        let mean_coverage = if den > 0.0 { num / den } else { 1.0 };
        DeploymentPlan {
            version: PLAN_VERSION,
            name: name.to_string(),
            model: model.to_string(),
            layers,
            total_area,
            baseline_area,
            mean_coverage,
            baseline_coverage,
        }
    }

    /// Engine-ready per-enc-point quantization config.
    pub fn to_quant_config(&self) -> QuantConfig {
        QuantConfig {
            layers: self
                .layers
                .iter()
                .map(|l| LayerQuant {
                    overq: l.overq,
                    scale: l.scale,
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                obj(&[
                    ("enc", Value::Num(l.enc as f64)),
                    ("bits", Value::Num(l.overq.bits as f64)),
                    ("cascade", Value::Num(l.overq.cascade as f64)),
                    ("ro", Value::Bool(l.overq.range_overwrite)),
                    ("pr", Value::Bool(l.overq.precision_overwrite)),
                    ("scale", Value::Num(l.scale as f64)),
                    ("p0", Value::Num(l.p0)),
                    ("outlier_rate", Value::Num(l.outlier_rate)),
                    ("theory_coverage", Value::Num(l.theory_coverage)),
                    ("measured_coverage", Value::Num(l.measured_coverage)),
                    ("area", Value::Num(l.area)),
                    ("macs", Value::Num(l.macs as f64)),
                ])
            })
            .collect();
        obj(&[
            ("version", Value::Num(self.version as f64)),
            ("name", Value::Str(self.name.clone())),
            ("model", Value::Str(self.model.clone())),
            ("layers", Value::Arr(layers)),
            ("total_area", Value::Num(self.total_area)),
            ("baseline_area", Value::Num(self.baseline_area)),
            ("mean_coverage", Value::Num(self.mean_coverage)),
            ("baseline_coverage", Value::Num(self.baseline_coverage)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<DeploymentPlan> {
        let version = v.at(&["version"]).as_usize().context("plan version")? as u32;
        anyhow::ensure!(
            version == PLAN_VERSION,
            "unsupported plan version {version} (expected {PLAN_VERSION})"
        );
        let mut layers = Vec::new();
        for l in v.at(&["layers"]).as_arr().context("plan layers")? {
            layers.push(PlanLayer {
                enc: l.at(&["enc"]).as_usize().context("layer enc")?,
                overq: OverQConfig {
                    bits: l.at(&["bits"]).as_usize().context("layer bits")? as u32,
                    cascade: l.at(&["cascade"]).as_usize().context("layer cascade")?,
                    // mode flags change the numerics — a missing key is
                    // a malformed plan, not a default
                    range_overwrite: l.at(&["ro"]).as_bool().context("layer ro")?,
                    precision_overwrite: l.at(&["pr"]).as_bool().context("layer pr")?,
                },
                scale: l.at(&["scale"]).as_f64().context("layer scale")? as f32,
                p0: l.at(&["p0"]).as_f64().unwrap_or(0.0),
                outlier_rate: l.at(&["outlier_rate"]).as_f64().unwrap_or(0.0),
                theory_coverage: l.at(&["theory_coverage"]).as_f64().unwrap_or(0.0),
                measured_coverage: l.at(&["measured_coverage"]).as_f64().unwrap_or(0.0),
                area: l.at(&["area"]).as_f64().unwrap_or(0.0),
                macs: l.at(&["macs"]).as_f64().unwrap_or(0.0) as u64,
            });
        }
        layers.sort_by_key(|l| l.enc);
        for (i, l) in layers.iter().enumerate() {
            anyhow::ensure!(l.enc == i, "plan enc points not dense (missing enc {i})");
        }
        Ok(DeploymentPlan {
            version,
            name: v.at(&["name"]).as_str().context("plan name")?.to_string(),
            model: v.at(&["model"]).as_str().context("plan model")?.to_string(),
            layers,
            total_area: v.at(&["total_area"]).as_f64().unwrap_or(0.0),
            baseline_area: v.at(&["baseline_area"]).as_f64().unwrap_or(0.0),
            mean_coverage: v.at(&["mean_coverage"]).as_f64().unwrap_or(0.0),
            baseline_coverage: v.at(&["baseline_coverage"]).as_f64().unwrap_or(0.0),
        })
    }

    /// Write the plan as JSON, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json().to_json())
            .with_context(|| format!("write {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<DeploymentPlan> {
        DeploymentPlan::from_json(&parse_file(path)?)
            .with_context(|| format!("parse plan {}", path.display()))
    }
}

fn obj(fields: &[(&str, Value)]) -> Value {
    Value::Obj(
        fields
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect::<BTreeMap<_, _>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_plan() -> DeploymentPlan {
        DeploymentPlan {
            version: PLAN_VERSION,
            name: "toy-a4".into(),
            model: "toy".into(),
            layers: vec![
                PlanLayer {
                    enc: 0,
                    overq: OverQConfig::full(4, 2),
                    scale: 0.031,
                    p0: 0.52,
                    outlier_rate: 0.013,
                    theory_coverage: 0.77,
                    measured_coverage: 0.81,
                    area: 350.25,
                    macs: 884_736,
                },
                PlanLayer {
                    enc: 1,
                    overq: OverQConfig::baseline(8),
                    scale: 0.0011,
                    p0: 0.48,
                    outlier_rate: 0.0,
                    theory_coverage: 0.0,
                    measured_coverage: 1.0,
                    area: 410.5,
                    macs: 442_368,
                },
            ],
            total_area: 370.3,
            baseline_area: 380.0,
            mean_coverage: 0.87,
            baseline_coverage: 0.8,
        }
    }

    #[test]
    fn from_layers_derives_weighted_aggregates() {
        let p = sample_plan();
        let rebuilt = DeploymentPlan::from_layers("x", "toy", p.layers.clone(), 1.0, 0.5);
        assert_eq!(rebuilt.name, "x");
        assert_eq!(rebuilt.model, "toy");
        // enc1 has outlier_rate 0 → carries no coverage weight
        assert!((rebuilt.mean_coverage - 0.81).abs() < 1e-12);
        let tm = (884_736u64 + 442_368) as f64;
        let want_area = 350.25 * 884_736.0 / tm + 410.5 * 442_368.0 / tm;
        assert!((rebuilt.total_area - want_area).abs() < 1e-9);
        assert_eq!(rebuilt.baseline_area, 1.0);
        assert_eq!(rebuilt.baseline_coverage, 0.5);
    }

    #[test]
    fn json_roundtrip() {
        let plan = sample_plan();
        let text = plan.to_json().to_json();
        let back = DeploymentPlan::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn file_roundtrip() {
        let plan = sample_plan();
        let dir = std::env::temp_dir().join("overq_plan_test");
        let path = dir.join("toy.plan.json");
        plan.save(&path).unwrap();
        let back = DeploymentPlan::load(&path).unwrap();
        assert_eq!(plan, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn to_quant_config_order() {
        let qc = sample_plan().to_quant_config();
        assert_eq!(qc.num_enc_points(), 2);
        assert_eq!(qc.layers[0].overq.bits, 4);
        assert_eq!(qc.layers[1].overq.bits, 8);
        assert!((qc.layers[1].scale - 0.0011).abs() < 1e-9);
    }

    #[test]
    fn rejects_sparse_or_wrong_version() {
        let mut plan = sample_plan();
        plan.layers[1].enc = 3; // hole at 1
        let text = plan.to_json().to_json();
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());

        let mut plan = sample_plan();
        plan.version = 99;
        let text = plan.to_json().to_json();
        assert!(DeploymentPlan::from_json(&parse(&text).unwrap()).is_err());
    }
}
