//! Per-enc-point statistics feeding the autotuner.
//!
//! One fp32 forward over the profiling batch collects, for every enc
//! point: the full activation tensor (for *measured* coverage via
//! `overq::coverage_stats`), a bounded subsample (for the fast predicted
//! error/coverage proxy), summary stats, the exact-zero fraction `p0`
//! driving Eq. (1), and the MAC count of the quantized convs reading the
//! point (the cost weight for the area-time budget).

use anyhow::Result;

use crate::harness::calibrate::subsample;
use crate::models::zoo::LoadedModel;
use crate::nn::conv::same_out;
use crate::nn::graph::Op;
use crate::quant::clip::ActStats;
use crate::tensor::TensorF;

/// Everything the autotuner knows about one enc point.
#[derive(Clone, Debug)]
pub struct EncPointProfile {
    /// Enc-point id (index into `QuantConfig::layers`).
    pub enc: usize,
    /// Summary stats of the profiled activations.
    pub stats: ActStats,
    /// Exact-zero fraction of the tap (the paper's `p0`).
    pub p0: f64,
    /// MACs per image across quantized convs consuming this point.
    pub macs: u64,
    /// Full profiled activation tensor (for measured coverage).
    pub tap: TensorF,
    /// Bounded subsample for candidate scoring.
    pub samples: Vec<f32>,
}

/// Profile every enc point of a model with one fp32 forward.
pub fn profile_enc_points(
    model: &LoadedModel,
    images: &TensorF,
    max_samples: usize,
) -> Result<Vec<EncPointProfile>> {
    let graph = &model.engine.graph;
    let srcs = graph.enc_point_sources();
    let (_, taps) = model.engine.forward_f32(images, &srcs)?;

    // MACs per enc point: conv cost at the spatial size of its input
    // tap, over the channels the hardware actually sees — OCS channel
    // splitting expands cin, and that extra occupancy must show up in
    // the plan's area-time accounting.
    let mut macs = vec![0u64; srcs.len()];
    for node in &graph.nodes {
        if let Op::Conv {
            kh,
            kw,
            stride,
            cin,
            cout,
            quant: true,
            enc: Some(e),
            ..
        } = &node.op
        {
            let tap = &taps[*e];
            let (h, w) = (tap.dims()[1], tap.dims()[2]);
            let (oh, ow) = (same_out(h, *stride), same_out(w, *stride));
            let cin_eff = model.engine.conv_in_channels(node.id).unwrap_or(*cin);
            macs[*e] += (kh * kw * cin_eff * cout * oh * ow) as u64;
        }
    }

    let mut out = Vec::with_capacity(taps.len());
    for (e, tap) in taps.into_iter().enumerate() {
        let samples = subsample(&tap, max_samples);
        let stats = ActStats::from_tensor(&tap);
        out.push(EncPointProfile {
            enc: e,
            stats,
            p0: tap.zero_frac(),
            macs: macs[e].max(1),
            tap,
            samples,
        });
    }
    Ok(out)
}
