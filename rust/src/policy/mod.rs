//! Per-layer OverQ policy engine — coverage-driven mixed-precision
//! autotuning and deployment plans.
//!
//! The paper applies one global OverQ config to every layer, but §3.2 /
//! Table 1 show outlier coverage depends strongly on per-layer zero and
//! outlier statistics. This subsystem chooses the config *per enc point*:
//!
//! * [`profile`] — one fp32 forward collects per-enc-point taps, zero
//!   fraction `p0`, outlier stats and MAC weights.
//! * [`candidates`] — the search space (bits × cascade × RO/PR) and the
//!   Table-3 PE-area cost of each config.
//! * [`autotune`] — scores candidates with an Eq.-(1)-based error proxy,
//!   keeps per-layer Pareto frontiers over (area, error), and greedily
//!   spends an area budget where it buys the most error reduction;
//!   final choices are validated with measured `coverage_stats`.
//! * [`plan`] — the serializable [`DeploymentPlan`] artifact: per-layer
//!   configs + evidence, JSON round-trip, and conversion to the
//!   engine's per-enc-point [`crate::nn::QuantConfig`]. The serving
//!   coordinator registers plans as `plan:<name>` variants.

pub mod autotune;
pub mod candidates;
pub mod plan;
pub mod profile;

pub use autotune::{autotune, AutotuneConfig, AutotuneResult, LayerChoice, ScoredCandidate};
pub use candidates::{pe_area, pe_variant, CandidateSpace};
pub use plan::{DeploymentPlan, PlanLayer, PLAN_VERSION};
pub use profile::{profile_enc_points, EncPointProfile};
