//! Per-layer OverQ policy engine — coverage-driven mixed-precision
//! autotuning and deployment plans.
//!
//! The paper applies one global OverQ config to every layer, but §3.2 /
//! Table 1 show outlier coverage depends strongly on per-layer zero and
//! outlier statistics. This subsystem chooses the config *per enc point*
//! in two stages (see `docs/autotuning.md` for the full walkthrough):
//!
//! * [`profile`] — one fp32 forward collects per-enc-point taps, zero
//!   fraction `p0`, outlier stats and MAC weights (OCS-expanded channels
//!   included).
//! * [`candidates`] — the search space (bits × cascade × RO/PR × weight
//!   bitwidth) and the Table-3 PE-area cost of each config.
//! * [`mod@autotune`] — stage 1 scores candidates with an Eq.-(1)-based
//!   error proxy, keeps per-layer Pareto frontiers over (area, error),
//!   and greedily spends an area budget where it buys the most error
//!   reduction; stage 2 ([`autotune_measured`]) re-scores the top-K
//!   greedy snapshots with measured accuracy on a held-out probe split
//!   and picks the budget-feasible winner.
//! * [`plan`] — the serializable [`DeploymentPlan`] artifact: per-layer
//!   configs + evidence (now including weight bitwidths and probe
//!   accuracy), versioned JSON round-trip with backward-compatible v1
//!   loading, and conversion to the engine's per-enc-point
//!   [`crate::nn::QuantConfig`]. The serving coordinator registers
//!   plans as `plan:<name>` variants.

pub mod autotune;
pub mod candidates;
pub mod plan;
pub mod profile;

pub use autotune::{
    autotune, autotune_measured, spearman, AutotuneConfig, AutotuneResult, LayerChoice,
    MeasuredAutotune, ProbeSplit, RefinedCandidate, ScoredCandidate,
};
pub use candidates::{effective_wbits, pe_area, pe_area_w, pe_variant, CandidateSpace};
pub use plan::{DeploymentPlan, PlanLayer, ProbeEvidence, PLAN_VERSION};
pub use profile::{profile_enc_points, EncPointProfile};
