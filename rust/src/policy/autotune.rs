//! Two-stage autotuner: proxy-scored greedy Pareto search, then
//! measured-accuracy refinement.
//!
//! **Stage 1 (proxy).** For every enc point the tuner scores each
//! candidate (OverQ config × weight bitwidth) with a fast analytic
//! proxy — Eq. (1) `theory_coverage` for the outlier term, uniform-
//! quantizer rounding error for the in-range term, and a crude
//! weight-quantization term ([`crate::nn::Engine::weight_quant_rel_mse`]
//! converted into equivalent activation MSE) — and keeps the per-layer
//! Pareto frontier over (PE area, predicted error). A global greedy pass
//! walks the frontiers, spending an area budget where it buys the
//! largest error reduction per µm², with cost weighted by each layer's
//! MAC share (the PE array is shared temporally, so the deployment cost
//! of a layer's config is area × occupancy).
//!
//! **Stage 2 (refinement, [`autotune_measured`]).** The proxy cannot see
//! everything — in particular, weight-side effects and clipping
//! interactions only show up in task accuracy (OCS/PACT make the same
//! observation). So the greedy upgrade path is snapshotted into a small
//! frontier of budget-feasible candidate plans, the top-K are re-scored
//! with `Engine::accuracy_quant` on a held-out probe split, and the plan
//! with the best *measured* accuracy wins. The proxy-only plan is always
//! in the candidate set, so refinement can only match or improve it.
//! Final choices are validated with measured coverage
//! (`overq::coverage_stats`) on the profiling taps, which is what lands
//! in the emitted [`DeploymentPlan`] together with [`ProbeEvidence`].

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::models::zoo::LoadedModel;
use crate::nn::{LayerQuant, QuantConfig, WBITS_DEFAULT};
use crate::overq::{coverage_stats, theory_coverage, OverQConfig};
use crate::quant::clip::ClipMethod;
use crate::tensor::TensorF;

use crate::analysis::absint::{AbsintConfig, GraphBounds, Interval};

use super::candidates::{effective_wbits, pe_area_w, CandidateSpace};
use super::plan::{DeploymentPlan, PlanLayer, ProbeEvidence};
use super::profile::{profile_enc_points, EncPointProfile};

/// Autotuner knobs.
#[derive(Clone, Debug)]
pub struct AutotuneConfig {
    /// Candidate search space.
    pub space: CandidateSpace,
    /// Clip-threshold method used to derive each candidate's scale.
    pub clip: ClipMethod,
    /// Global config the plan must beat (coverage) at ≤ its area.
    pub baseline: OverQConfig,
    /// MAC-weighted mean PE-area budget (µm²). `None` = the baseline's
    /// own area, i.e. "equal or lower total PE area".
    pub budget_area: Option<f64>,
    /// Max profiled values per enc point for proxy scoring.
    pub max_samples: usize,
    /// Plan name to emit (defaults to `<model>-auto`).
    pub plan_name: Option<String>,
    /// How many frontier plans the accuracy-refinement stage re-scores
    /// on the probe split ([`autotune_measured`] only).
    pub topk: usize,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            space: CandidateSpace::default(),
            clip: ClipMethod::StdMul(4.0),
            baseline: OverQConfig::full(4, 4),
            budget_area: None,
            max_samples: 4096,
            plan_name: None,
            topk: 4,
        }
    }
}

/// A held-out labeled split for the accuracy-refinement stage. Must be
/// disjoint from the profiling images, or the measured ranking just
/// refits the profiling noise.
#[derive(Clone, Debug)]
pub struct ProbeSplit {
    /// (N, H, W, C) probe images.
    pub images: TensorF,
    /// One label per probe image.
    pub labels: Vec<i32>,
}

impl ProbeSplit {
    /// Validate and wrap a probe split; empty splits and label/image
    /// mismatches are errors here, not panics deep in the accuracy loop.
    pub fn new(images: TensorF, labels: Vec<i32>) -> Result<ProbeSplit> {
        let n = images.dims().first().copied().unwrap_or(0);
        anyhow::ensure!(
            n > 0,
            "probe split is empty — the refinement stage needs at least \
             one labeled probe image (--probe)"
        );
        anyhow::ensure!(
            labels.len() >= n,
            "probe split has {n} images but only {} labels",
            labels.len()
        );
        Ok(ProbeSplit { images, labels })
    }

    /// Number of probe images.
    pub fn len(&self) -> usize {
        self.images.dims()[0]
    }

    /// False for any split built by [`ProbeSplit::new`], which rejects
    /// empty ones; present for the `len`/`is_empty` API convention.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One scored candidate at one enc point.
#[derive(Clone, Copy, Debug)]
pub struct ScoredCandidate {
    /// The OverQ mode being scored.
    pub cfg: OverQConfig,
    /// Weight bitwidth ([`WBITS_DEFAULT`] = prepared 8-bit weights).
    pub wbits: u32,
    /// Activation scale (clip / qmax at `cfg.bits`).
    pub scale: f32,
    /// PE area (µm²) from the Table-3 model at `wbits`.
    pub area: f64,
    /// Predicted mean squared activation error on the profile samples
    /// (plus the equivalent-activation weight-quantization term).
    pub pred_err: f64,
    /// Eq. (1) coverage (0 when RO is off).
    pub theory_cov: f64,
    /// Outlier fraction of the samples at this candidate's scale.
    pub outlier_rate: f64,
}

/// The tuner's decision for one enc point.
#[derive(Clone, Debug)]
pub struct LayerChoice {
    /// Enc-point id.
    pub enc: usize,
    /// The winning candidate at this enc point.
    pub chosen: ScoredCandidate,
    /// The global baseline config scored at this layer.
    pub baseline: ScoredCandidate,
    /// Measured coverage of the chosen config on the profiling tap.
    pub measured_cov: f64,
    /// Measured coverage of the baseline config on the profiling tap.
    pub baseline_measured_cov: f64,
    /// Exact-zero fraction of the profiling tap.
    pub p0: f64,
    /// MACs per image through this enc point (cost weight).
    pub macs: u64,
}

/// Full autotune output: per-layer choices + the emitted plan.
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    /// Per-enc-point decisions, in enc order.
    pub layers: Vec<LayerChoice>,
    /// MAC-weighted mean PE area of the plan.
    pub total_area: f64,
    /// MAC-weighted mean PE area of the global baseline.
    pub baseline_area: f64,
    /// Candidates discarded by the static-range prune before any proxy
    /// scoring: configs whose representable max the abstract interpreter
    /// ([`crate::analysis::absint`]) proves saturated against the
    /// certified activation bound. 0 when the model's bounds were
    /// unavailable.
    pub pruned_static: usize,
    /// The emitted deployment plan.
    pub plan: DeploymentPlan,
}

/// One candidate plan scored by the refinement stage.
#[derive(Clone, Debug)]
pub struct RefinedCandidate {
    /// The candidate's deployment plan.
    pub plan: DeploymentPlan,
    /// MAC-weighted mean predicted error (the stage-1 ranking score).
    pub proxy_err: f64,
    /// Measured top-1 accuracy on the probe split.
    pub measured_acc: f64,
    /// Which greedy upgrade step this plan snapshots (0 = min-area).
    pub greedy_step: usize,
}

/// Output of the two-stage tuner ([`autotune_measured`]).
#[derive(Clone, Debug)]
pub struct MeasuredAutotune {
    /// The winning plan (probe evidence attached), as an
    /// [`AutotuneResult`] so proxy-only consumers work unchanged.
    pub result: AutotuneResult,
    /// Every refined candidate, best proxy score first; `candidates[0]`
    /// is always the stage-1 (proxy-only) plan.
    pub candidates: Vec<RefinedCandidate>,
    /// Index of the winner in `candidates`.
    pub chosen: usize,
    /// Measured accuracy of the proxy-only plan (`candidates[0]`).
    pub proxy_acc: f64,
    /// Measured accuracy of the global-baseline control config.
    pub baseline_acc: f64,
    /// Spearman agreement between the proxy ranking and the measured
    /// ranking over the candidates (1 = proxy ordered them perfectly).
    pub rank_agreement: f64,
    /// Probe-split size used for refinement.
    pub probe_images: usize,
}

/// Score one candidate on one enc point's samples at the default
/// weight bitwidth with no weight-error term (the PR-2 behavior).
pub fn score_candidate(
    prof: &EncPointProfile,
    cfg: &OverQConfig,
    clip: ClipMethod,
) -> ScoredCandidate {
    score_candidate_w(prof, cfg, clip, WBITS_DEFAULT, 0.0)
}

/// Score one candidate on one enc point's samples.
///
/// Error model per sample x (scale s, step s, fine step s/B):
/// * exact zero          → 0
/// * in-range value      → s²/12, or (s/B)²/12 with probability `p0`
///                         when PR can park LSBs in a neighboring zero
/// * outlier             → covered (prob. Eq. 1, RO only): rounding at
///                         step s in the widened range, clamped at B²-1;
///                         uncovered: clamp error against qmax·s
///
/// `weight_mse` is the equivalent-activation MSE of quantizing the
/// consuming convs' weights at `wbits` (a per-sample constant), so
/// plans that narrow the weight datapath pay for it in the proxy.
pub fn score_candidate_w(
    prof: &EncPointProfile,
    cfg: &OverQConfig,
    clip: ClipMethod,
    wbits: u32,
    weight_mse: f64,
) -> ScoredCandidate {
    let qmax = cfg.qmax() as f32;
    let clip_v = clip.clip(&prof.samples, prof.stats, cfg.bits).max(1e-6);
    let scale = clip_v / qmax;
    let cov = if cfg.range_overwrite {
        theory_coverage(prof.p0, cfg.cascade)
    } else {
        0.0
    };
    let b = cfg.b() as f32;
    let wide_max = (b * b - 1.0) * scale;
    let step_sq = (scale as f64).powi(2) / 12.0;
    let fine_sq = step_sq / (b as f64 * b as f64);
    let mut err = 0.0f64;
    let mut outliers = 0usize;
    for &x in &prof.samples {
        if x == 0.0 {
            continue;
        }
        let v = (x / scale + 0.5).floor();
        if v > qmax {
            outliers += 1;
            let covered = if x > wide_max {
                ((x - wide_max) as f64).powi(2)
            } else {
                step_sq
            };
            let clamped = ((x - qmax * scale) as f64).powi(2);
            err += cov * covered + (1.0 - cov) * clamped;
        } else if cfg.precision_overwrite {
            err += prof.p0 * fine_sq + (1.0 - prof.p0) * step_sq;
        } else {
            err += step_sq;
        }
    }
    let n = prof.samples.len().max(1) as f64;
    ScoredCandidate {
        cfg: *cfg,
        wbits,
        scale,
        area: pe_area_w(cfg, wbits),
        pred_err: err / n + weight_mse,
        theory_cov: cov,
        outlier_rate: outliers as f64 / n,
    }
}

/// Per-layer Pareto frontier over (area ↑, pred_err ↓) across the full
/// (OverQ config × weight bitwidth) cross product, keeping only
/// candidates whose coverage cannot fall below the baseline's: either
/// they provably produce no outliers on the whole tap (the profiled max
/// rounds inside the code range), or RO is on with theory coverage ≥
/// the baseline's at this layer.
///
/// When `static_hi` carries the analyzer's certified activation bound
/// for this enc point, configs whose representable max falls below
/// `saturation_ratio` of that bound (the same OQ020 threshold the
/// serving gate enforces) are dropped *before* sample scoring — the
/// plan they'd produce would be refused at `register_plan` anyway, so
/// scoring them wastes the proxy/probe budget. Every skipped
/// (config × wbits) pair is counted into `pruned`.
fn frontier(
    prof: &EncPointProfile,
    space: &CandidateSpace,
    clip: ClipMethod,
    baseline: &ScoredCandidate,
    wterm: &[(u32, f64)],
    static_hi: Option<f64>,
    pruned: &mut usize,
) -> Vec<ScoredCandidate> {
    let sat_ratio = AbsintConfig::default().saturation_ratio;
    let mut scored: Vec<ScoredCandidate> = Vec::new();
    for c in space.enumerate() {
        if let Some(hi) = static_hi {
            let qmax = c.qmax() as f32;
            let scale = clip.clip(&prof.samples, prof.stats, c.bits).max(1e-6) / qmax;
            let b = c.b() as f32;
            let rmax = if c.range_overwrite {
                (b * b - 1.0) * scale
            } else {
                qmax * scale
            };
            if hi > 0.0 && (rmax as f64) < sat_ratio * hi {
                *pruned += wterm.len();
                continue;
            }
        }
        for &(w, mse) in wterm {
            let s = score_candidate_w(prof, &c, clip, w, mse);
            let outlier_free = prof.stats.max < (s.cfg.qmax() as f32 + 0.5) * s.scale;
            if outlier_free || s.theory_cov >= baseline.theory_cov - 1e-12 {
                scored.push(s);
            }
        }
    }
    // the baseline itself is always admissible, so the frontier (and the
    // min-area start point) can never exceed the baseline's area
    scored.push(*baseline);
    scored.sort_by(|a, b| {
        a.area
            .partial_cmp(&b.area)
            .unwrap()
            .then(a.pred_err.partial_cmp(&b.pred_err).unwrap())
    });
    let mut front: Vec<ScoredCandidate> = Vec::new();
    for s in scored {
        match front.last() {
            Some(last) if s.area == last.area => continue, // kept cheaper-err already
            Some(last) if s.pred_err >= last.pred_err => continue, // dominated
            _ => front.push(s),
        }
    }
    front
}

/// Stage-1 state: profiles, per-layer frontiers, baseline scores and
/// the budget, shared by plan emission for every greedy snapshot.
struct SearchState {
    profiles: Vec<EncPointProfile>,
    baselines: Vec<ScoredCandidate>,
    fronts: Vec<Vec<ScoredCandidate>>,
    /// MAC share per layer (the area-time cost weight).
    weight: Vec<f64>,
    /// Measured coverage of the baseline config per layer — fixed
    /// across snapshots, so computed once.
    baseline_cov: Vec<f64>,
    baseline_area: f64,
    budget: f64,
    /// (config × wbits) pairs the static-range prune discarded.
    pruned_static: usize,
}

/// Memo of measured coverage per (layer, frontier index), so emitting
/// several greedy snapshots never re-scans a tap for the same choice.
type CovCache = HashMap<(usize, usize), f64>;

/// Profile the model, build frontiers and run the greedy budget walk.
/// Returns the state plus the full upgrade history: `history[s]` is the
/// per-layer frontier index vector after `s` greedy upgrades (so
/// `history.last()` is the proxy-optimal plan at the budget).
fn search(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
) -> Result<(SearchState, Vec<Vec<usize>>)> {
    let profiles = profile_enc_points(model, images, cfg.max_samples)?;
    anyhow::ensure!(
        !profiles.is_empty(),
        "model {:?} has no enc points to tune (no quantized convs)",
        model.name
    );

    let total_macs: f64 = profiles.iter().map(|p| p.macs as f64).sum();
    let weight: Vec<f64> = profiles
        .iter()
        .map(|p| p.macs as f64 / total_macs)
        .collect();

    // equivalent-activation weight-error terms per (enc, effective width)
    let wlist = cfg.space.weight_bits_or_default();
    for &w in &wlist {
        // match the engine's servable range up front, so the tuner can
        // never emit a plan that fails on every `plan:` request
        anyhow::ensure!(
            w == WBITS_DEFAULT || (2..=8).contains(&w),
            "weight bitwidth {w} in the candidate space is outside the \
             engine's supported range (0 = default, or 2..=8)"
        );
    }
    let mean_sq: Vec<f64> = profiles
        .iter()
        .map(|p| {
            let n = p.samples.len().max(1) as f64;
            p.samples.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n
        })
        .collect();
    let wterm_at = |enc: usize, w: u32| -> f64 {
        mean_sq[enc] * model.engine.weight_quant_rel_mse(enc, effective_wbits(w))
    };

    // score baselines (default weights, at their own weight term so the
    // comparison against explicit-W8 candidates is apples-to-apples)
    let baselines: Vec<ScoredCandidate> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| {
            score_candidate_w(
                p,
                &cfg.baseline,
                cfg.clip,
                WBITS_DEFAULT,
                wterm_at(i, WBITS_DEFAULT),
            )
        })
        .collect();
    // static prune input: the analyzer's quant-track activation bound
    // per enc point, walked under the *baseline* capacities (the plan
    // the tuner must beat). Models without affine bounds — or with an
    // enc-point count the profiles disagree on — just skip the prune.
    let static_hi: Option<Vec<f64>> = GraphBounds::from_model(model)
        .ok()
        .filter(|gb| gb.num_enc_points() == profiles.len())
        .map(|gb| {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in &images.data {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            let input = if lo.is_finite() && hi.is_finite() && lo <= hi {
                Interval::new(lo.min(0.0) as f64, hi as f64)
            } else {
                crate::analysis::absint::DEFAULT_INPUT_RANGE
            };
            let caps: Vec<f64> = baselines
                .iter()
                .map(|b| {
                    let r = if b.cfg.range_overwrite {
                        let bb = b.cfg.b() as f64;
                        bb * bb - 1.0
                    } else {
                        b.cfg.qmax() as f64
                    };
                    r * b.scale as f64
                })
                .collect();
            gb.quant_track_hi(input, &caps)
        });

    let mut pruned_static = 0usize;
    let fronts: Vec<Vec<ScoredCandidate>> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let wterm: Vec<(u32, f64)> =
                wlist.iter().map(|&w| (w, wterm_at(i, w))).collect();
            frontier(
                p,
                &cfg.space,
                cfg.clip,
                &baselines[i],
                &wterm,
                static_hi.as_ref().map(|v| v[i]),
                &mut pruned_static,
            )
        })
        .collect();

    let baseline_cov: Vec<f64> = profiles
        .iter()
        .zip(&baselines)
        .map(|(p, b)| coverage_stats(&p.tap, b.scale, &cfg.baseline).coverage())
        .collect();
    let baseline_area: f64 = baselines
        .iter()
        .zip(&weight)
        .map(|(b, w)| w * b.area)
        .sum();
    let budget = cfg.budget_area.unwrap_or(baseline_area);

    // greedy: start at each frontier's min-area point, then repeatedly
    // take the upgrade with the best error reduction per weighted µm²
    let mut idx = vec![0usize; fronts.len()];
    let mut total_area: f64 = fronts
        .iter()
        .zip(&weight)
        .map(|(f, w)| w * f[0].area)
        .sum();
    let mut history = vec![idx.clone()];
    loop {
        let mut best: Option<(usize, f64)> = None; // (layer, gain/cost)
        for (l, front) in fronts.iter().enumerate() {
            if idx[l] + 1 >= front.len() {
                continue;
            }
            let (cur, nxt) = (&front[idx[l]], &front[idx[l] + 1]);
            let d_area = (nxt.area - cur.area) * weight[l];
            if total_area + d_area > budget + 1e-9 {
                continue;
            }
            let d_err = (cur.pred_err - nxt.pred_err) * weight[l];
            // frontier ⇒ d_area > 0 and d_err > 0
            let ratio = d_err / d_area.max(1e-12);
            if best.map(|(_, r)| ratio > r).unwrap_or(true) {
                best = Some((l, ratio));
            }
        }
        let Some((l, _)) = best else { break };
        total_area += (fronts[l][idx[l] + 1].area - fronts[l][idx[l]].area) * weight[l];
        idx[l] += 1;
        history.push(idx.clone());
    }

    Ok((
        SearchState {
            profiles,
            baselines,
            fronts,
            weight,
            baseline_cov,
            baseline_area,
            budget,
            pruned_static,
        },
        history,
    ))
}

/// MAC-weighted mean predicted error of one frontier-index state.
fn proxy_err(st: &SearchState, idx: &[usize]) -> f64 {
    st.fronts
        .iter()
        .zip(idx)
        .zip(&st.weight)
        .map(|((f, &i), w)| w * f[i].pred_err)
        .sum()
}

/// MAC-weighted mean PE area of one frontier-index state.
fn state_area(st: &SearchState, idx: &[usize]) -> f64 {
    st.fronts
        .iter()
        .zip(idx)
        .zip(&st.weight)
        .map(|((f, &i), w)| w * f[i].area)
        .sum()
}

/// Measure coverage of one frontier-index state on the profiling taps
/// (memoized per choice in `cov`) and emit the per-layer choices +
/// deployment plan.
fn emit_plan(
    st: &SearchState,
    idx: &[usize],
    name: &str,
    model_name: &str,
    cov: &mut CovCache,
) -> (Vec<LayerChoice>, DeploymentPlan) {
    let mut layers = Vec::with_capacity(st.profiles.len());
    for (l, p) in st.profiles.iter().enumerate() {
        let chosen = st.fronts[l][idx[l]];
        let measured_cov = *cov
            .entry((l, idx[l]))
            .or_insert_with(|| coverage_stats(&p.tap, chosen.scale, &chosen.cfg).coverage());
        layers.push(LayerChoice {
            enc: p.enc,
            chosen,
            baseline: st.baselines[l],
            measured_cov,
            baseline_measured_cov: st.baseline_cov[l],
            p0: p.p0,
            macs: p.macs,
        });
    }

    // the baseline's outlier-weighted mean coverage (the plan's own is
    // derived by DeploymentPlan::from_layers, which owns the convention:
    // layers with no outliers count as fully covered but carry no weight)
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for lc in &layers {
        num += lc.baseline_measured_cov * lc.baseline.outlier_rate * lc.macs as f64;
        den += lc.baseline.outlier_rate * lc.macs as f64;
    }
    let baseline_coverage = if den > 0.0 { num / den } else { 1.0 };

    let plan = DeploymentPlan::from_layers(
        name,
        model_name,
        layers
            .iter()
            .zip(&st.profiles)
            .map(|(lc, p)| PlanLayer {
                enc: lc.enc,
                overq: lc.chosen.cfg,
                scale: lc.chosen.scale,
                wbits: lc.chosen.wbits,
                p0: lc.p0,
                outlier_rate: lc.chosen.outlier_rate,
                theory_coverage: lc.chosen.theory_cov,
                measured_coverage: lc.measured_cov,
                area: lc.chosen.area,
                macs: lc.macs,
                // profile-time drift baseline: what the live telemetry
                // compares per-enc mean/var/clip-rate against
                drift: Some(crate::obs::counters::DriftBaseline {
                    mean: p.stats.mean as f64,
                    var: (p.stats.std as f64).powi(2),
                    clip_rate: lc.chosen.outlier_rate,
                }),
            })
            .collect(),
        st.baseline_area,
        baseline_coverage,
    );
    (layers, plan)
}

/// Run the proxy-only autotuner: profile, search, measure, emit a plan.
/// This is stage 1 of the pipeline; [`autotune_measured`] adds the
/// accuracy-refinement stage on a probe split.
pub fn autotune(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
) -> Result<AutotuneResult> {
    let (st, history) = search(model, images, cfg)?;
    let idx = history.last().unwrap();
    let name = cfg
        .plan_name
        .clone()
        .unwrap_or_else(|| format!("{}-auto", model.name));
    let (layers, plan) = emit_plan(&st, idx, &name, &model.name, &mut CovCache::new());
    self_lint(&plan, model, images)?;
    Ok(AutotuneResult {
        layers,
        total_area: state_area(&st, idx),
        baseline_area: st.baseline_area,
        pruned_static: st.pruned_static,
        plan,
    })
}

/// The tuner lints its own output before handing it to callers: an
/// Error-level finding here is a tuner bug (the serving layer would
/// refuse the plan anyway), so fail loudly at emission instead of at
/// registration. Warnings pass through — `overq lint` reports them.
fn self_lint(plan: &DeploymentPlan, model: &LoadedModel, images: &TensorF) -> Result<()> {
    let report = crate::analysis::lint_plan_with_model(plan, model, &images.dims()[1..]);
    if let Some(d) = report.first_error() {
        anyhow::bail!("autotuner emitted a plan that fails lint (tuner bug): {d}");
    }
    Ok(())
}

/// Run the full two-stage autotuner: stage-1 greedy search, then
/// re-score the top-K snapshot plans of the greedy upgrade path with
/// measured accuracy on `probe` and return the best measured plan
/// (never worse on the probe than the proxy-only plan, which is always
/// candidate 0).
pub fn autotune_measured(
    model: &LoadedModel,
    images: &TensorF,
    probe: &ProbeSplit,
    cfg: &AutotuneConfig,
) -> Result<MeasuredAutotune> {
    let (st, history) = search(model, images, cfg)?;
    let steps = history.len() - 1;
    let name = cfg
        .plan_name
        .clone()
        .unwrap_or_else(|| format!("{}-auto", model.name));

    // snapshot picks along the greedy path: the proxy-optimal endpoint
    // first, then evenly spaced back to the halfway state — cheaper
    // plans the proxy liked less, for the measured ranking to arbitrate
    let k = cfg.topk.max(1);
    let mut picks: Vec<usize> = vec![steps];
    if k > 1 && steps > 0 {
        let lo = steps / 2;
        for j in 1..k {
            picks.push(steps - (steps - lo) * j / (k - 1));
        }
    }
    picks.dedup();

    let batch = probe.len().clamp(1, 64);
    let mut candidates: Vec<RefinedCandidate> = Vec::with_capacity(picks.len());
    let mut cand_layers: Vec<Vec<LayerChoice>> = Vec::with_capacity(picks.len());
    let mut cov = CovCache::new(); // snapshots share most choices
    for &s in &picks {
        let cand_name = if s == steps {
            name.clone()
        } else {
            format!("{name}-g{s}")
        };
        let (layers, plan) =
            emit_plan(&st, &history[s], &cand_name, &model.name, &mut cov);
        let acc = model
            .engine
            .accuracy_quant(&probe.images, &probe.labels, batch, &plan.to_quant_config())
            .with_context(|| format!("probe accuracy of candidate {cand_name:?}"))?;
        candidates.push(RefinedCandidate {
            plan,
            proxy_err: proxy_err(&st, &history[s]),
            measured_acc: acc,
            greedy_step: s,
        });
        cand_layers.push(layers);
    }

    // the control arm: every layer pinned to the global baseline config
    let baseline_qc = QuantConfig {
        layers: st
            .baselines
            .iter()
            .map(|b| LayerQuant {
                overq: b.cfg,
                scale: b.scale,
                wbits: WBITS_DEFAULT,
            })
            .collect(),
    };
    let baseline_acc = model
        .engine
        .accuracy_quant(&probe.images, &probe.labels, batch, &baseline_qc)
        .context("probe accuracy of the baseline config")?;

    // pick the budget-feasible plan with the best measured accuracy;
    // ties break toward lower area, then lower proxy error. Starting
    // from candidates[0] (the proxy-only plan) guarantees the winner's
    // measured accuracy is ≥ the proxy-only plan's.
    let mut chosen = 0usize;
    for (i, c) in candidates.iter().enumerate().skip(1) {
        if c.plan.total_area > st.budget + 1e-9 {
            continue;
        }
        let best = &candidates[chosen];
        let better = c.measured_acc > best.measured_acc + 1e-12
            || ((c.measured_acc - best.measured_acc).abs() <= 1e-12
                && (c.plan.total_area < best.plan.total_area - 1e-9
                    || ((c.plan.total_area - best.plan.total_area).abs() <= 1e-9
                        && c.proxy_err < best.proxy_err)));
        if better {
            chosen = i;
        }
    }

    let proxy_acc = candidates[0].measured_acc;
    let errs: Vec<f64> = candidates.iter().map(|c| c.proxy_err).collect();
    let neg_accs: Vec<f64> = candidates.iter().map(|c| -c.measured_acc).collect();
    let rank_agreement = spearman(&errs, &neg_accs);

    // the winner was already emitted and measured above: rename it to
    // the final plan name and attach the probe evidence (no second
    // coverage pass over the taps)
    let win_step = candidates[chosen].greedy_step;
    let mut plan = candidates[chosen].plan.clone();
    plan.name = name;
    plan.probe = Some(ProbeEvidence {
        images: probe.len(),
        accuracy: candidates[chosen].measured_acc,
        baseline_accuracy: baseline_acc,
    });
    self_lint(&plan, model, images)?;
    let result = AutotuneResult {
        layers: cand_layers[chosen].clone(),
        total_area: state_area(&st, &history[win_step]),
        baseline_area: st.baseline_area,
        pruned_static: st.pruned_static,
        plan,
    };
    Ok(MeasuredAutotune {
        result,
        candidates,
        chosen,
        proxy_acc,
        baseline_acc,
        rank_agreement,
        probe_images: probe.len(),
    })
}

/// Spearman rank correlation (average ranks for ties); 1.0 for inputs
/// too short or too degenerate to disagree. Used to report how well the
/// stage-1 proxy ranking agreed with the measured-accuracy ranking.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spearman needs paired samples");
    if a.len() < 2 {
        return 1.0;
    }
    let (ra, rb) = (ranks(a), ranks(b));
    let n = ra.len() as f64;
    let mean = (n + 1.0) / 2.0;
    let (mut num, mut da, mut db) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in ra.iter().zip(&rb) {
        num += (x - mean) * (y - mean);
        da += (x - mean) * (x - mean);
        db += (y - mean) * (y - mean);
    }
    let den = (da * db).sqrt();
    if den <= 0.0 {
        1.0 // all-tied on one side: nothing to disagree about
    } else {
        num / den
    }
}

/// Average ranks (1-based) with ties shared.
fn ranks(x: &[f64]) -> Vec<f64> {
    let mut order: Vec<usize> = (0..x.len()).collect();
    order.sort_by(|&i, &j| x[i].partial_cmp(&x[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut r = vec![0.0; x.len()];
    let mut i = 0;
    while i < order.len() {
        let mut j = i + 1;
        while j < order.len() && x[order[j]] == x[order[i]] {
            j += 1;
        }
        let avg = (i + j - 1) as f64 / 2.0 + 1.0;
        for &k in &order[i..j] {
            r[k] = avg;
        }
        i = j;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spearman_basics() {
        assert!((spearman(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]) - 1.0).abs() < 1e-12);
        assert!((spearman(&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        // ties get average ranks; a single pair is trivially "agreed"
        assert_eq!(spearman(&[1.0], &[2.0]), 1.0);
        assert_eq!(spearman(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 1.0);
    }
}
