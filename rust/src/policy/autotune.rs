//! Coverage-driven greedy Pareto search over per-layer OverQ configs.
//!
//! For every enc point the tuner scores each candidate config with a
//! fast analytic proxy — Eq. (1) `theory_coverage` for the outlier term
//! plus uniform-quantizer rounding error — and keeps the per-layer
//! Pareto frontier over (PE area, predicted error). A global greedy pass
//! then walks the frontiers, spending an area budget where it buys the
//! largest error reduction per µm², with cost weighted by each layer's
//! MAC share (the PE array is shared temporally, so the deployment cost
//! of a layer's config is area × occupancy). Final choices are validated
//! with *measured* coverage (`overq::coverage_stats`) on the profiling
//! taps, which is what lands in the emitted [`DeploymentPlan`].

use anyhow::Result;

use crate::models::zoo::LoadedModel;
use crate::overq::{coverage_stats, theory_coverage, OverQConfig};
use crate::quant::clip::ClipMethod;
use crate::tensor::TensorF;

use super::candidates::{pe_area, CandidateSpace};
use super::plan::{DeploymentPlan, PlanLayer};
use super::profile::{profile_enc_points, EncPointProfile};

/// Autotuner knobs.
#[derive(Clone, Debug)]
pub struct AutotuneConfig {
    /// Candidate search space.
    pub space: CandidateSpace,
    /// Clip-threshold method used to derive each candidate's scale.
    pub clip: ClipMethod,
    /// Global config the plan must beat (coverage) at ≤ its area.
    pub baseline: OverQConfig,
    /// MAC-weighted mean PE-area budget (µm²). `None` = the baseline's
    /// own area, i.e. "equal or lower total PE area".
    pub budget_area: Option<f64>,
    /// Max profiled values per enc point for proxy scoring.
    pub max_samples: usize,
    /// Plan name to emit (defaults to `<model>-auto`).
    pub plan_name: Option<String>,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            space: CandidateSpace::default(),
            clip: ClipMethod::StdMul(4.0),
            baseline: OverQConfig::full(4, 4),
            budget_area: None,
            max_samples: 4096,
            plan_name: None,
        }
    }
}

/// One scored candidate at one enc point.
#[derive(Clone, Copy, Debug)]
pub struct ScoredCandidate {
    pub cfg: OverQConfig,
    /// Activation scale (clip / qmax at `cfg.bits`).
    pub scale: f32,
    /// PE area (µm²) from the Table-3 model.
    pub area: f64,
    /// Predicted mean squared activation error on the profile samples.
    pub pred_err: f64,
    /// Eq. (1) coverage (0 when RO is off).
    pub theory_cov: f64,
    /// Outlier fraction of the samples at this candidate's scale.
    pub outlier_rate: f64,
}

/// The tuner's decision for one enc point.
#[derive(Clone, Debug)]
pub struct LayerChoice {
    pub enc: usize,
    pub chosen: ScoredCandidate,
    /// The global baseline config scored at this layer.
    pub baseline: ScoredCandidate,
    /// Measured coverage of the chosen config on the profiling tap.
    pub measured_cov: f64,
    /// Measured coverage of the baseline config on the profiling tap.
    pub baseline_measured_cov: f64,
    pub p0: f64,
    pub macs: u64,
}

/// Full autotune output: per-layer choices + the emitted plan.
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    pub layers: Vec<LayerChoice>,
    /// MAC-weighted mean PE area of the plan.
    pub total_area: f64,
    /// MAC-weighted mean PE area of the global baseline.
    pub baseline_area: f64,
    pub plan: DeploymentPlan,
}

/// Score one candidate on one enc point's samples.
///
/// Error model per sample x (scale s, step s, fine step s/B):
/// * exact zero          → 0
/// * in-range value      → s²/12, or (s/B)²/12 with probability `p0`
///                         when PR can park LSBs in a neighboring zero
/// * outlier             → covered (prob. Eq. 1, RO only): rounding at
///                         step s in the widened range, clamped at B²-1;
///                         uncovered: clamp error against qmax·s
pub fn score_candidate(
    prof: &EncPointProfile,
    cfg: &OverQConfig,
    clip: ClipMethod,
) -> ScoredCandidate {
    let qmax = cfg.qmax() as f32;
    let clip_v = clip.clip(&prof.samples, prof.stats, cfg.bits).max(1e-6);
    let scale = clip_v / qmax;
    let cov = if cfg.range_overwrite {
        theory_coverage(prof.p0, cfg.cascade)
    } else {
        0.0
    };
    let b = cfg.b() as f32;
    let wide_max = (b * b - 1.0) * scale;
    let step_sq = (scale as f64).powi(2) / 12.0;
    let fine_sq = step_sq / (b as f64 * b as f64);
    let mut err = 0.0f64;
    let mut outliers = 0usize;
    for &x in &prof.samples {
        if x == 0.0 {
            continue;
        }
        let v = (x / scale + 0.5).floor();
        if v > qmax {
            outliers += 1;
            let covered = if x > wide_max {
                ((x - wide_max) as f64).powi(2)
            } else {
                step_sq
            };
            let clamped = ((x - qmax * scale) as f64).powi(2);
            err += cov * covered + (1.0 - cov) * clamped;
        } else if cfg.precision_overwrite {
            err += prof.p0 * fine_sq + (1.0 - prof.p0) * step_sq;
        } else {
            err += step_sq;
        }
    }
    let n = prof.samples.len().max(1) as f64;
    ScoredCandidate {
        cfg: *cfg,
        scale,
        area: pe_area(cfg),
        pred_err: err / n,
        theory_cov: cov,
        outlier_rate: outliers as f64 / n,
    }
}

/// Per-layer Pareto frontier over (area ↑, pred_err ↓), keeping only
/// candidates whose coverage cannot fall below the baseline's: either
/// they provably produce no outliers on the whole tap (the profiled max
/// rounds inside the code range), or RO is on with theory coverage ≥
/// the baseline's at this layer.
fn frontier(
    prof: &EncPointProfile,
    space: &CandidateSpace,
    clip: ClipMethod,
    baseline: &ScoredCandidate,
) -> Vec<ScoredCandidate> {
    let mut scored: Vec<ScoredCandidate> = space
        .enumerate()
        .iter()
        .map(|c| score_candidate(prof, c, clip))
        .filter(|s| {
            let outlier_free =
                prof.stats.max < (s.cfg.qmax() as f32 + 0.5) * s.scale;
            outlier_free || s.theory_cov >= baseline.theory_cov - 1e-12
        })
        .collect();
    // the baseline itself is always admissible, so the frontier (and the
    // min-area start point) can never exceed the baseline's area
    scored.push(*baseline);
    scored.sort_by(|a, b| {
        a.area
            .partial_cmp(&b.area)
            .unwrap()
            .then(a.pred_err.partial_cmp(&b.pred_err).unwrap())
    });
    let mut front: Vec<ScoredCandidate> = Vec::new();
    for s in scored {
        match front.last() {
            Some(last) if s.area == last.area => continue, // kept cheaper-err already
            Some(last) if s.pred_err >= last.pred_err => continue, // dominated
            _ => front.push(s),
        }
    }
    front
}

/// Run the autotuner: profile, search, measure, emit a plan.
pub fn autotune(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
) -> Result<AutotuneResult> {
    let profiles = profile_enc_points(model, images, cfg.max_samples)?;
    anyhow::ensure!(!profiles.is_empty(), "model has no enc points to tune");

    let total_macs: f64 = profiles.iter().map(|p| p.macs as f64).sum();
    let weight = |p: &EncPointProfile| p.macs as f64 / total_macs;

    // score baselines + build frontiers
    let baselines: Vec<ScoredCandidate> = profiles
        .iter()
        .map(|p| score_candidate(p, &cfg.baseline, cfg.clip))
        .collect();
    let fronts: Vec<Vec<ScoredCandidate>> = profiles
        .iter()
        .zip(&baselines)
        .map(|(p, b)| frontier(p, &cfg.space, cfg.clip, b))
        .collect();

    let baseline_area: f64 = profiles
        .iter()
        .zip(&baselines)
        .map(|(p, b)| weight(p) * b.area)
        .sum();
    let budget = cfg.budget_area.unwrap_or(baseline_area);

    // greedy: start at each frontier's min-area point, then repeatedly
    // take the upgrade with the best error reduction per weighted µm²
    let mut idx = vec![0usize; fronts.len()];
    let mut total_area: f64 = fronts
        .iter()
        .zip(&profiles)
        .map(|(f, p)| weight(p) * f[0].area)
        .sum();
    loop {
        let mut best: Option<(usize, f64)> = None; // (layer, gain/cost)
        for (l, front) in fronts.iter().enumerate() {
            if idx[l] + 1 >= front.len() {
                continue;
            }
            let (cur, nxt) = (&front[idx[l]], &front[idx[l] + 1]);
            let w = weight(&profiles[l]);
            let d_area = (nxt.area - cur.area) * w;
            if total_area + d_area > budget + 1e-9 {
                continue;
            }
            let d_err = (cur.pred_err - nxt.pred_err) * w;
            // frontier ⇒ d_area > 0 and d_err > 0
            let ratio = d_err / d_area.max(1e-12);
            if best.map(|(_, r)| ratio > r).unwrap_or(true) {
                best = Some((l, ratio));
            }
        }
        let Some((l, _)) = best else { break };
        let w = weight(&profiles[l]);
        total_area += (fronts[l][idx[l] + 1].area - fronts[l][idx[l]].area) * w;
        idx[l] += 1;
    }

    // measure coverage of the final choices (and baseline) on the taps
    let mut layers = Vec::with_capacity(profiles.len());
    for (l, p) in profiles.iter().enumerate() {
        let chosen = fronts[l][idx[l]];
        let m = coverage_stats(&p.tap, chosen.scale, &chosen.cfg);
        let mb = coverage_stats(&p.tap, baselines[l].scale, &cfg.baseline);
        layers.push(LayerChoice {
            enc: p.enc,
            chosen,
            baseline: baselines[l],
            measured_cov: m.coverage(),
            baseline_measured_cov: mb.coverage(),
            p0: p.p0,
            macs: p.macs,
        });
    }

    // the baseline's outlier-weighted mean coverage (the plan's own is
    // derived by DeploymentPlan::from_layers, which owns the convention:
    // layers with no outliers count as fully covered but carry no weight)
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for lc in &layers {
        num += lc.baseline_measured_cov * lc.baseline.outlier_rate * lc.macs as f64;
        den += lc.baseline.outlier_rate * lc.macs as f64;
    }
    let baseline_coverage = if den > 0.0 { num / den } else { 1.0 };

    let name = cfg
        .plan_name
        .clone()
        .unwrap_or_else(|| format!("{}-auto", model.name));
    let plan = DeploymentPlan::from_layers(
        &name,
        &model.name,
        layers
            .iter()
            .map(|lc| PlanLayer {
                enc: lc.enc,
                overq: lc.chosen.cfg,
                scale: lc.chosen.scale,
                p0: lc.p0,
                outlier_rate: lc.chosen.outlier_rate,
                theory_coverage: lc.chosen.theory_cov,
                measured_coverage: lc.measured_cov,
                area: lc.chosen.area,
                macs: lc.macs,
            })
            .collect(),
        baseline_area,
        baseline_coverage,
    );
    Ok(AutotuneResult {
        layers,
        total_area,
        baseline_area,
        plan,
    })
}
