//! Candidate OverQ configurations and their PE-area cost.
//!
//! The search space is the cross product of activation bitwidths and
//! OverQ modes (baseline / RO at cascade 1..c / full at cascade 1..c,
//! plus PR-only). "Cascade 0" in the issue's notation — no range
//! overwrite at all — is the baseline/PR-only candidates here, since the
//! crate encodes adjacent-only RO as `cascade = 1`.

use crate::area::{pe_breakdown_w, PeVariant};
use crate::nn::WBITS_DEFAULT;
use crate::overq::OverQConfig;

/// Search space knobs for the autotuner.
#[derive(Clone, Debug)]
pub struct CandidateSpace {
    /// Activation bitwidths to consider.
    pub bits: Vec<u32>,
    /// Cascade factors for RO/full candidates (1 = adjacent-only).
    pub cascades: Vec<usize>,
    /// Weight bitwidths to consider per layer. [`WBITS_DEFAULT`] (0)
    /// means the engine's prepared 8-bit weights — the only entry by
    /// default, which keeps the proxy-only search weight-blind like the
    /// paper's. Adding explicit widths (e.g. `[4, 6, 8]`) opens the
    /// weight side of the area/error frontier.
    pub weight_bits: Vec<u32>,
    /// Allow range-overwrite candidates.
    pub allow_ro: bool,
    /// Allow precision-overwrite candidates.
    pub allow_pr: bool,
}

impl Default for CandidateSpace {
    fn default() -> Self {
        CandidateSpace {
            bits: vec![3, 4, 5, 8],
            cascades: vec![1, 2, 3, 4],
            weight_bits: vec![WBITS_DEFAULT],
            allow_ro: true,
            allow_pr: true,
        }
    }
}

impl CandidateSpace {
    /// The weight-bitwidth axis, normalized: empty means "default only".
    pub fn weight_bits_or_default(&self) -> Vec<u32> {
        if self.weight_bits.is_empty() {
            vec![WBITS_DEFAULT]
        } else {
            self.weight_bits.clone()
        }
    }

    /// Enumerate every candidate configuration in the space.
    pub fn enumerate(&self) -> Vec<OverQConfig> {
        let mut out = Vec::new();
        for &bits in &self.bits {
            out.push(OverQConfig::baseline(bits));
            if self.allow_pr {
                // PR-only: precision overwrite without range overwrite
                out.push(OverQConfig {
                    bits,
                    cascade: 1,
                    range_overwrite: false,
                    precision_overwrite: true,
                });
            }
            for &c in &self.cascades {
                if self.allow_ro {
                    out.push(OverQConfig::ro(bits, c));
                    if self.allow_pr {
                        out.push(OverQConfig::full(bits, c));
                    }
                }
            }
        }
        out
    }
}

/// The PE flavour a config requires (which Table-3 column it pays for).
pub fn pe_variant(cfg: &OverQConfig) -> PeVariant {
    match (cfg.range_overwrite, cfg.precision_overwrite) {
        (false, false) => PeVariant::Baseline,
        (true, false) => PeVariant::OverQRo,
        // PR needs the 2-bit state lane and both shift directions even
        // without RO, so it pays the full-PE area.
        _ => PeVariant::OverQFull,
    }
}

/// The weight bitwidth a [`WBITS_DEFAULT`]-or-explicit value resolves
/// to on hardware (the prepared default weights are 8-bit).
pub fn effective_wbits(wbits: u32) -> u32 {
    if wbits == WBITS_DEFAULT {
        8
    } else {
        wbits
    }
}

/// Total PE area (µm²) a config costs at the default (8-bit) weight
/// datapath, from the Table-3 model.
pub fn pe_area(cfg: &OverQConfig) -> f64 {
    pe_area_w(cfg, WBITS_DEFAULT)
}

/// Total PE area (µm²) a config costs at an explicit weight bitwidth
/// ([`WBITS_DEFAULT`] = the 8-bit prepared-weight datapath).
pub fn pe_area_w(cfg: &OverQConfig, wbits: u32) -> f64 {
    pe_breakdown_w(pe_variant(cfg), cfg.bits, effective_wbits(wbits)).total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_covers_modes() {
        let space = CandidateSpace::default();
        let all = space.enumerate();
        // 4 bits × (1 baseline + 1 pr-only + 4 ro + 4 full)
        assert_eq!(all.len(), 4 * 10);
        assert!(all.iter().any(|c| !c.range_overwrite && !c.precision_overwrite));
        assert!(all.iter().any(|c| c.range_overwrite && c.cascade == 4));
        assert!(all.iter().any(|c| !c.range_overwrite && c.precision_overwrite));
    }

    #[test]
    fn area_ordering() {
        // same bits: baseline < ro < full; more bits: bigger PE
        let b = pe_area(&OverQConfig::baseline(4));
        let ro = pe_area(&OverQConfig::ro(4, 4));
        let full = pe_area(&OverQConfig::full(4, 4));
        assert!(b < ro && ro < full);
        assert!(pe_area(&OverQConfig::baseline(8)) > b);
        // cascade factor is a rescale-unit property, not a PE property
        assert_eq!(pe_area(&OverQConfig::ro(4, 1)), pe_area(&OverQConfig::ro(4, 4)));
    }

    #[test]
    fn weight_bits_area_axis() {
        let cfg = OverQConfig::full(4, 4);
        // default (0) resolves to the 8-bit datapath
        assert_eq!(pe_area_w(&cfg, 0), pe_area_w(&cfg, 8));
        assert_eq!(pe_area(&cfg), pe_area_w(&cfg, 8));
        // narrower weights shrink the PE monotonically
        assert!(pe_area_w(&cfg, 4) < pe_area_w(&cfg, 6));
        assert!(pe_area_w(&cfg, 6) < pe_area_w(&cfg, 8));
        assert_eq!(effective_wbits(0), 8);
        assert_eq!(effective_wbits(5), 5);
        // normalization: empty axis means default-only
        let mut space = CandidateSpace::default();
        space.weight_bits.clear();
        assert_eq!(space.weight_bits_or_default(), vec![WBITS_DEFAULT]);
    }

    #[test]
    fn restricted_space() {
        let space = CandidateSpace {
            bits: vec![4],
            cascades: vec![1, 2],
            weight_bits: vec![WBITS_DEFAULT],
            allow_ro: true,
            allow_pr: false,
        };
        let all = space.enumerate();
        assert_eq!(all.len(), 3); // baseline + ro(1) + ro(2)
        assert!(all.iter().all(|c| !c.precision_overwrite));
    }
}
