//! # OverQ — Opportunistic Outlier Quantization for Neural Network Accelerators
//!
//! Production reproduction of Zhao et al., *"OverQ: Opportunistic Outlier
//! Quantization for Neural Network Accelerators"*. This crate is the L3
//! (rust) layer of a three-layer Rust + JAX + Pallas stack:
//!
//! * [`overq`] — the paper's contribution: range/precision overwrite
//!   encoding with cascading, coverage analysis, and the overwrite dot
//!   product (DESIGN.md §7 is the normative spec).
//! * [`quant`] — post-training quantization substrate: uniform affine
//!   quantizers, MMSE / percentile / KL / STD-sweep clipping, OCS weight
//!   splitting and a ZeroQ-style data-free calibrator.
//! * [`nn`] + [`models`] — a native int8/fp32 inference engine that
//!   executes the graph IR exported by `python/compile/model.py`,
//!   bit-exact with the JAX/Pallas path on codes and states.
//! * [`policy`] — the per-layer policy engine: a coverage-driven
//!   mixed-precision autotuner that picks (bits, cascade, RO/PR) per enc
//!   point under a PE-area budget and emits serializable
//!   [`policy::DeploymentPlan`]s the serving layer runs as
//!   `plan:<name>` variants.
//! * [`sim`] — cycle-level weight-stationary systolic-array simulator
//!   with baseline and OverQ processing elements.
//! * [`area`] — parametric ASIC area model reproducing Table 3.
//! * [`olaccel`] — OLAccel-style outlier-accelerator comparator.
//! * [`runtime`] — PJRT client (via the `xla` crate) that loads the AOT
//!   HLO artifacts produced by `python/compile/aot.py`.
//! * [`obs`] — dependency-free telemetry: structured tracing spans,
//!   OverQ-native coverage/drift counters, and exact log-bucketed
//!   histograms; exported as Prometheus text and JSONL traces
//!   (docs/observability.md).
//! * [`analysis`] — the `overq lint` static analyzer: a diagnostics
//!   framework with stable codes (`OQ001..`) and a rule engine that
//!   checks deployment plans against the model graph and the hardware
//!   model; every plan boundary (register, watch, autotune) gates on it.
//! * [`coordinator`] — the serving layer: request router, dynamic
//!   batcher and worker pool over compiled executables, plus the
//!   closed-loop plan operations: outcome-aware bandit routing
//!   ([`coordinator::router::BanditRouter`]) and plan hot-reload from
//!   disk ([`coordinator::watch`]).
//! * [`harness`] — experiment drivers regenerating every table/figure of
//!   the paper (Table 1-3, Figure 6a/6b) plus the hardware comparison.
//! * [`util`] — offline-registry substitutes: deterministic RNG, JSON,
//!   CLI parsing, property-testing and benchmarking helpers.
//!
//! Python never runs on the request path: `make artifacts` AOT-compiles
//! the models once; the rust binary is self-contained afterwards.

// CI denies warnings under clippy. Lint opt-outs are per-module (on the
// `pub mod` items below) so a new module starts from a clean slate
// instead of inheriting the numeric kernels' exemptions crate-wide.
pub mod analysis;
pub mod area;
// serving plumbing: wide builder signatures, shared-state field types
#[allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::field_reassign_with_default
)]
pub mod coordinator;
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
pub mod data;
// harness configs use the `cfg.field = ...` override-after-default style
#[allow(clippy::needless_range_loop, clippy::field_reassign_with_default)]
pub mod harness;
#[allow(clippy::needless_range_loop, clippy::manual_memcpy)]
pub mod io;
pub mod models;
// numeric kernels below deliberately favor explicit index loops and
// wide argument lists; the lints stay scoped to them
#[allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]
pub mod nn;
pub mod obs;
pub mod olaccel;
#[allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy
)]
pub mod overq;
pub mod policy;
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
pub mod quant;
pub mod runtime;
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
pub mod sim;
#[allow(clippy::manual_memcpy)]
pub mod tensor;
#[allow(clippy::needless_range_loop, clippy::new_without_default)]
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
