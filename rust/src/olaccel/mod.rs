//! OLAccel comparator (Park et al., ISCA 2018) — the prior
//! specialized-hardware approach OverQ is contrasted against (Fig. 2).
//!
//! OLAccel routes outliers to a *separate sparse 16-bit PE* while the
//! dense array runs at low precision. Functionally, outliers keep
//! (nearly) full precision; the costs are (1) extra 16-bit MAC units,
//! (2) 32 bits of index storage per outlier, and (3) sparse-engine
//! scheduling. This module models the functional accuracy path and the
//! area/storage cost, for the hardware-comparison bench.

use crate::area::{pe_breakdown, PeVariant};
use crate::tensor::TensorF;

/// Functional model: activations quantized to `bits`, but values beyond
/// the clip (outliers) are kept at 16-bit precision by the sparse PE.
pub fn fakequant_olaccel(x: &TensorF, scale: f32, bits: u32) -> TensorF {
    let qmax = ((1u32 << bits) - 1) as f32;
    let inv = 1.0 / scale;
    // outlier path: 16-bit quantization over the full observed range
    let max = x.max_abs().max(1e-9);
    let s16 = max / ((1u32 << 16) - 1) as f32;
    let inv16 = 1.0 / s16;
    x.map(|v| {
        let q = (v * inv + 0.5).floor();
        if q > qmax {
            // handled by the sparse 16-bit PE
            (v * inv16 + 0.5).floor() * s16
        } else {
            q.max(0.0) * scale
        }
    })
}

/// Cost model for one layer's activations.
#[derive(Clone, Copy, Debug)]
pub struct OlaccelCost {
    /// Fraction of activations routed to the sparse PE.
    pub outlier_frac: f64,
    /// Index storage overhead in bits per activation tensor element.
    pub index_bits_per_elem: f64,
    /// Relative MAC-area overhead vs a baseline dense array of the same
    /// throughput (sparse 16-bit PEs sized for the outlier rate, plus a
    /// 2x provisioning factor for load imbalance).
    pub area_overhead: f64,
}

/// Compute the OLAccel cost model given the outlier fraction.
///
/// The sparse PE bank must sustain `outlier_frac` of the MAC throughput
/// at 16×8 precision; a 16-bit MAC is ~`ratio16` the area of the dense
/// low-bit MAC. The paper notes 32 bits of index per outlier.
pub fn cost_model(outlier_frac: f64, dense_bits: u32) -> OlaccelCost {
    let dense_pe = pe_breakdown(PeVariant::Baseline, dense_bits).total();
    let wide_pe = pe_breakdown(PeVariant::Baseline, 16).total();
    let ratio16 = wide_pe / dense_pe;
    const IMBALANCE_PROVISION: f64 = 2.0;
    OlaccelCost {
        outlier_frac,
        index_bits_per_elem: outlier_frac * 32.0,
        area_overhead: outlier_frac * ratio16 * IMBALANCE_PROVISION,
    }
}

/// OverQ's corresponding per-element storage overhead: the state lane
/// (1-2 bits per activation, paper §3.1).
pub fn overq_state_bits(pr_supported: bool) -> f64 {
    if pr_supported {
        2.0
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn outliers_keep_precision() {
        let x = TensorF::from_vec(&[1, 4], vec![0.1, 0.5, 3.0, 9.0]);
        let scale = 1.5 / 15.0; // clip at 1.5 → 3.0 and 9.0 are outliers
        let q = fakequant_olaccel(&x, scale, 4);
        assert!((q.data[2] - 3.0).abs() < 0.01);
        assert!((q.data[3] - 9.0).abs() < 0.01);
        // non-outliers see plain 4-bit error
        assert!((q.data[0] - 0.1).abs() <= scale / 2.0 + 1e-6);
    }

    #[test]
    fn cost_scales_with_outlier_rate() {
        let a = cost_model(0.01, 4);
        let b = cost_model(0.05, 4);
        assert!(b.area_overhead > a.area_overhead);
        assert!((a.index_bits_per_elem - 0.32).abs() < 1e-9);
        // OverQ state lane is far cheaper than OLAccel indices at
        // realistic outlier rates ≥ ~6 % … but costs 2 bits always:
        // crossover structure the hwcmp bench reports.
        assert!(overq_state_bits(true) < cost_model(0.1, 4).index_bits_per_elem);
    }

    #[test]
    fn olaccel_more_accurate_than_clipping() {
        let mut rng = Rng::new(4);
        let mut x = TensorF::zeros(&[10, 64]);
        for v in x.data.iter_mut() {
            *v = rng.normal().abs() * (if rng.bool(0.05) { 6.0 } else { 0.7 });
        }
        let scale = 1.0 / 15.0;
        let ol = fakequant_olaccel(&x, scale, 4);
        let qmax = 15.0;
        let e_clip: f64 = x
            .data
            .iter()
            .map(|&v| {
                let q = ((v / scale + 0.5).floor()).clamp(0.0, qmax) * scale;
                ((v - q) as f64).abs()
            })
            .sum();
        let e_ol: f64 = x
            .data
            .iter()
            .zip(&ol.data)
            .map(|(&a, &b)| ((a - b) as f64).abs())
            .sum();
        assert!(e_ol < e_clip * 0.8, "{e_ol} vs {e_clip}");
    }
}
