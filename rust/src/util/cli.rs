//! Tiny CLI argument parser — substitute for `clap`.
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first non-option token is the subcommand.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap().clone();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok.clone();
            } else {
                out.positional.push(tok.clone());
            }
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // bare `--flag value` is option-greedy; flags either trail or use
        // the explicit `--flag` + option-form convention
        let a = Args::parse(&v(&["serve", "x.json", "--port", "8080", "--verbose"]));
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["x.json"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&v(&["run", "--n=5", "--t=0.5"]));
        assert_eq!(a.get_usize("n", 0), 5);
        assert_eq!(a.get_f64("t", 0.0), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&v(&["x", "--quiet"]));
        assert!(a.flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&v(&["x"]));
        assert_eq!(a.get_or("mode", "fast"), "fast");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
