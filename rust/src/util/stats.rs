//! Small statistics helpers shared by harnesses, benches and the batcher.

use crate::obs::hist::Hist;

/// Online mean/variance/min/max accumulator (Welford), with percentiles
/// backed by an exact log-bucketed [`Hist`] — every sample ever added
/// is counted, so tail percentiles stay unbiased however long the
/// stream runs (the old capped reservoir under-weighted the tail once
/// it filled; see `obs::hist` for the error bound, ~4.4% worst case).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
    /// Exact log-bucketed histogram of the stream (percentile substrate,
    /// mergeable across shards via [`Hist::merge`]).
    hist: Hist,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.hist.add(x);
    }

    /// Percentile from the histogram: exact within one log bucket for
    /// every sample ever added. `p` in [0, 100]; 0.0 for an empty
    /// summary.
    pub fn percentile(&self, p: f64) -> f64 {
        self.hist.percentile(p)
    }

    /// The backing histogram (bucket export for exporters; merge across
    /// shards with [`Hist::merge`]).
    pub fn hist(&self) -> &Hist {
        &self.hist
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sorted copy (nearest-rank). p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 100.0).abs() < 1e-12);
        let var: f64 = xs.iter().map(|x| (x - 50.5).powi(2)).sum::<f64>() / 99.0;
        assert!((s.var() - var).abs() < 1e-9);
    }

    #[test]
    fn summary_percentiles_within_bucket_error() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.add(i as f64);
        }
        // extreme ranks are exact (clamped to observed min/max)
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 100.0);
        // interior ranks are exact within one log bucket (~±9%)
        let p50 = s.percentile(50.0);
        assert!((45.0..=55.0).contains(&p50), "p50 {p50}");
        let p95 = s.percentile(95.0);
        assert!((87.0..=100.0).contains(&p95), "p95 {p95}");
        assert_eq!(Summary::new().percentile(50.0), 0.0);
    }

    #[test]
    fn summary_histogram_counts_every_sample() {
        // the histogram never caps: a long stream keeps exact counts,
        // and the percentile reflects the whole stream (the reservoir
        // this replaced degraded to a sample once past its cap)
        let mut s = Summary::new();
        let n = 20_000usize;
        for i in 0..n {
            s.add((i % 1000) as f64);
        }
        assert_eq!(s.n, n as u64);
        assert_eq!(s.hist().count(), n as u64);
        let total: u64 = s.hist().buckets().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, n as u64, "histogram dropped samples");
        let p50 = s.percentile(50.0);
        assert!((450.0..=550.0).contains(&p50), "p50 {p50}");
        // deterministic: same stream, same answer
        let mut t = Summary::new();
        for i in 0..n {
            t.add((i % 1000) as f64);
        }
        assert_eq!(s.percentile(95.0), t.percentile(95.0));
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }
}
