//! Small statistics helpers shared by harnesses, benches and the batcher.

use super::rng::splitmix64;

/// Sample cap for [`Summary`]'s percentile reservoir.
const RESERVOIR_CAP: usize = 4096;

/// Online mean/variance/min/max accumulator (Welford), plus a bounded
/// deterministic reservoir so percentiles stay available at O(1) memory
/// however long the stream runs.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
    /// Uniform sample of the stream (algorithm R), capped at
    /// [`RESERVOIR_CAP`]. Deterministic in insertion order.
    samples: Vec<f64>,
    /// splitmix64 state driving reservoir replacement.
    rstate: u64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            // algorithm R; a full Rng would bloat every Summary, one
            // splitmix64 u64 of state is enough
            let j = (splitmix64(&mut self.rstate) % self.n) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = x;
            }
        }
    }

    /// Percentile estimate from the reservoir (exact while the stream is
    /// under the cap). `p` in [0, 100]; 0.0 for an empty summary.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        percentile(&self.samples, p)
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sorted copy (nearest-rank). p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 100.0).abs() < 1e-12);
        let var: f64 = xs.iter().map(|x| (x - 50.5).powi(2)).sum::<f64>() / 99.0;
        assert!((s.var() - var).abs() < 1e-9);
    }

    #[test]
    fn summary_percentiles_exact_under_cap() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(Summary::new().percentile(50.0), 0.0);
    }

    #[test]
    fn summary_reservoir_caps_and_stays_deterministic() {
        let run = || {
            let mut s = Summary::new();
            for i in 0..20_000 {
                s.add((i % 1000) as f64);
            }
            s
        };
        let (a, b) = (run(), run());
        assert!(a.samples.len() <= super::RESERVOIR_CAP);
        assert_eq!(a.samples, b.samples, "reservoir is not deterministic");
        // the sample of a uniform 0..1000 stream should put p50 mid-range
        let p50 = a.percentile(50.0);
        assert!((300.0..700.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }
}
