//! Property-testing driver — substitute for `proptest`.
//!
//! Runs a property over many seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use overq::util::prop::check;
//! check("sum commutes", 200, |rng| {
//!     let (a, b) = (rng.range(-100, 100), rng.range(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! (no_run: doctest binaries don't inherit the xla rpath on this image)

use super::rng::Rng;

/// Run `prop` on `cases` deterministic random cases. Panics (with the
/// failing seed) if a case panics.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5EED_0000 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Like [`check`] but the property returns `Result` instead of panicking.
pub fn check_result<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0x5EED_0000 ^ seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Seeded generators for OverQ kernel properties — hardware configs,
/// ReLU-shaped activation planes, encoder state and weight matrices.
/// Shared by the in-crate property tests and `tests/kernel_diff.rs` so
/// every differential harness draws from the same distributions.
pub mod gen {
    use super::Rng;
    use crate::overq::{encode_tensor, Encoded, OverQConfig};
    use crate::tensor::{TensorF, TensorI};

    /// Random hardware mode: bits 2..=8, cascade 1..=4, any RO/PR strap
    /// combination (baseline, RO-only, PR-only and full all reachable).
    pub fn overq_config(rng: &mut Rng) -> OverQConfig {
        OverQConfig {
            bits: 2 + rng.index(7) as u32,
            cascade: 1 + rng.index(4),
            range_overwrite: rng.bool(0.7),
            precision_overwrite: rng.bool(0.5),
        }
    }

    /// ReLU-shaped activation plane: ~half exact zeros (claimable
    /// slots) and a heavy tail of outliers, so range overwrite,
    /// precision overwrite and cascading all trigger under encoding.
    pub fn activations(rng: &mut Rng, rows: usize, cols: usize) -> TensorF {
        let mut x = TensorF::zeros(&[rows, cols]);
        for v in x.data.iter_mut() {
            *v = if rng.bool(0.5) {
                0.0
            } else {
                rng.normal().abs() * (if rng.bool(0.08) { 10.0 } else { 1.0 })
            };
        }
        x
    }

    /// Encoder state over a random activation plane; returns the
    /// encoded (codes, state) pair and the scale it was encoded at.
    pub fn encoded(rng: &mut Rng, rows: usize, cols: usize, cfg: &OverQConfig) -> (Encoded, f32) {
        let scale = 0.1 + rng.f32() * 0.3;
        let x = activations(rng, rows, cols);
        (encode_tensor(&x, scale, cfg), scale)
    }

    /// Random signed (K, N) weight matrix in int8 range.
    pub fn weights(rng: &mut Rng, k: usize, n: usize) -> TensorI {
        let mut w = TensorI::zeros(&[k, n]);
        for v in w.data.iter_mut() {
            *v = rng.range(-127, 128) as i32;
        }
        w
    }

    /// One request arrival for serving-layer properties (the deadline
    /// batcher in `coordinator::batcher`).
    #[derive(Clone, Debug)]
    pub struct Arrival {
        /// Admission-control tenant id (small so tenants collide).
        pub tenant: usize,
        /// Variant-group id (batches must stay single-group).
        pub group: usize,
        /// Deadline offset from enqueue in µs; negative = already
        /// expired at enqueue, `None` = no deadline.
        pub deadline_us: Option<i64>,
    }

    /// Random request-arrival stream: a handful of tenants and variant
    /// groups with a mix of expired, tight and absent deadlines, so
    /// admission, fairness and expiry paths all trigger.
    pub fn arrivals(rng: &mut Rng, max_len: usize) -> Vec<Arrival> {
        let n = 1 + rng.index(max_len.max(1));
        (0..n)
            .map(|_| Arrival {
                tenant: rng.index(4),
                group: rng.index(3),
                deadline_us: if rng.bool(0.4) {
                    Some(if rng.bool(0.3) {
                        -(1 + rng.index(1000) as i64)
                    } else {
                        1_000_000 + rng.index(1_000_000) as i64
                    })
                } else {
                    None
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs is nonneg", 50, |r| {
            let x = r.normal();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn reports_failing_seed() {
        check("always fails eventually", 10, |r| {
            assert!(r.f64() < 0.9, "unlucky draw");
        });
    }

    #[test]
    fn gen_configs_cover_the_mode_space() {
        let mut r = Rng::new(3);
        let (mut bits_seen, mut modes_seen) = ([false; 9], [false; 4]);
        for _ in 0..400 {
            let c = gen::overq_config(&mut r);
            assert!((2..=8).contains(&c.bits));
            assert!((1..=4).contains(&c.cascade));
            bits_seen[c.bits as usize] = true;
            modes_seen[(c.range_overwrite as usize) * 2 + c.precision_overwrite as usize] = true;
        }
        assert!(bits_seen[2..=8].iter().all(|&b| b), "missing a bit width");
        assert!(modes_seen.iter().all(|&m| m), "missing an RO/PR strap combo");
    }

    #[test]
    fn gen_activations_have_zeros_and_outliers() {
        let mut r = Rng::new(4);
        let x = gen::activations(&mut r, 32, 64);
        let zeros = x.data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > x.data.len() / 4, "too few claimable zeros");
        assert!(x.data.iter().all(|&v| v >= 0.0), "ReLU plane went negative");
        assert!(x.data.iter().any(|&v| v > 4.0), "no outlier tail");
        // encoding such a plane under full OverQ populates non-NORM states
        let cfg = crate::overq::OverQConfig::full(4, 2);
        let (enc, _) = gen::encoded(&mut r, 32, 64, &cfg);
        let h = crate::overq::slot_histogram(&enc.state);
        assert!(h[1] + h[2] + h[3] > 0, "encoder never left NORM: {h:?}");
    }
}
