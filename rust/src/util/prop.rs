//! Property-testing driver — substitute for `proptest`.
//!
//! Runs a property over many seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use overq::util::prop::check;
//! check("sum commutes", 200, |rng| {
//!     let (a, b) = (rng.range(-100, 100), rng.range(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! (no_run: doctest binaries don't inherit the xla rpath on this image)

use super::rng::Rng;

/// Run `prop` on `cases` deterministic random cases. Panics (with the
/// failing seed) if a case panics.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5EED_0000 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Like [`check`] but the property returns `Result` instead of panicking.
pub fn check_result<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0x5EED_0000 ^ seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs is nonneg", 50, |r| {
            let x = r.normal();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn reports_failing_seed() {
        check("always fails eventually", 10, |r| {
            assert!(r.f64() < 0.9, "unlucky draw");
        });
    }
}
