//! Minimal JSON parser/serializer — substitute for `serde_json`.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Used for the artifact manifest, graph IR files
//! and experiment configs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Value {
        static NULL: Value = Value::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Value, String> {
    let bytes = src.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk =
                        std::str::from_utf8(&rest[..ch_len.min(rest.len())]).map_err(|e| e.to_string())?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience: parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\ny"}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.at(&["a"]).as_f64(), Some(1.0));
        assert_eq!(v.at(&["b"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["b"]).as_arr().unwrap()[2].as_f64(), Some(-2500.0));
        assert_eq!(v.at(&["c", "d"]).as_str(), Some("x\ny"));
        // serialize and reparse
        let again = parse(&v.to_json()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn nested_depth() {
        let src = "[".repeat(50) + &"]".repeat(50);
        assert!(parse(&src).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }
}
