//! Minimal scoped thread pool — substitute for `rayon`-style parallel maps.
//!
//! On this testbed (`nproc == 1`) the pool degrades to sequential
//! execution, but the coordinator and harness code are written against
//! this interface so multi-core machines parallelize for free.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of worker threads to use by default.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(i)` for every i in 0..n, splitting across `threads` workers.
/// Work-stealing via a shared atomic counter.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let counter = Arc::clone(&counter);
            let f = &f;
            scope.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits = AtomicU64::new(0);
        parallel_for(100, 4, |i| {
            hits.fetch_add(1 << (i % 60), Ordering::Relaxed);
        });
        // every index executed exactly once => sum of powers matches
        let mut want = 0u64;
        for i in 0..100 {
            want = want.wrapping_add(1 << (i % 60));
        }
        assert_eq!(hits.load(Ordering::Relaxed), want);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(50, 4, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_fallback() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }
}
