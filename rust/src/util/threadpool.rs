//! Minimal scoped thread pool — substitute for `rayon`-style parallel maps.
//!
//! On this testbed (`nproc == 1`) the pool degrades to sequential
//! execution, but the coordinator and harness code are written against
//! this interface so multi-core machines parallelize for free.
//!
//! The blocked kernels ([`crate::nn::gemm`], `overq::dotprod`) size
//! their worker count off [`configured_threads`] — the `OVERQ_THREADS`
//! environment variable (or [`set_threads`]) caps it, otherwise it is
//! the machine's available parallelism. Workers are scoped threads
//! spawned per call (~tens of µs), so the kernels only go parallel when
//! the work comfortably amortizes the spawn cost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Number of worker threads to use by default.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Cached process-wide thread budget (0 = not yet resolved).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// The process-wide kernel thread budget: `OVERQ_THREADS` when set to a
/// positive integer, else [`default_parallelism`]. Resolved once and
/// cached; [`set_threads`] overrides it.
pub fn configured_threads() -> usize {
    let v = CONFIGURED.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let n = std::env::var("OVERQ_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(default_parallelism);
    CONFIGURED.store(n, Ordering::Relaxed);
    n
}

/// Override the kernel thread budget (e.g. for benchmarking scaling).
pub fn set_threads(n: usize) {
    CONFIGURED.store(n.max(1), Ordering::Relaxed);
}

/// Run `f(i)` for every i in 0..n, splitting across `threads` workers.
/// Work-stealing via a shared atomic counter.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let counter = Arc::clone(&counter);
            let f = &f;
            scope.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<Mutex<&mut T>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

/// Split `data` into contiguous chunks of `chunk_len` elements (the last
/// one may be shorter) and run `f(chunk_index, chunk)` over them on
/// `threads` workers. Chunks are disjoint, so this is the safe way for
/// kernels to parallelize writes into one output buffer; the per-chunk
/// `Mutex` is uncontended (each index is claimed exactly once) and only
/// exists to hand `&mut` access across the scoped threads.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let nchunks = data.len().div_ceil(chunk_len);
    if threads.max(1) <= 1 || nchunks <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let slots: Vec<Mutex<&mut [T]>> = data.chunks_mut(chunk_len).map(Mutex::new).collect();
    parallel_for(nchunks, threads, |i| {
        f(i, &mut **slots[i].lock().unwrap());
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits = AtomicU64::new(0);
        parallel_for(100, 4, |i| {
            hits.fetch_add(1 << (i % 60), Ordering::Relaxed);
        });
        // every index executed exactly once => sum of powers matches
        let mut want = 0u64;
        for i in 0..100 {
            want = want.wrapping_add(1 << (i % 60));
        }
        assert_eq!(hits.load(Ordering::Relaxed), want);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(50, 4, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_fallback() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn chunks_cover_whole_slice() {
        for &threads in &[1usize, 2, 4, 8] {
            for &(len, chunk) in &[(100usize, 7usize), (100, 100), (100, 1000), (5, 1), (1, 3)] {
                let mut data = vec![0u32; len];
                parallel_chunks_mut(&mut data, chunk, threads, |ci, c| {
                    for (off, v) in c.iter_mut().enumerate() {
                        *v = (ci * chunk + off) as u32 + 1;
                    }
                });
                for (i, &v) in data.iter().enumerate() {
                    assert_eq!(v as usize, i + 1, "len={len} chunk={chunk} t={threads}");
                }
            }
        }
    }

    #[test]
    fn chunks_on_empty_slice() {
        let mut data: Vec<u32> = vec![];
        parallel_chunks_mut(&mut data, 4, 4, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn configured_threads_positive() {
        assert!(configured_threads() >= 1);
        set_threads(3);
        assert_eq!(configured_threads(), 3);
        set_threads(default_parallelism());
    }
}
