//! Infrastructure substrates.
//!
//! The offline vendored registry only carries the `xla` crate's
//! dependency closure, so the usual ecosystem crates (clap, serde,
//! criterion, proptest, rand) are re-implemented here at the scale this
//! project needs (DESIGN.md §2, substitution table).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
