//! Deterministic RNG (xoshiro256**) — substitute for the `rand` crate.
//!
//! Every stochastic component in the crate (workload generators, property
//! tests, the synthetic dataset) takes an explicit [`Rng`] so runs are
//! reproducible from a seed.

/// One splitmix64 step (Steele, Lea & Flood; public domain reference
/// algorithm): advance `state` and return the next 64-bit output. Used
/// to seed [`Rng`] and wherever a lightweight single-u64 generator is
/// enough.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent child stream (for per-image / per-case keys).
    pub fn fork(&self, key: u64) -> Rng {
        let mut r = Rng::new(self.s[0] ^ key.wrapping_mul(0x2545F4914F6CDD1D));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.range(-5, 9);
            assert!((-5..9).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_independent() {
        let base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
        // same key → same stream
        let mut c = base.fork(1);
        let mut d = base.fork(1);
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
