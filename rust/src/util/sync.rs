//! Concurrency facade for the serving stack: poison-safe locking, a
//! single import point for the sync primitives the coordinator uses,
//! and a small exhaustive-interleaving model checker ([`model`]) that
//! the shard swap/shutdown protocol tests run under.
//!
//! Three layers:
//!
//! * [`lock`] — the poisoning-aware lock helper. A panicking worker
//!   poisons every `Mutex` it held; the admin plane must keep working
//!   anyway (an operator fixing a wedged shard needs `swap_plan` the
//!   most right after something panicked), so coordinator code takes
//!   locks through this helper instead of `lock().unwrap()`.
//! * Re-exported `Arc`/`Mutex`/`MutexGuard` — the coordinator imports
//!   its primitives from here, not `std::sync`, so the whole shard
//!   protocol can be re-pointed at a model-checking runtime (e.g.
//!   `loom`) by swapping one `cfg`-gated block. Under `--cfg loom`
//!   these resolve to `loom::sync` (the `loom` crate must then be
//!   provided by the build environment; the normal offline build never
//!   sets the cfg).
//! * [`model`] — a dependency-free bounded model checker with a
//!   loom-shaped API (`model::check`, `model::Mutex`,
//!   `model::thread::spawn`, `model::AtomicBool`). It runs a closure
//!   under *every* distinguishable thread interleaving (scheduling
//!   decisions are explored by depth-first search over yield points),
//!   so the swap/submit publication protocol and the shutdown drain
//!   protocol are checked exhaustively in regular `cargo test` — no
//!   registry access, no nightly. `rust/tests/model_check.rs` holds
//!   the protocol models; docs/static_analysis.md documents the
//!   methodology next to the `overq lint` rules.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Take a mutex, recovering from poisoning: if a previous holder
/// panicked, the data is returned anyway (`into_inner` on the poison
/// error). Every coordinator lock site uses this so one panicked worker
/// cannot wedge the admin plane (`swap_plan`, metrics snapshots) of an
/// otherwise healthy process. Callers that need to *observe* poisoning
/// (none in this crate) can still call `Mutex::lock` directly.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`Condvar::wait`] with the same poison-recovery contract as
/// [`lock`]: a panicked peer never wedges a waiter.
pub fn cv_wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`Condvar::wait_timeout`] with poison recovery; returns the guard
/// and whether the wait timed out.
pub fn cv_wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(g, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(poisoned) => {
            let (g, t) = poisoned.into_inner();
            (g, t.timed_out())
        }
    }
}

/// Bounded exhaustive-interleaving model checker.
///
/// [`check`] runs a closure repeatedly, once per distinguishable
/// schedule: every shared-memory operation of the [`Mutex`] /
/// [`AtomicBool`] / [`AtomicUsize`] types in this module is a yield
/// point where the scheduler picks which runnable thread proceeds.
/// Depth-first search over those decisions enumerates all
/// interleavings; an assertion failure in any of them panics out of
/// `check` with the schedule count, and a schedule where no runnable
/// thread remains while some are blocked panics with a deadlock
/// report.
///
/// The API mirrors the subset of `loom` the coordinator protocol tests
/// need, so the same test bodies can be pointed at real `loom` later
/// by swapping imports. Exploration is bounded by
/// [`check_bounded`]'s schedule cap (default 100 000) — far above what
/// the small protocol models here generate, and a hard panic (never a
/// silent truncation) when exceeded.
pub mod model {
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering};
    use std::sync::{Arc as StdArc, Condvar, Mutex as StdMutex};

    pub use std::sync::Arc;

    /// Default schedule cap for [`check`].
    pub const DEFAULT_MAX_SCHEDULES: usize = 100_000;

    thread_local! {
        /// (execution, my thread id) for threads running under a check.
        static CTX: std::cell::RefCell<Option<(StdArc<Execution>, usize)>> =
            const { std::cell::RefCell::new(None) };
    }

    #[derive(Clone, Copy, PartialEq)]
    enum ThreadState {
        Runnable,
        /// Blocked trying to lock the mutex with this token.
        BlockedOnLock(usize),
        /// Blocked joining the thread with this id.
        BlockedOnJoin(usize),
        Finished,
    }

    struct SchedState {
        threads: Vec<ThreadState>,
        /// Index of the thread currently allowed to run.
        current: usize,
        /// Decisions taken so far this execution: at each branch point
        /// (more than one runnable thread), which position in the
        /// sorted runnable list was chosen, and how many there were.
        decisions: Vec<(usize, usize)>,
        /// Prefix of decision positions to replay (from the DFS).
        replay: Vec<usize>,
        /// First panic payload observed in any checked thread.
        panic: Option<String>,
        live: usize,
    }

    struct Execution {
        state: StdMutex<SchedState>,
        cv: Condvar,
        next_token: StdAtomicUsize,
    }

    impl Execution {
        /// Pick the next thread to run; called with the state lock held
        /// by whichever thread is yielding (or finishing).
        fn pick_next(&self, st: &mut SchedState) {
            let runnable: Vec<usize> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, s)| **s == ThreadState::Runnable)
                .map(|(i, _)| i)
                .collect();
            if runnable.is_empty() {
                if st.live > 0 && st.panic.is_none() {
                    let blocked: Vec<String> = st
                        .threads
                        .iter()
                        .enumerate()
                        .map(|(i, s)| match s {
                            ThreadState::BlockedOnLock(t) => {
                                format!("thread {i} blocked on lock #{t}")
                            }
                            ThreadState::BlockedOnJoin(t) => {
                                format!("thread {i} blocked joining thread {t}")
                            }
                            ThreadState::Runnable => format!("thread {i} runnable"),
                            ThreadState::Finished => format!("thread {i} finished"),
                        })
                        .collect();
                    st.panic = Some(format!(
                        "model check: deadlock — no runnable thread ({})",
                        blocked.join(", ")
                    ));
                }
                // wake everyone so blocked threads can observe the abort
                st.current = usize::MAX;
                return;
            }
            let pos = if runnable.len() == 1 {
                0
            } else {
                let d = st.decisions.len();
                let pos = st.replay.get(d).copied().unwrap_or(0);
                st.decisions.push((pos, runnable.len()));
                pos
            };
            st.current = runnable[pos.min(runnable.len() - 1)];
        }

        /// One scheduling point: give every other runnable thread the
        /// chance to be scheduled before this thread's next shared op.
        fn yield_point(self: &StdArc<Self>, me: usize) {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            if st.panic.is_some() {
                drop(st);
                panic!("model check aborted");
            }
            st.threads[me] = ThreadState::Runnable;
            self.pick_next(&mut st);
            self.cv.notify_all();
            while st.current != me {
                if st.panic.is_some() || st.current == usize::MAX {
                    drop(st);
                    panic!("model check aborted");
                }
                st = self
                    .cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        }

        /// Block `me` until `wake(me)` makes it runnable again.
        fn block(self: &StdArc<Self>, me: usize, why: ThreadState) {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            st.threads[me] = why;
            self.pick_next(&mut st);
            self.cv.notify_all();
            while st.current != me {
                if st.panic.is_some() || st.current == usize::MAX {
                    drop(st);
                    panic!("model check aborted");
                }
                st = self
                    .cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        }

        /// Make every thread blocked on `pred` runnable again.
        fn wake_blocked(self: &StdArc<Self>, pred: impl Fn(&ThreadState) -> bool) {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            for s in st.threads.iter_mut() {
                if pred(s) {
                    *s = ThreadState::Runnable;
                }
            }
        }

        fn finish(self: &StdArc<Self>, me: usize, panic_msg: Option<String>) {
            self.wake_blocked(|s| *s == ThreadState::BlockedOnJoin(me));
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            st.threads[me] = ThreadState::Finished;
            st.live -= 1;
            if let Some(msg) = panic_msg {
                st.panic.get_or_insert(msg);
            }
            self.pick_next(&mut st);
            self.cv.notify_all();
        }
    }

    fn ctx() -> (StdArc<Execution>, usize) {
        CTX.with(|c| {
            c.borrow()
                .clone()
                .expect("model-check primitive used outside model::check")
        })
    }

    /// Run `f` under every distinguishable interleaving (DFS over
    /// scheduling decisions), with the default schedule cap.
    /// Panics if any schedule fails an assertion, deadlocks, or the
    /// cap is exceeded.
    pub fn check<F>(f: F)
    where
        F: Fn() + Send + Sync + 'static,
    {
        check_bounded(f, DEFAULT_MAX_SCHEDULES);
    }

    /// [`check`] with an explicit schedule cap.
    pub fn check_bounded<F>(f: F, max_schedules: usize)
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f = StdArc::new(f);
        let mut replay: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            assert!(
                schedules <= max_schedules,
                "model check exceeded {max_schedules} schedules — shrink the protocol model"
            );
            let decisions = run_one(f.clone(), replay.clone(), schedules);
            // DFS: advance the deepest decision that still has an
            // untried alternative, drop everything after it
            let mut next: Option<Vec<usize>> = None;
            for d in (0..decisions.len()).rev() {
                let (pos, alts) = decisions[d];
                if pos + 1 < alts {
                    let mut r: Vec<usize> =
                        decisions[..d].iter().map(|(p, _)| *p).collect();
                    r.push(pos + 1);
                    next = Some(r);
                    break;
                }
            }
            match next {
                Some(r) => replay = r,
                None => break,
            }
        }
    }

    /// Execute one schedule; returns the decision trace for the DFS.
    fn run_one<F>(f: StdArc<F>, replay: Vec<usize>, schedule_no: usize) -> Vec<(usize, usize)>
    where
        F: Fn() + Send + Sync + 'static,
    {
        let exec = StdArc::new(Execution {
            state: StdMutex::new(SchedState {
                threads: vec![ThreadState::Runnable],
                current: 0,
                decisions: Vec::new(),
                replay,
                panic: None,
                live: 1,
            }),
            cv: Condvar::new(),
            next_token: StdAtomicUsize::new(0),
        });
        let e2 = exec.clone();
        let root = std::thread::Builder::new()
            .name("model-check-0".into())
            .spawn(move || {
                CTX.with(|c| *c.borrow_mut() = Some((e2.clone(), 0)));
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f()));
                let msg = r.err().map(|p| payload_msg(&p));
                // scheduler-abort unwinds are bookkeeping, not failures
                let msg = msg.filter(|m| m != "model check aborted");
                e2.finish(0, msg);
            })
            .expect("spawn model-check root");
        let _ = root.join();
        // the root closure joins its own spawned handles before
        // returning, so by now every checked thread has finished
        let st = exec.state.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(msg) = &st.panic {
            panic!("model check failed on schedule {schedule_no}: {msg}");
        }
        st.decisions.clone()
    }

    fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = p.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = p.downcast_ref::<String>() {
            s.clone()
        } else {
            "panic (non-string payload)".to_string()
        }
    }

    /// Model-checked mutex: every `lock` is a yield point; contended
    /// locks block the thread in the scheduler (never spin), so the
    /// checker can prove deadlock-freedom of a locking protocol.
    pub struct Mutex<T> {
        token: usize,
        inner: StdMutex<T>,
    }

    impl<T> Mutex<T> {
        #[allow(clippy::new_without_default)]
        pub fn new(value: T) -> Mutex<T> {
            let (exec, _) = ctx();
            Mutex {
                token: exec.next_token.fetch_add(1, Ordering::Relaxed),
                inner: StdMutex::new(value),
            }
        }

        pub fn lock(&self) -> MutexGuard<'_, T> {
            let (exec, me) = ctx();
            loop {
                exec.yield_point(me);
                match self.inner.try_lock() {
                    Ok(g) => {
                        return MutexGuard {
                            token: self.token,
                            guard: Some(g),
                        }
                    }
                    Err(std::sync::TryLockError::Poisoned(p)) => {
                        return MutexGuard {
                            token: self.token,
                            guard: Some(p.into_inner()),
                        }
                    }
                    Err(std::sync::TryLockError::WouldBlock) => {
                        exec.block(me, ThreadState::BlockedOnLock(self.token));
                    }
                }
            }
        }
    }

    /// Guard for [`Mutex`]; releasing it wakes blocked waiters in the
    /// scheduler.
    pub struct MutexGuard<'a, T> {
        token: usize,
        guard: Option<std::sync::MutexGuard<'a, T>>,
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.guard.as_ref().unwrap()
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.guard.as_mut().unwrap()
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // release the real lock first, then wake waiters
            self.guard.take();
            if let Some((exec, _)) = CTX.with(|c| c.borrow().clone()) {
                exec.wake_blocked(|s| *s == ThreadState::BlockedOnLock(self.token));
            }
        }
    }

    /// Model-checked boolean flag: loads and stores are yield points
    /// with sequentially consistent (scheduler-serialized) semantics.
    pub struct AtomicBool {
        inner: StdMutex<bool>,
    }

    impl AtomicBool {
        pub fn new(v: bool) -> AtomicBool {
            AtomicBool {
                inner: StdMutex::new(v),
            }
        }

        pub fn load(&self) -> bool {
            let (exec, me) = ctx();
            exec.yield_point(me);
            *self.inner.lock().unwrap_or_else(|p| p.into_inner())
        }

        pub fn store(&self, v: bool) {
            let (exec, me) = ctx();
            exec.yield_point(me);
            *self.inner.lock().unwrap_or_else(|p| p.into_inner()) = v;
        }
    }

    /// Model-checked FIFO queue standing in for the shard's mpsc
    /// channel in protocol models: sends and receives are yield
    /// points, receives never block (the models drain explicitly).
    pub struct Channel<T> {
        inner: StdMutex<VecDeque<T>>,
    }

    impl<T> Channel<T> {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Channel<T> {
            Channel {
                inner: StdMutex::new(VecDeque::new()),
            }
        }

        pub fn send(&self, v: T) {
            let (exec, me) = ctx();
            exec.yield_point(me);
            self.inner
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push_back(v);
        }

        pub fn try_recv(&self) -> Option<T> {
            let (exec, me) = ctx();
            exec.yield_point(me);
            self.inner
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .pop_front()
        }
    }

    /// Threads under the model checker.
    pub mod thread {
        use super::{ctx, payload_msg, ThreadState, CTX};

        /// Handle to a model-checked thread.
        pub struct JoinHandle<T> {
            id: usize,
            result: std::thread::JoinHandle<T>,
        }

        impl<T> JoinHandle<T> {
            /// Block (in the scheduler) until the thread finishes.
            pub fn join(self) -> std::thread::Result<T> {
                let (exec, me) = ctx();
                loop {
                    {
                        let st = exec
                            .state
                            .lock()
                            .unwrap_or_else(|p| p.into_inner());
                        if st.threads[self.id] == ThreadState::Finished {
                            break;
                        }
                    }
                    exec.block(me, ThreadState::BlockedOnJoin(self.id));
                }
                self.result.join()
            }
        }

        /// Spawn a thread participating in the current model check.
        pub fn spawn<F, T>(f: F) -> JoinHandle<T>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            let (exec, _) = ctx();
            let id = {
                let mut st = exec.state.lock().unwrap_or_else(|p| p.into_inner());
                st.threads.push(ThreadState::Runnable);
                st.live += 1;
                st.threads.len() - 1
            };
            let e2 = exec.clone();
            let handle = std::thread::Builder::new()
                .name(format!("model-check-{id}"))
                .spawn(move || {
                    CTX.with(|c| *c.borrow_mut() = Some((e2.clone(), id)));
                    // wait to be scheduled before touching shared state
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        e2.yield_point(id);
                        f()
                    }));
                    match out {
                        Ok(v) => {
                            e2.finish(id, None);
                            v
                        }
                        Err(p) => {
                            let msg = payload_msg(&p);
                            let msg =
                                Some(msg).filter(|m| m != "model check aborted");
                            e2.finish(id, msg);
                            std::panic::resume_unwind(p);
                        }
                    }
                })
                .expect("spawn model-check thread");
            JoinHandle { id, result: handle }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::model;
    use super::*;

    #[test]
    fn lock_recovers_from_poison() {
        let m = std::sync::Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // the helper still returns the data
        assert_eq!(*lock(&m), 7);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn model_explores_both_orders() {
        // two writers → final value depends on schedule; both must be
        // observed across the exploration
        use std::sync::atomic::{AtomicUsize, Ordering};
        let saw_a = std::sync::Arc::new(AtomicUsize::new(0));
        let saw_b = std::sync::Arc::new(AtomicUsize::new(0));
        let (sa, sb) = (saw_a.clone(), saw_b.clone());
        model::check(move || {
            let v = model::Arc::new(model::Mutex::new(0));
            let v2 = v.clone();
            let t = model::thread::spawn(move || {
                *v2.lock() = 1;
            });
            *v.lock() = 2;
            t.join().unwrap();
            match *v.lock() {
                1 => sa.fetch_add(1, Ordering::Relaxed),
                2 => sb.fetch_add(1, Ordering::Relaxed),
                _ => unreachable!(),
            };
        });
        assert!(saw_a.load(Ordering::Relaxed) > 0, "order writer-last never explored");
        assert!(saw_b.load(Ordering::Relaxed) > 0, "order main-last never explored");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn model_detects_lock_order_inversion() {
        model::check(|| {
            let a = model::Arc::new(model::Mutex::new(()));
            let b = model::Arc::new(model::Mutex::new(()));
            let (a2, b2) = (a.clone(), b.clone());
            let t = model::thread::spawn(move || {
                let _ga = a2.lock();
                let _gb = b2.lock();
            });
            {
                let _gb = b.lock();
                let _ga = a.lock();
            }
            t.join().unwrap();
        });
    }

    #[test]
    fn model_passes_consistent_lock_order() {
        // same two locks, same order everywhere → provably deadlock-free
        model::check(|| {
            let a = model::Arc::new(model::Mutex::new(0));
            let b = model::Arc::new(model::Mutex::new(0));
            let (a2, b2) = (a.clone(), b.clone());
            let t = model::thread::spawn(move || {
                let mut ga = a2.lock();
                let mut gb = b2.lock();
                *ga += 1;
                *gb += 1;
            });
            {
                let mut ga = a.lock();
                let mut gb = b.lock();
                *ga += 10;
                *gb += 10;
            }
            t.join().unwrap();
            assert_eq!(*a.lock(), 11);
            assert_eq!(*b.lock(), 11);
        });
    }

    #[test]
    #[should_panic(expected = "model check failed")]
    fn model_finds_racy_check_then_act() {
        // classic TOCTOU: both threads read 0, both write 1 → lost
        // update; some schedule must catch the violated invariant
        model::check(|| {
            let v = model::Arc::new(model::Mutex::new(0));
            let v2 = v.clone();
            let t = model::thread::spawn(move || {
                let seen = *v2.lock(); // read under one lock...
                *v2.lock() = seen + 1; // ...write under another
            });
            let seen = *v.lock();
            *v.lock() = seen + 1;
            t.join().unwrap();
            assert_eq!(*v.lock(), 2, "lost update");
        });
    }
}
