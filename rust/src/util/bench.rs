//! Benchmark harness — substitute for `criterion` (offline registry).
//!
//! Each `benches/*.rs` target sets `harness = false` and drives this:
//! warmup, timed iterations until a minimum wall-time, and a report with
//! mean / std / min / throughput. Also hosts the table printer used by
//! the paper-reproduction benches.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` (which should perform ONE logical operation per call).
///
/// Runs a warmup, then batches of calls until `min_time` has elapsed or
/// `max_iters` is reached.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_millis(300), 3, 10_000, &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    min_time: Duration,
    warmup: u64,
    max_iters: u64,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    let start = Instant::now();
    let mut iters = 0;
    while (start.elapsed() < min_time && iters < max_iters) || iters < 5 {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_nanos() as f64);
        iters += 1;
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        std_ns: s.std(),
        min_ns: s.min,
    };
    println!(
        "bench {:<44} {:>10.3} ms/iter (±{:>8.3}, min {:>8.3}, n={})",
        r.name,
        r.mean_ns / 1e6,
        r.std_ns / 1e6,
        r.min_ns / 1e6,
        r.iters
    );
    r
}

/// Markdown-ish table printer for paper-reproduction benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// CSV dump (for plotting / EXPERIMENTS.md appendices).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench_cfg(
            "noop",
            Duration::from_millis(5),
            1,
            1000,
            &mut || n += 1,
        );
        assert!(r.iters >= 5);
        assert_eq!(n, r.iters + 1); // warmup included
    }

    #[test]
    fn table_shapes() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
        t.print();
    }
}
