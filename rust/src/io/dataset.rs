//! Dataset loading re-exports (see models::zoo::Dataset).
