//! Artifact I/O: the `.tensors` binary format and dataset loading.

pub mod dataset;
pub mod tensorfile;
