//! `.tensors` reader/writer — mirrors python/compile/tensorfile.py.
//!
//! Layout (little endian):
//!   magic  b"OVQT" | u32 version (1) | u32 count
//!   per tensor: u16 name_len, name, u8 dtype, u8 ndim, u32 dims[ndim], raw data
//! dtype: 0 = f32, 1 = i32, 2 = u8, 3 = i8.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"OVQT";
const VERSION: u32 = 1;

/// A tensor of any supported dtype.
#[derive(Clone, Debug)]
pub enum AnyTensor {
    F32(Tensor<f32>),
    I32(Tensor<i32>),
    U8(Tensor<u8>),
    I8(Tensor<i8>),
}

impl AnyTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => t.dims(),
            AnyTensor::I32(t) => t.dims(),
            AnyTensor::U8(t) => t.dims(),
            AnyTensor::I8(t) => t.dims(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor<f32>> {
        match self {
            AnyTensor::F32(t) => Ok(t),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&Tensor<i32>> {
        match self {
            AnyTensor::I32(t) => Ok(t),
            _ => bail!("tensor is not i32"),
        }
    }
}

/// Named tensor collection.
pub type TensorMap = BTreeMap<String, AnyTensor>;

/// Read a `.tensors` file.
pub fn read(path: &Path) -> Result<TensorMap> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)?;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = dims.iter().product::<usize>().max(1);
        let tensor = match dtype {
            0 => {
                let mut raw = vec![0u8; numel * 4];
                f.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                AnyTensor::F32(Tensor::from_vec(&dims, data))
            }
            1 => {
                let mut raw = vec![0u8; numel * 4];
                f.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                AnyTensor::I32(Tensor::from_vec(&dims, data))
            }
            2 => {
                let mut raw = vec![0u8; numel];
                f.read_exact(&mut raw)?;
                AnyTensor::U8(Tensor::from_vec(&dims, raw))
            }
            3 => {
                let mut raw = vec![0u8; numel];
                f.read_exact(&mut raw)?;
                AnyTensor::I8(Tensor::from_vec(
                    &dims,
                    raw.into_iter().map(|b| b as i8).collect(),
                ))
            }
            d => bail!("{}: unknown dtype {d}", path.display()),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

/// Write a `.tensors` file.
pub fn write(path: &Path, tensors: &TensorMap) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let (code, dims): (u8, &[usize]) = match t {
            AnyTensor::F32(t) => (0, t.dims()),
            AnyTensor::I32(t) => (1, t.dims()),
            AnyTensor::U8(t) => (2, t.dims()),
            AnyTensor::I8(t) => (3, t.dims()),
        };
        f.write_all(&[code, dims.len() as u8])?;
        for &d in dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            AnyTensor::F32(t) => {
                for &x in &t.data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            AnyTensor::I32(t) => {
                for &x in &t.data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            AnyTensor::U8(t) => f.write_all(&t.data)?,
            AnyTensor::I8(t) => {
                let raw: Vec<u8> = t.data.iter().map(|&b| b as u8).collect();
                f.write_all(&raw)?;
            }
        }
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ovqt_test_{}", std::process::id()));
        let path = dir.join("t.tensors");
        let mut m = TensorMap::new();
        m.insert(
            "a".into(),
            AnyTensor::F32(Tensor::from_vec(&[2, 2], vec![1.0, -2.5, 3.0, 0.0])),
        );
        m.insert(
            "b".into(),
            AnyTensor::I32(Tensor::from_vec(&[3], vec![-7, 0, 9])),
        );
        m.insert("c".into(), AnyTensor::U8(Tensor::from_vec(&[2], vec![1, 255])));
        m.insert(
            "d".into(),
            AnyTensor::I8(Tensor::from_vec(&[2], vec![-128, 127])),
        );
        write(&path, &m).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back["a"].as_f32().unwrap().data, vec![1.0, -2.5, 3.0, 0.0]);
        assert_eq!(back["b"].as_i32().unwrap().data, vec![-7, 0, 9]);
        match &back["d"] {
            AnyTensor::I8(t) => assert_eq!(t.data, vec![-128, 127]),
            _ => panic!(),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("ovqt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tensors");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
