//! Shared calibration: profile activations, derive clips per method.

use anyhow::Result;

use crate::models::zoo::{Dataset, LoadedModel};
use crate::nn::QuantConfig;
use crate::overq::OverQConfig;
use crate::quant::clip::{ActStats, ClipMethod};
use crate::quant::zeroq;
use crate::tensor::TensorF;

/// Profiled activation samples per enc point (subsampled).
pub struct Profile {
    pub samples: Vec<Vec<f32>>,
    pub stats: Vec<ActStats>,
}

/// Evenly subsample a tensor to at most `max_samples` values. The
/// stride rounds down, so the strided walk can yield up to stride-1
/// extra values when numel is not a multiple — cap at exactly
/// `max_samples`.
pub fn subsample(t: &TensorF, max_samples: usize) -> Vec<f32> {
    let stride = (t.numel() / max_samples).max(1);
    t.data
        .iter()
        .step_by(stride)
        .take(max_samples)
        .copied()
        .collect()
}

/// Forward a batch of images through the fp32 path collecting enc-point
/// tensors, subsampled to at most `max_samples` values per point.
pub fn profile_acts(model: &LoadedModel, images: &TensorF, max_samples: usize) -> Result<Profile> {
    let srcs = model.engine.graph.enc_point_sources();
    let (_, taps) = model.engine.forward_f32(images, &srcs)?;
    let mut samples = Vec::with_capacity(taps.len());
    let mut stats = Vec::with_capacity(taps.len());
    for t in &taps {
        samples.push(subsample(t, max_samples));
        stats.push(ActStats::from_tensor(t));
    }
    Ok(Profile { samples, stats })
}

/// Derive per-enc-point activation scales from a profile + clip method.
pub fn scales_for(profile: &Profile, method: ClipMethod, bits: u32) -> Vec<f32> {
    let qmax = ((1u32 << bits) - 1) as f32;
    profile
        .samples
        .iter()
        .zip(&profile.stats)
        .map(|(s, &st)| method.clip(s, st, bits).max(1e-6) / qmax)
        .collect()
}

/// Scales from the exported enc stats (mean + t·std), no live profiling.
pub fn scales_from_stats(stats: &[ActStats], t: f64, bits: u32) -> Vec<f32> {
    let qmax = ((1u32 << bits) - 1) as f32;
    stats
        .iter()
        .map(|s| {
            (s.mean + t as f32 * s.std)
                .clamp(1e-6, s.max.max(1e-6))
                / qmax
        })
        .collect()
}

/// Build a (uniform) QuantConfig for a clip method on a live profile.
/// Per-enc-point mixed-precision configs come from `policy::autotune`.
pub fn quant_config(
    profile: &Profile,
    method: ClipMethod,
    overq: OverQConfig,
) -> QuantConfig {
    QuantConfig::uniform(overq, scales_for(profile, method, overq.bits))
}

/// Subset the first `n` images of a dataset.
pub fn subset(ds: &Dataset, n: usize) -> (TensorF, Vec<i32>) {
    let n = n.min(ds.images.dims()[0]);
    let img_sz: usize = ds.images.dims()[1..].iter().product();
    let mut dims = vec![n];
    dims.extend_from_slice(&ds.images.dims()[1..]);
    (
        TensorF::from_vec(&dims, ds.images.data[..n * img_sz].to_vec()),
        ds.labels[..n].to_vec(),
    )
}

/// ZeroQ-style data-free profile: synthetic calibration inputs forwarded
/// through the model (no real data touched).
pub fn zeroq_profile(model: &LoadedModel, n: usize, seed: u64) -> Result<Profile> {
    let x = zeroq::synthetic_calibration_batch(n, 16, 16, 3, seed);
    profile_acts(model, &x, 4096)
}

/// The paper's STD method: sweep t over a grid, pick the best accuracy
/// on the profiling (not eval!) split.
pub fn std_sweep_best(
    model: &LoadedModel,
    profile: &Profile,
    overq: OverQConfig,
    probe_images: &TensorF,
    probe_labels: &[i32],
    grid: &[f64],
    batch: usize,
) -> Result<(f64, QuantConfig)> {
    let mut best_t = grid[0];
    let mut best_acc = -1.0;
    for &t in grid {
        let qc = quant_config(profile, ClipMethod::StdMul(t), overq);
        let acc = model
            .engine
            .accuracy_quant(probe_images, probe_labels, batch, &qc)?;
        if acc > best_acc {
            best_acc = acc;
            best_t = t;
        }
    }
    Ok((
        best_t,
        quant_config(profile, ClipMethod::StdMul(best_t), overq),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shapes;
    use crate::models::synth::synth_model;

    #[test]
    fn profile_acts_caps_samples_exactly() {
        let model = synth_model("synth-tiny", 11).unwrap();
        let (images, _) = shapes::gen_batch(11, 0, 4);
        // tap numels (4 images, 16x16x8 and 8x8x12) are not multiples of
        // 100, so the strided walk used to overshoot max_samples
        let prof = profile_acts(&model, &images, 100).unwrap();
        for (e, s) in prof.samples.iter().enumerate() {
            assert_eq!(s.len(), 100, "enc {e}: {} samples", s.len());
        }
        // when the tap is smaller than the cap, keep everything
        let prof = profile_acts(&model, &images, usize::MAX).unwrap();
        let srcs = model.engine.graph.enc_point_sources();
        let (_, taps) = model.engine.forward_f32(&images, &srcs).unwrap();
        for (s, t) in prof.samples.iter().zip(&taps) {
            assert_eq!(s.len(), t.numel());
        }
    }

    #[test]
    fn uniform_quant_config_covers_all_enc_points() {
        let model = synth_model("synth-tiny", 12).unwrap();
        let (images, _) = shapes::gen_batch(12, 0, 4);
        let prof = profile_acts(&model, &images, 512).unwrap();
        let qc = quant_config(&prof, ClipMethod::StdMul(4.0), OverQConfig::full(4, 4));
        assert_eq!(qc.num_enc_points(), model.engine.graph.num_enc_points());
        assert!(qc.layers.iter().all(|l| l.scale > 0.0));
    }
}
