//! Shared calibration: profile activations, derive clips per method.

use anyhow::Result;

use crate::models::zoo::{Dataset, LoadedModel};
use crate::nn::QuantConfig;
use crate::overq::OverQConfig;
use crate::quant::clip::{ActStats, ClipMethod};
use crate::quant::zeroq;
use crate::tensor::TensorF;

/// Profiled activation samples per enc point (subsampled).
pub struct Profile {
    pub samples: Vec<Vec<f32>>,
    pub stats: Vec<ActStats>,
}

/// Forward a batch of images through the fp32 path collecting enc-point
/// tensors, subsampled to at most `max_samples` values per point.
pub fn profile_acts(model: &LoadedModel, images: &TensorF, max_samples: usize) -> Result<Profile> {
    let srcs = model.engine.graph.enc_point_sources();
    let (_, taps) = model.engine.forward_f32(images, &srcs)?;
    let mut samples = Vec::with_capacity(taps.len());
    let mut stats = Vec::with_capacity(taps.len());
    for t in &taps {
        let stride = (t.numel() / max_samples).max(1);
        let s: Vec<f32> = t.data.iter().step_by(stride).copied().collect();
        samples.push(s);
        stats.push(ActStats {
            mean: t.mean(),
            std: t.std(),
            max: t.data.iter().fold(0f32, |m, &x| m.max(x)),
        });
    }
    Ok(Profile { samples, stats })
}

/// Derive per-enc-point activation scales from a profile + clip method.
pub fn scales_for(profile: &Profile, method: ClipMethod, bits: u32) -> Vec<f32> {
    let qmax = ((1u32 << bits) - 1) as f32;
    profile
        .samples
        .iter()
        .zip(&profile.stats)
        .map(|(s, &st)| method.clip(s, st, bits).max(1e-6) / qmax)
        .collect()
}

/// Scales from the exported enc stats (mean + t·std), no live profiling.
pub fn scales_from_stats(stats: &[ActStats], t: f64, bits: u32) -> Vec<f32> {
    let qmax = ((1u32 << bits) - 1) as f32;
    stats
        .iter()
        .map(|s| {
            (s.mean + t as f32 * s.std)
                .clamp(1e-6, s.max.max(1e-6))
                / qmax
        })
        .collect()
}

/// Build a QuantConfig for a clip method on a live profile.
pub fn quant_config(
    profile: &Profile,
    method: ClipMethod,
    overq: OverQConfig,
) -> QuantConfig {
    QuantConfig {
        act_scales: scales_for(profile, method, overq.bits),
        overq,
    }
}

/// Subset the first `n` images of a dataset.
pub fn subset(ds: &Dataset, n: usize) -> (TensorF, Vec<i32>) {
    let n = n.min(ds.images.dims()[0]);
    let img_sz: usize = ds.images.dims()[1..].iter().product();
    let mut dims = vec![n];
    dims.extend_from_slice(&ds.images.dims()[1..]);
    (
        TensorF::from_vec(&dims, ds.images.data[..n * img_sz].to_vec()),
        ds.labels[..n].to_vec(),
    )
}

/// ZeroQ-style data-free profile: synthetic calibration inputs forwarded
/// through the model (no real data touched).
pub fn zeroq_profile(model: &LoadedModel, n: usize, seed: u64) -> Result<Profile> {
    let x = zeroq::synthetic_calibration_batch(n, 16, 16, 3, seed);
    profile_acts(model, &x, 4096)
}

/// The paper's STD method: sweep t over a grid, pick the best accuracy
/// on the profiling (not eval!) split.
pub fn std_sweep_best(
    model: &LoadedModel,
    profile: &Profile,
    overq: OverQConfig,
    probe_images: &TensorF,
    probe_labels: &[i32],
    grid: &[f64],
    batch: usize,
) -> Result<(f64, QuantConfig)> {
    let mut best_t = grid[0];
    let mut best_acc = -1.0;
    for &t in grid {
        let qc = quant_config(profile, ClipMethod::StdMul(t), overq);
        let acc = model
            .engine
            .accuracy_quant(probe_images, probe_labels, batch, &qc)?;
        if acc > best_acc {
            best_acc = acc;
            best_t = t;
        }
    }
    Ok((
        best_t,
        quant_config(profile, ClipMethod::StdMul(best_t), overq),
    ))
}
