//! Policy harness — Table-1-style per-layer report for the autotuner.
//!
//! Runs the coverage-driven mixed-precision autotuner on a model and
//! renders one row per enc point: zero/outlier statistics, the chosen
//! (bits, cascade, mode), Eq. (1) theory coverage vs measured coverage,
//! the Table-3 PE area, and the layer's MAC share — plus plan-vs-global-
//! baseline summary rows ("equal or lower area, equal or better
//! coverage" is the contract the deployment plan must certify).

use anyhow::Result;

use crate::models::zoo::LoadedModel;
use crate::overq::OverQConfig;
use crate::policy::{autotune, AutotuneConfig, AutotuneResult};
use crate::tensor::TensorF;
use crate::util::bench::Table;

/// Short mode tag for a config ("base", "ro", "pr", "full").
pub fn mode_tag(cfg: &OverQConfig) -> &'static str {
    match (cfg.range_overwrite, cfg.precision_overwrite) {
        (false, false) => "base",
        (true, false) => "ro",
        (false, true) => "pr",
        (true, true) => "full",
    }
}

/// Run the autotuner and render the per-layer report.
pub fn run(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
) -> Result<(Table, AutotuneResult)> {
    let result = autotune(model, images, cfg)?;
    let total_macs: f64 = result.layers.iter().map(|l| l.macs as f64).sum();

    let mut table = Table::new(
        &format!(
            "Policy — per-layer OverQ plan for {} (baseline {}@A{} c{})",
            model.name,
            mode_tag(&cfg.baseline),
            cfg.baseline.bits,
            cfg.baseline.cascade
        ),
        &[
            "Enc", "Zero %", "Outlier %", "Bits", "Casc", "Mode", "Theory Cov %",
            "Meas Cov %", "Base Cov %", "PE µm²", "MAC %",
        ],
    );
    for lc in &result.layers {
        let c = &lc.chosen;
        table.row(vec![
            lc.enc.to_string(),
            format!("{:.1}", lc.p0 * 100.0),
            format!("{:.2}", c.outlier_rate * 100.0),
            c.cfg.bits.to_string(),
            if c.cfg.range_overwrite {
                c.cfg.cascade.to_string()
            } else {
                "-".into()
            },
            mode_tag(&c.cfg).into(),
            format!("{:.1}", c.theory_cov * 100.0),
            format!("{:.1}", lc.measured_cov * 100.0),
            format!("{:.1}", lc.baseline_measured_cov * 100.0),
            format!("{:.1}", c.area),
            format!("{:.1}", lc.macs as f64 / total_macs * 100.0),
        ]);
    }
    let plan = &result.plan;
    table.row(vec![
        "PLAN".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", plan.mean_coverage * 100.0),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.total_area),
        "100.0".into(),
    ]);
    table.row(vec![
        "BASE".into(),
        "-".into(),
        "-".into(),
        cfg.baseline.bits.to_string(),
        cfg.baseline.cascade.to_string(),
        mode_tag(&cfg.baseline).into(),
        "-".into(),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.baseline_area),
        "100.0".into(),
    ]);
    Ok((table, result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shapes;
    use crate::models::synth::synth_model;

    #[test]
    fn report_shapes_and_budget_holds() {
        let model = synth_model("synth-tiny", 3).unwrap();
        let (images, _) = shapes::gen_batch(3, 0, 8);
        let cfg = AutotuneConfig::default();
        let (table, result) = run(&model, &images, &cfg).unwrap();
        // one row per enc point + PLAN + BASE summary rows
        assert_eq!(table.rows.len(), 2 + 2);
        // the contract: equal or lower MAC-weighted PE area
        assert!(
            result.total_area <= result.baseline_area + 1e-9,
            "plan area {} exceeds baseline {}",
            result.total_area,
            result.baseline_area
        );
    }
}
