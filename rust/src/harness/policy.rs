//! Policy harness — Table-1-style per-layer report for the autotuner.
//!
//! Runs the coverage-driven mixed-precision autotuner on a model and
//! renders one row per enc point: zero/outlier statistics, the chosen
//! (bits, cascade, mode), Eq. (1) theory coverage vs measured coverage,
//! the Table-3 PE area, and the layer's MAC share — plus plan-vs-global-
//! baseline summary rows ("equal or lower area, equal or better
//! coverage" is the contract the deployment plan must certify).

use anyhow::Result;

use crate::models::zoo::LoadedModel;
use crate::nn::WBITS_DEFAULT;
use crate::obs::counters::DriftBaseline;
use crate::overq::{coverage_stats, OverQConfig};
use crate::policy::{
    autotune, autotune_measured, profile_enc_points, AutotuneConfig, AutotuneResult,
    DeploymentPlan, MeasuredAutotune, PlanLayer, ProbeSplit,
};
use crate::tensor::TensorF;
use crate::util::bench::Table;

/// Short mode tag for a config ("base", "ro", "pr", "full").
pub fn mode_tag(cfg: &OverQConfig) -> &'static str {
    match (cfg.range_overwrite, cfg.precision_overwrite) {
        (false, false) => "base",
        (true, false) => "ro",
        (false, true) => "pr",
        (true, true) => "full",
    }
}

/// Render a weight bitwidth ("-" for the default prepared weights).
fn wbits_tag(wbits: u32) -> String {
    if wbits == WBITS_DEFAULT {
        "-".into()
    } else {
        wbits.to_string()
    }
}

/// Pin every enc point to the global baseline config and emit it as a
/// [`DeploymentPlan`] named `name`. This is the control arm for A/B
/// traffic splits: register the tuned plan and the baseline plan on the
/// same coordinator shard and route weighted live traffic across them
/// (`ModelHandle::set_traffic_split`) to measure which one wins.
pub fn baseline_plan(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
    name: &str,
) -> Result<DeploymentPlan> {
    let profiles = profile_enc_points(model, images, cfg.max_samples)?;
    anyhow::ensure!(!profiles.is_empty(), "model has no enc points");

    let mut layers = Vec::with_capacity(profiles.len());
    for p in &profiles {
        let sc = autotune::score_candidate(p, &cfg.baseline, cfg.clip);
        let measured = coverage_stats(&p.tap, sc.scale, &cfg.baseline).coverage();
        layers.push(PlanLayer {
            enc: p.enc,
            overq: cfg.baseline,
            scale: sc.scale,
            wbits: WBITS_DEFAULT,
            p0: p.p0,
            outlier_rate: sc.outlier_rate,
            theory_coverage: sc.theory_cov,
            measured_coverage: measured,
            area: sc.area,
            macs: p.macs,
            drift: Some(DriftBaseline {
                mean: p.stats.mean as f64,
                var: (p.stats.std as f64).powi(2),
                clip_rate: sc.outlier_rate,
            }),
        });
    }
    // the baseline is its own control: baseline_{area,coverage} mirror
    // the aggregates from_layers derives for the plan itself
    let mut plan = DeploymentPlan::from_layers(name, &model.name, layers, 0.0, 0.0);
    plan.baseline_area = plan.total_area;
    plan.baseline_coverage = plan.mean_coverage;
    Ok(plan)
}

/// Run the autotuner and render the per-layer report.
pub fn run(
    model: &LoadedModel,
    images: &TensorF,
    cfg: &AutotuneConfig,
) -> Result<(Table, AutotuneResult)> {
    let result = autotune(model, images, cfg)?;
    let total_macs: f64 = result.layers.iter().map(|l| l.macs as f64).sum();

    let mut table = Table::new(
        &format!(
            "Policy — per-layer OverQ plan for {} (baseline {}@A{} c{})",
            model.name,
            mode_tag(&cfg.baseline),
            cfg.baseline.bits,
            cfg.baseline.cascade
        ),
        &[
            "Enc", "Zero %", "Outlier %", "Bits", "Wb", "Casc", "Mode", "Theory Cov %",
            "Meas Cov %", "Base Cov %", "PE µm²", "MAC %",
        ],
    );
    for lc in &result.layers {
        let c = &lc.chosen;
        table.row(vec![
            lc.enc.to_string(),
            format!("{:.1}", lc.p0 * 100.0),
            format!("{:.2}", c.outlier_rate * 100.0),
            c.cfg.bits.to_string(),
            wbits_tag(c.wbits),
            if c.cfg.range_overwrite {
                c.cfg.cascade.to_string()
            } else {
                "-".into()
            },
            mode_tag(&c.cfg).into(),
            format!("{:.1}", c.theory_cov * 100.0),
            format!("{:.1}", lc.measured_cov * 100.0),
            format!("{:.1}", lc.baseline_measured_cov * 100.0),
            format!("{:.1}", c.area),
            format!("{:.1}", lc.macs as f64 / total_macs * 100.0),
        ]);
    }
    let plan = &result.plan;
    table.row(vec![
        "PLAN".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", plan.mean_coverage * 100.0),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.total_area),
        "100.0".into(),
    ]);
    table.row(vec![
        "BASE".into(),
        "-".into(),
        "-".into(),
        cfg.baseline.bits.to_string(),
        "-".into(),
        cfg.baseline.cascade.to_string(),
        mode_tag(&cfg.baseline).into(),
        "-".into(),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.baseline_coverage * 100.0),
        format!("{:.1}", plan.baseline_area),
        "100.0".into(),
    ]);
    Ok((table, result))
}

/// Run the two-stage autotuner and render both reports: the per-layer
/// table for the winning plan, and the plan-vs-baseline accuracy table
/// over every refined candidate. [`baseline_plan`] is the control arm:
/// its config is what the refinement stage measures as "baseline".
pub fn run_measured(
    model: &LoadedModel,
    images: &TensorF,
    probe: &ProbeSplit,
    cfg: &AutotuneConfig,
) -> Result<(Table, Table, MeasuredAutotune)> {
    let measured = autotune_measured(model, images, probe, cfg)?;

    let mut acc_table = Table::new(
        &format!(
            "Policy refinement — measured accuracy on {} probe images ({})",
            measured.probe_images, model.name
        ),
        &[
            "Candidate", "Step", "Wb", "PE µm²", "Proxy Err", "Probe Acc %", "Picked",
        ],
    );
    for (i, c) in measured.candidates.iter().enumerate() {
        // weight bitwidths actually used, deduped for display
        let mut wbs: Vec<u32> = c.plan.layers.iter().map(|l| l.wbits).collect();
        wbs.sort_unstable();
        wbs.dedup();
        let wb = wbs
            .iter()
            .map(|&w| wbits_tag(w))
            .collect::<Vec<_>>()
            .join(",");
        acc_table.row(vec![
            c.plan.name.clone(),
            c.greedy_step.to_string(),
            wb,
            format!("{:.1}", c.plan.total_area),
            format!("{:.3e}", c.proxy_err),
            format!("{:.2}", c.measured_acc * 100.0),
            if i == measured.chosen { "◀".into() } else { "".into() },
        ]);
    }
    acc_table.row(vec![
        "baseline".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", measured.result.baseline_area),
        "-".into(),
        format!("{:.2}", measured.baseline_acc * 100.0),
        "".into(),
    ]);

    let total_macs: f64 = measured.result.layers.iter().map(|l| l.macs as f64).sum();
    let mut layer_table = Table::new(
        &format!(
            "Policy — per-layer OverQ plan for {} (chosen by probe accuracy)",
            model.name
        ),
        &["Enc", "Zero %", "Bits", "Wb", "Casc", "Mode", "Meas Cov %", "PE µm²", "MAC %"],
    );
    for lc in &measured.result.layers {
        let c = &lc.chosen;
        layer_table.row(vec![
            lc.enc.to_string(),
            format!("{:.1}", lc.p0 * 100.0),
            c.cfg.bits.to_string(),
            wbits_tag(c.wbits),
            if c.cfg.range_overwrite {
                c.cfg.cascade.to_string()
            } else {
                "-".into()
            },
            mode_tag(&c.cfg).into(),
            format!("{:.1}", lc.measured_cov * 100.0),
            format!("{:.1}", c.area),
            format!("{:.1}", lc.macs as f64 / total_macs * 100.0),
        ]);
    }
    Ok((layer_table, acc_table, measured))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shapes;
    use crate::models::synth::synth_model;

    #[test]
    fn baseline_plan_pins_every_enc_point() {
        let model = synth_model("synth-tiny", 3).unwrap();
        let (images, _) = shapes::gen_batch(3, 0, 8);
        let cfg = AutotuneConfig::default();
        let plan = baseline_plan(&model, &images, &cfg, "tiny-base").unwrap();
        assert_eq!(plan.name, "tiny-base");
        assert_eq!(plan.model, "synth-tiny");
        assert_eq!(
            plan.layers.len(),
            model.engine.graph.num_enc_points()
        );
        assert!(plan.layers.iter().all(|l| l.overq == cfg.baseline));
        // it is engine-ready, like any tuned plan
        let qc = plan.to_quant_config();
        let out = model.engine.forward_quant(&images, &qc).unwrap();
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn report_shapes_and_budget_holds() {
        let model = synth_model("synth-tiny", 3).unwrap();
        let (images, _) = shapes::gen_batch(3, 0, 8);
        let cfg = AutotuneConfig::default();
        let (table, result) = run(&model, &images, &cfg).unwrap();
        // one row per enc point + PLAN + BASE summary rows
        assert_eq!(table.rows.len(), 2 + 2);
        // the contract: equal or lower MAC-weighted PE area
        assert!(
            result.total_area <= result.baseline_area + 1e-9,
            "plan area {} exceeds baseline {}",
            result.total_area,
            result.baseline_area
        );
    }

    #[test]
    fn measured_report_and_refinement_guarantee() {
        let model = synth_model("synth-tiny", 3).unwrap();
        let (images, _) = shapes::gen_batch(3, 0, 8);
        // probe images disjoint from the profiling split (indices 8..32)
        let (pimg, plab) = shapes::gen_batch(3, 8, 24);
        let probe = ProbeSplit::new(pimg, plab).unwrap();
        let mut cfg = AutotuneConfig::default();
        cfg.space.weight_bits = vec![0, 4, 6];
        let (layer_table, acc_table, m) = run_measured(&model, &images, &probe, &cfg).unwrap();
        assert_eq!(layer_table.rows.len(), 2);
        // every candidate + the baseline control row
        assert_eq!(acc_table.rows.len(), m.candidates.len() + 1);
        // refinement can only match or beat the proxy-only plan
        let chosen = &m.candidates[m.chosen];
        assert!(
            chosen.measured_acc >= m.proxy_acc - 1e-12,
            "chosen {} < proxy-only {}",
            chosen.measured_acc,
            m.proxy_acc
        );
        // evidence lands in the emitted plan, within the area contract
        let probe_ev = m.result.plan.probe.expect("probe evidence");
        assert_eq!(probe_ev.images, 24);
        assert!((probe_ev.accuracy - chosen.measured_acc).abs() < 1e-12);
        assert!(m.result.total_area <= m.result.baseline_area + 1e-9);
    }
}
