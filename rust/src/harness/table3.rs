//! Table 3 — PE area breakdown from the calibrated gate-level model.
//!
//! Columns: multiply / add / other-datapath areas (µm²). Rows: baseline,
//! OverQ-RO (+ overheads vs same-bit and +1b baselines), OverQ-Full
//! (+ overheads vs same-bit, +1b, +2b baselines) — the structure of the
//! paper's Table 3.

use anyhow::Result;

use crate::area::{pe_breakdown, PeAreas, PeVariant};
use crate::util::bench::Table;

pub struct Table3Config {
    pub act_bits: u32,
}

impl Default for Table3Config {
    fn default() -> Self {
        Table3Config { act_bits: 4 }
    }
}

fn fmt(a: &PeAreas) -> Vec<String> {
    vec![
        format!("{:.2}", a.multiply),
        format!("{:.2}", a.add),
        format!("{:.2}", a.other),
    ]
}

fn overhead_row(label: &str, ovq: &PeAreas, base: &PeAreas) -> Vec<String> {
    vec![
        label.to_string(),
        format!("{:+.2}%", (ovq.multiply / base.multiply - 1.0) * 100.0),
        format!("{:+.2}%", (ovq.add / base.add - 1.0) * 100.0),
        format!("{:+.2}%", (ovq.other / base.other - 1.0) * 100.0),
    ]
}

pub fn run(cfg: &Table3Config) -> Result<Table> {
    let b = cfg.act_bits;
    let base = pe_breakdown(PeVariant::Baseline, b);
    let base1 = pe_breakdown(PeVariant::Baseline, b + 1);
    let base2 = pe_breakdown(PeVariant::Baseline, b + 2);
    let ro = pe_breakdown(PeVariant::OverQRo, b);
    let full = pe_breakdown(PeVariant::OverQFull, b);

    let mut t = Table::new(
        &format!("Table 3 — PE area breakdown (µm², A{b} W8)"),
        &["Area (um^2)", "Multiply", "Add", "Other Datapath"],
    );
    fn named(t: &mut Table, label: &str, a: &PeAreas) {
        let mut row = vec![label.to_string()];
        row.extend(fmt(a));
        t.row(row);
    }
    named(&mut t, "Baseline", &base);
    named(&mut t, "OverQ RO", &ro);
    t.row(overhead_row("Overhead", &ro, &base));
    t.row(overhead_row("Overhead +1b", &ro, &base1));
    named(&mut t, "OverQ Full", &full);
    t.row(overhead_row("Overhead", &full, &base));
    t.row(overhead_row("Overhead +1b", &full, &base1));
    t.row(overhead_row("Overhead +2b", &full, &base2));
    // totals footer (the paper's ≈0.5 % whole-PE claim context)
    t.row(vec![
        "Total overhead (Full)".into(),
        format!("{:.2}", base.total()),
        format!("{:.2}", full.total()),
        format!("{:+.2}%", (full.total() / base.total() - 1.0) * 100.0),
    ]);
    Ok(t)
}
