//! Figure 6(b) — quantization-error breakdown between small and large
//! values vs clipping threshold, on one mini-ResNet-18 layer.
//!
//! Error = Σ |x - Q(x)| split at the paper's small/large boundary. Shows
//! the opposing trends (clipping error on large values falls with the
//! threshold; rounding error on small values rises) and how range
//! overwrite + cascading collapse the large-value error.

use anyhow::Result;

use crate::harness::calibrate::{profile_acts, subset};
use crate::models::Artifacts;
use crate::overq::{decode_rows, encode_tensor, OverQConfig};
use crate::quant::fake_quant_tensor;
use crate::tensor::TensorF;
use crate::util::bench::Table;

pub struct Fig6bConfig {
    pub model: String,
    /// Enc point standing in for the paper's "arbitrary layer".
    pub layer: usize,
    pub bits: u32,
    pub cascade: usize,
    /// Small/large split, as a multiple of the layer std (the paper's
    /// figure splits at 4 on its axis units).
    pub split_std: f64,
    pub thresholds: Vec<f64>,
    pub images: usize,
}

impl Default for Fig6bConfig {
    fn default() -> Self {
        Fig6bConfig {
            model: "resnet18m".into(),
            layer: 4,
            bits: 4,
            cascade: 4,
            split_std: 4.0,
            thresholds: vec![1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0, 8.0],
            images: 128,
        }
    }
}

fn abs_err_split(x: &TensorF, q: &TensorF, boundary: f32) -> (f64, f64) {
    let mut small = 0f64;
    let mut large = 0f64;
    for (&a, &b) in x.data.iter().zip(&q.data) {
        let e = (a - b).abs() as f64;
        if a.abs() <= boundary {
            small += e;
        } else {
            large += e;
        }
    }
    (small, large)
}

pub fn run(arts: &Artifacts, cfg: &Fig6bConfig) -> Result<Table> {
    let model = arts.load_model(&cfg.model)?;
    let pf = arts.load_dataset("profileset")?;
    let (images, _) = subset(&pf, cfg.images);
    let srcs = model.engine.graph.enc_point_sources();
    let layer = cfg.layer.min(srcs.len() - 1);
    let (_, taps) = model.engine.forward_f32(&images, &[srcs[layer]])?;
    let x = &taps[0];
    let prof = profile_acts(&model, &images, 4096)?;
    let st = prof.stats[layer];
    let boundary = cfg.split_std as f32 * st.std;
    let qmax = ((1u32 << cfg.bits) - 1) as f32;

    let mut table = Table::new(
        &format!(
            "Figure 6(b) — abs quant error, {} enc{} (split at {:.1} std)",
            cfg.model, layer, cfg.split_std
        ),
        &[
            "clip (std)",
            "small:base",
            "large:base",
            "large:RO c=1",
            "large:RO+casc",
            "small:full OverQ",
        ],
    );
    for &t in &cfg.thresholds {
        let clip = (st.mean + t as f32 * st.std).clamp(1e-6, st.max.max(1e-6));
        let scale = clip / qmax;
        let base = fake_quant_tensor(x, scale, cfg.bits);
        let (s_b, l_b) = abs_err_split(x, &base, boundary);
        let dec = |ovq: OverQConfig| -> (f64, f64) {
            let enc = encode_tensor(x, scale, &ovq);
            let d = decode_rows(&enc.codes, &enc.state, scale, &ovq);
            abs_err_split(x, &d, boundary)
        };
        let (_, l_ro1) = dec(OverQConfig::ro(cfg.bits, 1));
        let (_, l_roc) = dec(OverQConfig::ro(cfg.bits, cfg.cascade));
        let (s_full, _) = dec(OverQConfig::full(cfg.bits, cfg.cascade));
        table.row(vec![
            format!("{t:.1}"),
            format!("{s_b:.1}"),
            format!("{l_b:.1}"),
            format!("{l_ro1:.1}"),
            format!("{l_roc:.1}"),
            format!("{s_full:.1}"),
        ]);
    }
    Ok(table)
}
