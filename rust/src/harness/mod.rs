//! Experiment harnesses — one per paper table/figure (DESIGN.md §5),
//! plus the per-layer policy report (`policy`).

pub mod calibrate;
pub mod fig6a;
pub mod fig6b;
pub mod hwcmp;
pub mod policy;
pub mod table1;
pub mod table2;
pub mod table3;
