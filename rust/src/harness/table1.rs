//! Table 1 — cascading outlier coverage vs Eq. (1) theory.
//!
//! Paper: three layers of ResNet-50 quantized to A4; rows are cascade
//! factors 1..6, columns 'Theory' (Eq. 1 at p0 = 0.5) and per-layer
//! empirical coverage, plus a final zero-percentage row. We reproduce it
//! on three enc-point activations of the bottleneck mini-ResNet-50.

use anyhow::Result;

use crate::harness::calibrate::{profile_acts, subset};
use crate::models::Artifacts;
use crate::overq::{coverage_stats, theory_coverage, OverQConfig};
use crate::util::bench::Table;

pub struct Table1Config {
    pub model: String,
    /// Enc points standing in for the paper's three layers.
    pub layers: Vec<usize>,
    pub bits: u32,
    /// Clip threshold in stds (controls the outlier rate like the
    /// paper's A4 setting).
    pub std_t: f64,
    pub images: usize,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            model: "resnet50m".into(),
            layers: vec![9, 13, 15],
            bits: 4,
            std_t: 4.0,
            images: 128,
        }
    }
}

pub fn run(arts: &Artifacts, cfg: &Table1Config) -> Result<Table> {
    let model = arts.load_model(&cfg.model)?;
    let pf = arts.load_dataset("profileset")?;
    let (images, _) = subset(&pf, cfg.images);
    let srcs = model.engine.graph.enc_point_sources();
    let layers: Vec<usize> = cfg
        .layers
        .iter()
        .map(|&l| l.min(srcs.len() - 1))
        .collect();
    let (_, taps) = model.engine.forward_f32(
        &images,
        &layers.iter().map(|&l| srcs[l]).collect::<Vec<_>>(),
    )?;
    let prof = profile_acts(&model, &images, 4096)?;
    let qmax = ((1u32 << cfg.bits) - 1) as f32;

    let mut headers = vec!["Cascade Factor".to_string(), "Theory".to_string()];
    for (i, &l) in layers.iter().enumerate() {
        headers.push(format!("Layer{} (enc{})", i + 1, l));
    }
    let mut table = Table::new(
        "Table 1 — Cascading Outlier Coverage (%)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for c in 1..=6 {
        let mut row = vec![
            c.to_string(),
            format!("{:.1}", theory_coverage(0.5, c) * 100.0),
        ];
        for (i, t) in taps.iter().enumerate() {
            let scale =
                (prof.stats[layers[i]].mean + cfg.std_t as f32 * prof.stats[layers[i]].std) / qmax;
            let s = coverage_stats(t, scale.max(1e-6), &OverQConfig::ro(cfg.bits, c));
            row.push(format!("{:.1}", s.coverage() * 100.0));
        }
        table.row(row);
    }
    // zero-percentage footer row
    let mut zrow = vec!["Zero Perc.".to_string(), "50.0".to_string()];
    for t in &taps {
        zrow.push(format!("{:.1}", t.zero_frac() * 100.0));
    }
    table.row(zrow);
    Ok(table)
}
