//! Table 2 — the full accuracy grid: 4 models × {MMSE, ZeroQ, OCS, STD}
//! × {A4, A5} × {±OverQ}, all at W8 with per-channel weight quantization.
//!
//! Matches the paper's protocol: OverQ = range + precision overwrite with
//! cascade factor 4; OCS and ZeroQ are combined with MMSE clipping; STD
//! sweeps the threshold on the PROFILING split and keeps the best.

use anyhow::Result;

use crate::harness::calibrate::{
    profile_acts, quant_config, std_sweep_best, subset, zeroq_profile,
};
use crate::models::Artifacts;
use crate::overq::OverQConfig;
use crate::quant::clip::ClipMethod;
use crate::util::bench::Table;

pub struct Table2Config {
    pub models: Vec<String>,
    pub bits: Vec<u32>,
    pub cascade: usize,
    pub eval_images: usize,
    pub profile_images: usize,
    pub ocs_ratio: f64,
    pub std_grid: Vec<f64>,
    pub batch: usize,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config {
            models: vec![
                "resnet18m".into(),
                "resnet50m".into(),
                "densenet21m".into(),
                "vgg11m".into(),
            ],
            bits: vec![4, 5],
            cascade: 4,
            eval_images: 512,
            profile_images: 256,
            ocs_ratio: 0.05,
            std_grid: vec![2.0, 3.0, 4.0, 5.0, 6.0, 8.0],
            batch: 64,
        }
    }
}

pub fn run(arts: &Artifacts, cfg: &Table2Config) -> Result<Table> {
    let ev = arts.load_dataset("evalset")?;
    let pf = arts.load_dataset("profileset")?;
    let (eimg, elab) = subset(&ev, cfg.eval_images);
    let (pimg, plab) = subset(&pf, cfg.profile_images);

    let mut headers = vec!["Clipping Method".to_string()];
    for m in &cfg.models {
        for &b in &cfg.bits {
            headers.push(format!("{m} A{b}"));
        }
    }
    let mut table = Table::new(
        "Table 2 — OverQ ImageNet-protocol evaluation (top-1, W8)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let methods = ["MMSE", "ZeroQ", "OCS", "STD"];
    let mut rows: Vec<Vec<String>> = methods
        .iter()
        .flat_map(|m| {
            vec![
                vec![m.to_string()],
                vec![format!("{m} + OverQ")],
            ]
        })
        .collect();
    let mut float_row = vec!["Float".to_string()];

    for mname in &cfg.models {
        let model = arts.load_model(mname)?;
        let profile = profile_acts(&model, &pimg, 4096)?;
        let zprofile = zeroq_profile(&model, cfg.profile_images.min(128), 99)?;
        let mut ocs_model = arts.load_model(mname)?;
        ocs_model.engine.apply_ocs(cfg.ocs_ratio);
        let facc = model.engine.accuracy_f32(&eimg, &elab, cfg.batch)?;

        for &bits in &cfg.bits {
            let base = OverQConfig::baseline(bits);
            let full = OverQConfig::full(bits, cfg.cascade);
            let mut col = Vec::new();
            for (mi, method) in methods.iter().enumerate() {
                for (vi, ovq) in [base, full].into_iter().enumerate() {
                    let acc = match *method {
                        "MMSE" => {
                            let qc = quant_config(&profile, ClipMethod::Mmse, ovq);
                            model.engine.accuracy_quant(&eimg, &elab, cfg.batch, &qc)?
                        }
                        "ZeroQ" => {
                            // data-free calibration + MMSE clipping
                            let qc = quant_config(&zprofile, ClipMethod::Mmse, ovq);
                            model.engine.accuracy_quant(&eimg, &elab, cfg.batch, &qc)?
                        }
                        "OCS" => {
                            let qc = quant_config(&profile, ClipMethod::Mmse, ovq);
                            ocs_model
                                .engine
                                .accuracy_quant(&eimg, &elab, cfg.batch, &qc)?
                        }
                        "STD" => {
                            let (_, qc) = std_sweep_best(
                                &model,
                                &profile,
                                ovq,
                                &pimg,
                                &plab,
                                &cfg.std_grid,
                                cfg.batch,
                            )?;
                            model.engine.accuracy_quant(&eimg, &elab, cfg.batch, &qc)?
                        }
                        _ => unreachable!(),
                    };
                    let _ = (mi, vi);
                    col.push(acc);
                }
            }
            for (ri, acc) in col.into_iter().enumerate() {
                rows[ri].push(format!("{:.2}", acc * 100.0));
            }
            float_row.push(format!("{:.2}", facc * 100.0));
        }
        eprintln!("[table2] {mname} done");
    }
    for r in rows {
        table.row(r);
    }
    table.row(float_row);
    Ok(table)
}
