//! Hardware comparison (paper §5.3 discussion + Fig. 2 contrast):
//! systolic-array cycle/utilization study and the OverQ-vs-OLAccel
//! storage/area comparison.

use anyhow::Result;

use crate::harness::calibrate::{profile_acts, subset};
use crate::models::Artifacts;
use crate::nn::conv::im2col;
use crate::olaccel;
use crate::overq::{encode_tensor, OverQConfig};
use crate::sim::SystolicArray;
use crate::tensor::TensorI;
use crate::util::bench::Table;

pub struct HwcmpConfig {
    pub model: String,
    pub layer: usize,
    pub bits: u32,
    pub cascade: usize,
    pub std_t: f64,
    pub rows: usize,
    pub cols: usize,
    pub images: usize,
}

impl Default for HwcmpConfig {
    fn default() -> Self {
        HwcmpConfig {
            model: "resnet18m".into(),
            layer: 2,
            bits: 4,
            cascade: 4,
            std_t: 3.0,
            rows: 32,
            cols: 16,
            images: 8,
        }
    }
}

/// Simulate one conv layer's matmul on the systolic array, baseline vs
/// OverQ PEs, and report cycles / utilization / OverQ traffic, plus the
/// OLAccel storage-and-area comparison at the measured outlier rate.
pub fn run(arts: &Artifacts, cfg: &HwcmpConfig) -> Result<Table> {
    let model = arts.load_model(&cfg.model)?;
    let pf = arts.load_dataset("profileset")?;
    let (images, _) = subset(&pf, cfg.images);
    let srcs = model.engine.graph.enc_point_sources();
    let layer = cfg.layer.min(srcs.len() - 1);
    let prof = profile_acts(&model, &images, 4096)?;
    let (_, taps) = model.engine.forward_f32(&images, &[srcs[layer]])?;
    let x = &taps[0];
    let qmax = ((1u32 << cfg.bits) - 1) as f32;
    let st = prof.stats[layer];
    let scale = ((st.mean + cfg.std_t as f32 * st.std) / qmax).max(1e-6);

    // encode then im2col (3x3 conv shape), mirroring the engine
    let c = x.dims()[3];
    let n_out = 2 * c; // representative output-channel count
    let ovq = OverQConfig::full(cfg.bits, cfg.cascade);
    let enc = encode_tensor(x, scale, &ovq);
    let (ccols, _, _) = im2col(&enc.codes, 3, 3, 1);
    let (scols, _, _) = im2col(&enc.state, 3, 3, 1);
    let k = 9 * c;
    let m = ccols.numel() / k;
    let mut rng = crate::util::rng::Rng::new(17);
    let mut w = TensorI::zeros(&[k, n_out]);
    for v in w.data.iter_mut() {
        *v = rng.range(-127, 128) as i32;
    }

    // outlier rate for the OLAccel cost model
    let cov = crate::overq::coverage_stats(x, scale, &ovq);
    let outlier_frac = cov.outliers as f64 / cov.total as f64;

    let overq_arr = SystolicArray::new(cfg.rows, cfg.cols, true);
    let (_, s_ovq) = overq_arr.run(&ccols, &scols, &w, &ovq, c)?;
    let base_cfg = OverQConfig::baseline(cfg.bits);
    let encb = encode_tensor(x, scale, &base_cfg);
    let (bcols, _, _) = im2col(&encb.codes, 3, 3, 1);
    let (bscols, _, _) = im2col(&encb.state, 3, 3, 1);
    let base_arr = SystolicArray::new(cfg.rows, cfg.cols, false);
    let (_, s_base) = base_arr.run(&bcols, &bscols, &w, &base_cfg, c)?;

    let ol = olaccel::cost_model(outlier_frac, cfg.bits);

    let mut t = Table::new(
        &format!(
            "HW comparison — {} enc{} ({}x{} array, M={m} K={k} N={n_out})",
            cfg.model, layer, cfg.rows, cfg.cols
        ),
        &["metric", "baseline array", "OverQ array", "OLAccel model"],
    );
    t.row(vec![
        "cycles".into(),
        s_base.cycles.to_string(),
        s_ovq.cycles.to_string(),
        format!("{} (+sparse engine)", s_base.cycles),
    ]);
    t.row(vec![
        "useful-MAC utilization".into(),
        format!("{:.3}", s_base.utilization()),
        format!("{:.3}", s_ovq.utilization()),
        "-".into(),
    ]);
    t.row(vec![
        "zero-slot fraction".into(),
        format!("{:.3}", s_base.zero_frac()),
        format!("{:.3}", s_ovq.zero_frac()),
        "-".into(),
    ]);
    t.row(vec![
        "overq-routed MACs".into(),
        "0".into(),
        s_ovq.overq_macs.to_string(),
        format!("{} (sparse 16b)", (outlier_frac * (m * k) as f64) as u64),
    ]);
    t.row(vec![
        "outlier fraction".into(),
        format!("{:.4}", outlier_frac),
        format!("{:.4}", outlier_frac),
        format!("{:.4}", outlier_frac),
    ]);
    t.row(vec![
        "storage bits / element".into(),
        "0".into(),
        format!("{:.2} (state lane)", olaccel::overq_state_bits(true)),
        format!("{:.2} (32b indices)", ol.index_bits_per_elem),
    ]);
    t.row(vec![
        "MAC-area overhead".into(),
        "0%".into(),
        format!(
            "{:+.2}%",
            (crate::area::pe_breakdown(crate::area::PeVariant::OverQFull, cfg.bits).total()
                / crate::area::pe_breakdown(crate::area::PeVariant::Baseline, cfg.bits).total()
                - 1.0)
                * 100.0
        ),
        format!("{:+.2}% (sparse PEs)", ol.area_overhead * 100.0),
    ]);
    Ok(t)
}
