//! Figure 6(a) — accuracy vs clipping threshold for baseline, range
//! overwrite, RO+cascading, and full OverQ (W8A4 mini-ResNet-18).
//!
//! Reproduces the paper's core tradeoff plot: each method peaks at some
//! threshold; OverQ peaks EARLIER (smaller threshold) and HIGHER because
//! covered outliers stop pushing the optimum outward.

use anyhow::Result;

use crate::harness::calibrate::{profile_acts, quant_config, subset};
use crate::models::Artifacts;
use crate::overq::OverQConfig;
use crate::quant::clip::ClipMethod;
use crate::util::bench::Table;

pub struct Fig6aConfig {
    pub model: String,
    pub bits: u32,
    pub cascade: usize,
    pub thresholds: Vec<f64>,
    pub eval_images: usize,
    pub profile_images: usize,
}

impl Default for Fig6aConfig {
    fn default() -> Self {
        Fig6aConfig {
            model: "resnet18m".into(),
            bits: 4,
            cascade: 4,
            thresholds: vec![1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0, 7.0, 8.0, 10.0],
            eval_images: 512,
            profile_images: 256,
        }
    }
}

pub fn run(arts: &Artifacts, cfg: &Fig6aConfig) -> Result<Table> {
    let model = arts.load_model(&cfg.model)?;
    let ev = arts.load_dataset("evalset")?;
    let pf = arts.load_dataset("profileset")?;
    let (pimg, _) = subset(&pf, cfg.profile_images);
    let profile = profile_acts(&model, &pimg, 4096)?;
    let (eimg, elab) = subset(&ev, cfg.eval_images);

    let variants: Vec<(&str, OverQConfig)> = vec![
        ("baseline", OverQConfig::baseline(cfg.bits)),
        ("RO (c=1)", OverQConfig::ro(cfg.bits, 1)),
        ("RO+cascade", OverQConfig::ro(cfg.bits, cfg.cascade)),
        ("full OverQ", OverQConfig::full(cfg.bits, cfg.cascade)),
    ];
    let mut headers = vec!["clip (std)".to_string()];
    headers.extend(variants.iter().map(|(n, _)| n.to_string()));
    let mut table = Table::new(
        &format!(
            "Figure 6(a) — top-1 accuracy vs clip threshold ({} W8A{})",
            cfg.model, cfg.bits
        ),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &t in &cfg.thresholds {
        let mut row = vec![format!("{t:.1}")];
        for (_, ovq) in &variants {
            let qc = quant_config(&profile, ClipMethod::StdMul(t), *ovq);
            let acc = model.engine.accuracy_quant(&eimg, &elab, 64, &qc)?;
            row.push(format!("{:.4}", acc));
        }
        table.row(row);
    }
    Ok(table)
}
