//! Plan hot-reload: watch `plans/*.plan.json` on disk and swap plans
//! through the admin plane, no operator in the loop.
//!
//! The deployment story OverQ targets (paper §1) is a service provider
//! re-tuning customer plans offline and shipping the winners by writing
//! plan files — the serving layer must pick them up without a restart
//! and without an admin call. [`PlanWatch`] is the synchronous core: one
//! [`PlanWatch::poll`] scans the directory once, loads changed files
//! through the versioned schema loader (`policy::DeploymentPlan::load`,
//! v1 and v2 both accepted), and applies each matching plan with
//! [`super::ModelHandle::swap_plan`] — which the coordinator already
//! guarantees is atomic with respect to in-flight requests. A bad file
//! (unparseable JSON, schema violation, an Error-level `overq lint`
//! finding — see `docs/static_analysis.md`) is
//! *rejected with the previously served plan left untouched*; the error
//! is counted in the shard metrics (`watch_errors`, `last_watch_error`)
//! and returned in the [`WatchReport`].
//!
//! [`spawn`] (or the convenience [`super::ModelHandle::watch_plans`])
//! wraps a `PlanWatch` in a background polling thread; dropping the
//! returned [`PlanWatcher`] stops it. Tests drive `poll` directly so
//! reload edge cases stay deterministic.
//!
//! Several shards may watch the same directory: each one applies only
//! the plans tuned for its own model and silently skips the rest, so a
//! single `plans/` drop-box can feed a whole multi-model coordinator.
//! See `docs/operations.md` for the day-2 lifecycle.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::sync::Arc;
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use super::server::ModelHandle;
use crate::policy::DeploymentPlan;

/// Cheap change signature for one watched file. The mtime+len pair
/// decides whether the file is re-read at all; the FNV-1a content hash
/// then suppresses spurious re-applies when the metadata changed but
/// the content did not (touch(1), rename-into-place of identical
/// bytes). A rewrite that keeps both length and mtime (possible on
/// filesystems with coarse timestamps) is not detected until either
/// changes — writers should rename a new file into place, which always
/// refreshes the metadata.
#[derive(Clone, Copy, PartialEq, Eq)]
struct FileSig {
    mtime: SystemTime,
    len: u64,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Outcome of one [`PlanWatch::poll`].
#[derive(Clone, Debug, Default)]
pub struct WatchReport {
    /// Plan aliases swapped (or first registered) this poll.
    pub applied: Vec<String>,
    /// Files whose new content was rejected; the previously served plan
    /// (if any) keeps serving. One entry per content *change*, not per
    /// poll — an unchanged bad file is not re-reported.
    pub errors: Vec<(PathBuf, String)>,
    /// `*.plan.json` files seen in the directory this poll.
    pub scanned: usize,
    /// Files skipped because their plan targets another model.
    pub skipped_other_model: usize,
}

/// Synchronous plan-directory watcher for one model shard. Create it
/// with [`PlanWatch::new`], then either call [`PlanWatch::poll`]
/// yourself (deterministic — what the tests do) or hand it to [`spawn`]
/// for a background polling loop.
pub struct PlanWatch {
    handle: ModelHandle,
    dir: PathBuf,
    seen: HashMap<PathBuf, (FileSig, u64)>,
    /// Last directory-level error (e.g. the directory vanished), so a
    /// persistent condition is reported once, not once per poll.
    dir_error: Option<String>,
}

impl PlanWatch {
    /// Watch `dir` for the model behind `handle`. The directory must
    /// exist; nothing is scanned until the first [`PlanWatch::poll`].
    pub fn new(handle: ModelHandle, dir: impl AsRef<Path>) -> Result<PlanWatch> {
        let dir = dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.is_dir(),
            "plan watch directory {} does not exist",
            dir.display()
        );
        Ok(PlanWatch {
            handle,
            dir,
            seen: HashMap::new(),
            dir_error: None,
        })
    }

    /// The watched directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Scan once: load every new/changed `*.plan.json`, swap matching
    /// plans through the admin plane, reject bad files with the old plan
    /// left serving. Never panics on filesystem races — a file that
    /// vanishes mid-scan is just skipped until the next poll.
    pub fn poll(&mut self) -> WatchReport {
        let mut report = WatchReport::default();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => {
                self.dir_error = None;
                e
            }
            Err(e) => {
                // a persistent condition (directory deleted) is reported
                // once, not on all of the following polls
                let msg = format!("read_dir: {e}");
                if self.dir_error.as_deref() != Some(msg.as_str()) {
                    self.dir_error = Some(msg.clone());
                    self.surface_error(&mut report, self.dir.clone(), msg);
                }
                return report;
            }
        };
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.ends_with(".plan.json"))
                    .unwrap_or(false)
            })
            .collect();
        // deterministic apply order regardless of readdir order
        paths.sort();
        // forget vanished files: the registered plan keeps serving (the
        // admin plane has no unregister — see docs/operations.md), but a
        // file recreated later must count as new content and re-apply
        self.seen.retain(|p, _| paths.contains(p));
        for path in paths {
            report.scanned += 1;
            let Ok(meta) = std::fs::metadata(&path) else {
                continue; // vanished mid-scan
            };
            let sig = FileSig {
                mtime: meta.modified().unwrap_or(SystemTime::UNIX_EPOCH),
                len: meta.len(),
            };
            if self.seen.get(&path).map(|(s, _)| *s == sig).unwrap_or(false) {
                continue; // fast path: metadata unchanged
            }
            let Ok(bytes) = std::fs::read(&path) else {
                continue; // vanished mid-scan
            };
            let hash = fnv1a(&bytes);
            if self
                .seen
                .get(&path)
                .map(|(_, h)| *h == hash)
                .unwrap_or(false)
            {
                // content identical (e.g. touch(1)): refresh the sig only
                self.seen.insert(path.clone(), (sig, hash));
                continue;
            }
            // record the content as seen whether or not it applies, so a
            // bad or foreign file is diagnosed once, not every poll
            self.seen.insert(path.clone(), (sig, hash));
            match self.load_and_apply(&path, &bytes) {
                Ok(Some(alias)) => report.applied.push(alias),
                Ok(None) => report.skipped_other_model += 1,
                Err(e) => self.surface_error(&mut report, path, format!("{e:#}")),
            }
        }
        report
    }

    /// Parse + validate one plan file and swap it in if it targets this
    /// shard's model. `Ok(None)` = valid plan for another model.
    fn load_and_apply(&self, path: &Path, bytes: &[u8]) -> Result<Option<String>> {
        let text = std::str::from_utf8(bytes).context("plan file is not UTF-8")?;
        let value = crate::util::json::parse(text)
            .map_err(|e| anyhow::anyhow!("json parse: {e}"))?;
        let plan = DeploymentPlan::from_json(&value)
            .with_context(|| format!("parse plan {}", path.display()))?;
        if plan.model != self.handle.model_name() {
            return Ok(None);
        }
        // static analysis gate: a plan with Error-level lint findings is
        // rejected here — the lint code lands in `last_watch_error` and
        // the previously served plan keeps serving untouched
        let report = crate::analysis::lint_plan(&plan);
        if let Some(d) = report.first_error() {
            anyhow::bail!("lint: {d}");
        }
        let alias = plan.name.clone();
        self.handle.swap_plan(&alias, plan)?;
        self.handle.note_plan_swap();
        Ok(Some(alias))
    }

    fn surface_error(&self, report: &mut WatchReport, path: PathBuf, msg: String) {
        let full = format!("{}: {msg}", path.display());
        self.handle.note_watch_error(&full);
        report.errors.push((path, msg));
    }
}

/// Handle to a background plan-watch thread. Dropping it (or calling
/// [`PlanWatcher::stop`]) stops the polling loop and joins the thread.
pub struct PlanWatcher {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl PlanWatcher {
    /// Stop polling and join the watcher thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for PlanWatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run `watch` on a background thread, polling every `interval`. The
/// thread polls immediately on startup, but that first scan races any
/// traffic submitted right after this returns — call
/// [`PlanWatch::poll`] synchronously first if startup registration must
/// be ordered before traffic (which is what
/// [`super::ModelHandle::watch_plans`] does).
pub fn spawn(mut watch: PlanWatch, interval: Duration) -> PlanWatcher {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let thread = std::thread::Builder::new()
        .name(format!("overq-watch-{}", watch.handle.model_name()))
        .spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                let _ = watch.poll();
                // sleep in small slices so stop() returns promptly even
                // with long poll intervals
                let mut left = interval;
                while !flag.load(Ordering::Relaxed) && left > Duration::ZERO {
                    let nap = left.min(Duration::from_millis(20));
                    std::thread::sleep(nap);
                    left = left.saturating_sub(nap);
                }
            }
        })
        .expect("spawn plan watcher");
    PlanWatcher {
        stop,
        thread: Some(thread),
    }
}
