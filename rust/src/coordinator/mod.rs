//! Serving coordinator — the L3 request path.
//!
//! Architecture: clients submit [`InferRequest`]s over a channel; a
//! single worker thread (an actor owning the non-`Send` PJRT state)
//! drains the queue through the [`batcher`], routes each group to the
//! best-fitting compiled executable ([`router`]) or to the native
//! engine backend (deployment-plan variants `plan:<name>` and
//! `native_fp32`), executes, and replies per-request. Python never
//! appears on this path — the executables were AOT-compiled by
//! `make artifacts`, and plan variants run the in-process engine.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use metrics::MetricsSnapshot;
pub use server::{InferRequest, InferResponse, InferResult, Server, ServerConfig};
