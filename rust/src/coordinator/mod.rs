//! Serving coordinator — the L3 request path.
//!
//! Architecture: a [`Coordinator`] hosts N model shards; each shard is
//! a single worker thread (an actor owning the non-`Send` PJRT state)
//! that drains its queue through the [`batcher`], routes each group to
//! the best-fitting compiled executable ([`router`]) or to the native
//! engine backend (deployment-plan variants `plan:<name>` and the fp32
//! reference paths), executes, and replies per-request. Clients hold a
//! cheap [`ModelHandle`] and submit typed [`VariantSpec`]s ([`variant`])
//! that are validated at `submit` time; weighted A/B traffic splits
//! resolve through a deterministic seeded router so experiments
//! reproduce exactly. Python never appears on this path — the
//! executables were AOT-compiled by `make artifacts`, and plan variants
//! run the in-process engine.
//!
//! See `docs/serving.md` for the full API walkthrough.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod variant;

pub use metrics::{MetricsSnapshot, VariantSnapshot};
pub use server::{
    Coordinator, InferRequest, InferResponse, InferResult, ModelHandle, ServerBuilder,
};
pub use variant::{Backend, VariantSpec};
