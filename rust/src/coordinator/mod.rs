//! Serving coordinator — the L3 request path.
//!
//! Architecture: a [`Coordinator`] hosts N model shards; each shard is
//! a bounded, deadline-aware submission queue ([`batcher`]) drained by
//! a fleet of replica worker threads. Replicas pull cross-request
//! batches (single variant group, tenant-fair), route each batch to
//! the best-fitting compiled executable ([`router`]) or to the native
//! engine backend (deployment-plan variants `plan:<name>` and the fp32
//! reference paths), execute, and reply per-request. Overload sheds at
//! admission with typed errors instead of queueing unboundedly, and a
//! panicking replica fail-stops without taking the shard down. Clients
//! hold a cheap [`ModelHandle`] and submit typed [`VariantSpec`]s
//! ([`variant`]) that are validated at `submit` time; weighted A/B
//! traffic splits resolve through a deterministic seeded router so
//! experiments reproduce exactly. Python never appears on this path —
//! the executables were AOT-compiled by `make artifacts`, and plan
//! variants run the in-process engine.
//!
//! Day-2 operation is closed-loop: [`router::BanditRouter`] learns
//! outcome-aware split weights from live per-variant rewards (with a
//! pinned control arm and an exploration floor), and [`watch`] hot-
//! reloads retuned `*.plan.json` files from disk through the same
//! admin plane — no operator in the loop for either.
//!
//! The telemetry plane rides on the same handles: request spans and
//! OverQ coverage counters aggregate per shard, and [`telemetry`]
//! exports them over HTTP (Prometheus text + JSON + JSONL traces).
//!
//! See `docs/serving.md` for the full API walkthrough,
//! `docs/operations.md` for the operations handbook and
//! `docs/observability.md` for the telemetry plane.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod telemetry;
pub mod variant;
pub mod watch;

pub use batcher::{
    BatchItem, BatchPolicy, Drained, PushError, QueueConfig, ShedReason, SubmitQueue,
};
pub use metrics::{MetricsSnapshot, TenantMetrics, VariantSnapshot};
pub use router::{round_robin_merge, ArmStats, BanditConfig, BanditRouter, BanditStrategy};
pub use server::{
    Coordinator, InferRequest, InferResponse, InferResult, ModelHandle, ReplicaFault,
    RoutingPolicy, ServeError, ServerBuilder, SubmitOpts,
};
pub use telemetry::TelemetryServer;
pub use variant::{Backend, VariantSpec};
pub use watch::{PlanWatch, PlanWatcher, WatchReport};
