//! Routing: pick the executable batch size for a pending group.

/// Choose the compiled batch size for `pending` requests from the
/// `available` (ascending) sizes: the smallest size that fits them all,
/// else the largest available (the group is split across launches).
pub fn pick_batch(pending: usize, available: &[usize]) -> Option<usize> {
    if available.is_empty() || pending == 0 {
        return None;
    }
    for &b in available {
        if b >= pending {
            return Some(b);
        }
    }
    available.last().copied()
}

/// Split a group into execution chunks of at most `exe_batch`.
pub fn chunks(pending: usize, exe_batch: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = pending;
    while left > 0 {
        let take = left.min(exe_batch);
        out.push(take);
        left -= take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn picks_tight_fit() {
        assert_eq!(pick_batch(1, &[1, 8]), Some(1));
        assert_eq!(pick_batch(2, &[1, 8]), Some(8));
        assert_eq!(pick_batch(8, &[1, 8]), Some(8));
        assert_eq!(pick_batch(12, &[1, 8]), Some(8));
        assert_eq!(pick_batch(3, &[8]), Some(8));
        assert_eq!(pick_batch(0, &[8]), None);
        assert_eq!(pick_batch(3, &[]), None);
    }

    #[test]
    fn chunking_covers_everything() {
        assert_eq!(chunks(12, 8), vec![8, 4]);
        assert_eq!(chunks(8, 8), vec![8]);
        assert_eq!(chunks(3, 8), vec![3]);
    }

    #[test]
    fn prop_chunks_sum() {
        check("chunks sum to pending", 100, |rng| {
            let pending = 1 + rng.index(100);
            let exe = 1 + rng.index(16);
            let cs = chunks(pending, exe);
            assert_eq!(cs.iter().sum::<usize>(), pending);
            assert!(cs.iter().all(|&c| c > 0 && c <= exe));
        });
    }
}
