//! Routing: executable batch-size selection, group chunking, the
//! deterministic weighted router behind A/B traffic splits, and the
//! outcome-aware [`BanditRouter`] behind `--routing bandit`.

use anyhow::Result;

use super::variant::VariantSpec;
use crate::util::rng::Rng;

/// Choose the compiled batch size for `pending` requests from the
/// `available` (ascending) sizes: the smallest size that fits them all,
/// else the largest available (the group is split across launches).
pub fn pick_batch(pending: usize, available: &[usize]) -> Option<usize> {
    if available.is_empty() || pending == 0 {
        return None;
    }
    for &b in available {
        if b >= pending {
            return Some(b);
        }
    }
    available.last().copied()
}

/// Split a group into execution chunks of at most `exe_batch`.
pub fn chunks(pending: usize, exe_batch: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = pending;
    while left > 0 {
        let take = left.min(exe_batch);
        out.push(take);
        left -= take;
    }
    out
}

/// Tenant-fair dequeue order: interleave per-tenant FIFO lanes round-
/// robin, one item per lane per round, starting from the lane holding
/// the globally oldest item. Within a lane the input order is
/// preserved, so FIFO holds per tenant while no tenant can monopolise a
/// batch just by flooding the queue. `lanes` are (lane, items) pairs
/// sorted so that `lanes[0]` holds the oldest item; returns the merged
/// item sequence.
///
/// ```
/// use overq::coordinator::router::round_robin_merge;
/// let lanes = vec![("a", vec![1, 2, 3]), ("b", vec![10])];
/// assert_eq!(round_robin_merge(lanes), vec![1, 10, 2, 3]);
/// ```
pub fn round_robin_merge<L, T>(lanes: Vec<(L, Vec<T>)>) -> Vec<T> {
    let total: usize = lanes.iter().map(|(_, v)| v.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut iters: Vec<std::vec::IntoIter<T>> =
        lanes.into_iter().map(|(_, v)| v.into_iter()).collect();
    while out.len() < total {
        for it in iters.iter_mut() {
            if let Some(x) = it.next() {
                out.push(x);
            }
        }
    }
    out
}

/// Pick an arm index proportionally to `weights` with one uniform draw
/// from `rng`. Weights must be positive; the caller validates. Because
/// the RNG is owned by the shard and seeded at build time, the arm
/// sequence for a given request order is reproducible — A/B experiments
/// can be replayed exactly.
pub fn pick_weighted(rng: &mut Rng, weights: &[f64]) -> usize {
    assert!(!weights.is_empty(), "pick_weighted needs at least one arm");
    let total: f64 = weights.iter().sum();
    let mut x = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        x -= w;
        if x < 0.0 {
            return i;
        }
    }
    weights.len() - 1 // fp rounding landed exactly on `total`
}

/// Arm-selection strategy for the [`BanditRouter`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BanditStrategy {
    /// Thompson sampling: sample each arm's posterior mean reward and
    /// route the round's exploit mass to the best sample. Converges
    /// smoothly and keeps probability-matching exploration.
    Thompson,
    /// UCB1: route the exploit mass to the arm with the highest
    /// `mean + c·sqrt(2·ln(total)/pulls)` upper confidence bound.
    Ucb,
}

impl std::str::FromStr for BanditStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BanditStrategy> {
        match s {
            "thompson" => Ok(BanditStrategy::Thompson),
            "ucb" => Ok(BanditStrategy::Ucb),
            other => anyhow::bail!("unknown bandit strategy {other:?} (thompson|ucb)"),
        }
    }
}

/// Configuration for a [`BanditRouter`].
///
/// `arms` pairs each servable (non-split) [`VariantSpec`] with a static
/// *quality prior* in `[0, 1]` — for `plan:` arms this is typically the
/// plan's probe-split accuracy (or its mean coverage when no probe ran);
/// for fp32 arms it is 1.0. The per-request reward blends this prior
/// with the request's live e2e latency (see [`BanditRouter::observe`]).
#[derive(Clone, Debug)]
pub struct BanditConfig {
    /// `(variant, quality prior)` per arm; at least two, no splits.
    pub arms: Vec<(VariantSpec, f64)>,
    /// Index of the pinned control arm (e.g. the
    /// `harness::policy::baseline_plan` variant). It always keeps at
    /// least the exploration floor of traffic, so the bandit's learned
    /// routing stays comparable against a fixed reference.
    pub control: usize,
    /// Minimum routing probability every arm keeps, regardless of
    /// observed rewards. Must satisfy `0 < floor` and
    /// `arms.len() · floor ≤ 1`.
    pub explore_floor: f64,
    /// Arm-selection strategy.
    pub strategy: BanditStrategy,
    /// Seed for the router's deterministic RNG: the same request order
    /// and reward stream reproduce the same arm sequence.
    pub seed: u64,
    /// Latency softening scale (µs) in the reward. A request served at
    /// e2e latency `l` scores `quality · tau/(tau + l)`.
    pub tau_us: f64,
}

impl BanditConfig {
    /// Config with the default exploration floor (0.05), Thompson
    /// sampling, a fixed seed, and a 5 ms latency scale.
    pub fn new(arms: Vec<(VariantSpec, f64)>, control: usize) -> BanditConfig {
        BanditConfig {
            arms,
            control,
            explore_floor: 0.05,
            strategy: BanditStrategy::Thompson,
            seed: 0x0B4D_D17E,
            tau_us: 5_000.0,
        }
    }
}

/// Point-in-time statistics for one bandit arm.
#[derive(Clone, Debug)]
pub struct ArmStats {
    /// The arm's metrics key ([`VariantSpec::key`]).
    pub key: String,
    /// Static quality prior from the config.
    pub quality: f64,
    /// Observed (completed) requests on this arm.
    pub pulls: u64,
    /// Mean observed reward (0.0 before the first observation).
    pub mean_reward: f64,
    /// Whether this is the pinned control arm.
    pub is_control: bool,
}

struct Arm {
    spec: VariantSpec,
    key: String,
    quality: f64,
    pulls: u64,
    reward_sum: f64,
    reward_sq: f64,
}

impl Arm {
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.reward_sum / self.pulls as f64
        }
    }
}

/// Standard normal draw (Box–Muller). Two RNG draws per call, always.
fn gauss(rng: &mut Rng) -> f64 {
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// Outcome-aware replacement for fixed A/B split weights: every routed
/// request draws an arm whose probability reflects the rewards observed
/// so far, while an exploration floor keeps every arm — in particular
/// the pinned control arm — alive forever.
///
/// Reward for a request served on arm `a` at e2e latency `l` µs:
///
/// ```text
/// reward = quality(a) · tau / (tau + l)      ∈ (0, 1]
/// ```
///
/// so an arm wins by being accurate (quality prior) *and* fast (live
/// latency), and the control arm's running mean is the fixed reference
/// that `regret_vs_control` in [`super::metrics::MetricsSnapshot`] is
/// computed against.
///
/// The router is deterministic: all randomness comes from one seeded
/// [`Rng`], and every [`BanditRouter::pick`] consumes a fixed number of
/// draws, so a replayed request/reward stream reproduces the exact arm
/// sequence. This is the runnable version of the routing example in
/// `docs/operations.md`:
///
/// ```
/// use overq::coordinator::router::{BanditConfig, BanditRouter};
/// use overq::coordinator::VariantSpec;
///
/// // two plan arms: the tuned candidate and the pinned baseline control
/// let mut router = BanditRouter::new(BanditConfig::new(
///     vec![
///         (VariantSpec::parse("plan:tuned")?, 0.9),
///         (VariantSpec::parse("plan:base")?, 0.3),
///     ],
///     1, // control = plan:base
/// ))?;
///
/// // simulate 1000 served requests at identical latency: the quality
/// // gap alone shifts traffic to plan:tuned...
/// for _ in 0..1000 {
///     let spec = router.pick();
///     router.observe(&spec.key(), 900.0);
/// }
/// let stats = router.arm_stats();
/// let total: u64 = stats.iter().map(|a| a.pulls).sum();
/// assert!(stats[0].pulls as f64 / total as f64 >= 0.7, "tuned arm starved");
///
/// // ...while the control arm keeps at least its exploration floor
/// assert!(stats[1].is_control);
/// assert!(stats[1].pulls as f64 / total as f64 >= 0.5 * router.explore_floor());
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct BanditRouter {
    arms: Vec<Arm>,
    control: usize,
    floor: f64,
    strategy: BanditStrategy,
    tau_us: f64,
    rng: Rng,
}

impl BanditRouter {
    /// Validate the config and build the router. Fails on fewer than two
    /// arms, split/duplicate arms, an out-of-range control index, a
    /// quality prior outside `[0, 1]`, a non-positive latency scale, or
    /// an exploration floor outside `0 < floor ≤ 1/arms`.
    pub fn new(cfg: BanditConfig) -> Result<BanditRouter> {
        anyhow::ensure!(cfg.arms.len() >= 2, "bandit routing needs at least two arms");
        anyhow::ensure!(
            cfg.control < cfg.arms.len(),
            "control arm index {} out of range (arms: {})",
            cfg.control,
            cfg.arms.len()
        );
        let n = cfg.arms.len() as f64;
        anyhow::ensure!(
            cfg.explore_floor > 0.0 && cfg.explore_floor * n <= 1.0,
            "exploration floor {} outside 0 < floor <= 1/{} — the control \
             arm's no-starvation guarantee needs a positive floor",
            cfg.explore_floor,
            cfg.arms.len()
        );
        anyhow::ensure!(
            cfg.tau_us.is_finite() && cfg.tau_us > 0.0,
            "latency scale tau_us must be positive, got {}",
            cfg.tau_us
        );
        let mut arms = Vec::with_capacity(cfg.arms.len());
        for (spec, quality) in &cfg.arms {
            anyhow::ensure!(
                !spec.is_split(),
                "bandit arms must be non-split variants, got {spec}"
            );
            anyhow::ensure!(
                quality.is_finite() && (0.0..=1.0).contains(quality),
                "arm {spec} quality prior {quality} outside [0, 1]"
            );
            let key = spec.key();
            anyhow::ensure!(
                arms.iter().all(|a: &Arm| a.key != key),
                "duplicate bandit arm {key}"
            );
            arms.push(Arm {
                spec: spec.clone(),
                key,
                quality: *quality,
                pulls: 0,
                reward_sum: 0.0,
                reward_sq: 0.0,
            });
        }
        Ok(BanditRouter {
            arms,
            control: cfg.control,
            floor: cfg.explore_floor,
            strategy: cfg.strategy,
            tau_us: cfg.tau_us,
            rng: Rng::new(cfg.seed),
        })
    }

    /// The configured exploration floor.
    pub fn explore_floor(&self) -> f64 {
        self.floor
    }

    /// Metrics key of the pinned control arm.
    pub fn control_key(&self) -> &str {
        &self.arms[self.control].key
    }

    /// Per-arm score for this round. Unobserved arms get a score above
    /// any real reward (rewards are ≤ 1), tie-broken toward lower
    /// indices, so every arm is tried before exploitation narrows.
    /// Thompson consumes two RNG draws per arm whether or not the arm
    /// has been observed, keeping the draw count per pick fixed.
    fn scores(&mut self) -> Vec<f64> {
        let total: u64 = self.arms.iter().map(|a| a.pulls).sum();
        let mut out = Vec::with_capacity(self.arms.len());
        for i in 0..self.arms.len() {
            let z = match self.strategy {
                BanditStrategy::Thompson => gauss(&mut self.rng),
                BanditStrategy::Ucb => 0.0,
            };
            let a = &self.arms[i];
            if a.pulls == 0 {
                out.push(2.0 - i as f64 * 1e-9);
                continue;
            }
            let mean = a.mean();
            out.push(match self.strategy {
                BanditStrategy::Thompson => {
                    // gaussian posterior on the mean; sample sd with a
                    // floor so exploration never collapses early
                    let var = if a.pulls >= 2 {
                        ((a.reward_sq - a.pulls as f64 * mean * mean) / (a.pulls - 1) as f64)
                            .max(0.0)
                    } else {
                        0.0625 // uninformed: sd 0.25
                    };
                    let sd = var.sqrt().max(0.02);
                    mean + sd / (a.pulls as f64).sqrt() * z
                }
                BanditStrategy::Ucb => {
                    mean + 0.7 * (2.0 * (total.max(1) as f64).ln() / a.pulls as f64).sqrt()
                }
            });
        }
        out
    }

    /// Routing probabilities for this round: every arm keeps the
    /// exploration floor; the round's winner gets the remaining mass.
    pub fn weights(&mut self) -> Vec<f64> {
        let scores = self.scores();
        let winner = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let n = self.arms.len() as f64;
        let mut w = vec![self.floor; self.arms.len()];
        w[winner] += 1.0 - n * self.floor;
        w
    }

    /// Draw the arm for one routed request.
    pub fn pick(&mut self) -> VariantSpec {
        let w = self.weights();
        let i = pick_weighted(&mut self.rng, &w);
        self.arms[i].spec.clone()
    }

    /// Feed back one served request: `key` is the resolved variant's
    /// metrics key ([`VariantSpec::key`]), `e2e_us` its end-to-end
    /// latency. Returns the recorded reward, or `None` when no arm
    /// matches (e.g. pinned-variant traffic outside the experiment).
    pub fn observe(&mut self, key: &str, e2e_us: f64) -> Option<f64> {
        let tau = self.tau_us;
        let a = self.arms.iter_mut().find(|a| a.key == key)?;
        let reward = a.quality * tau / (tau + e2e_us.max(0.0));
        a.pulls += 1;
        a.reward_sum += reward;
        a.reward_sq += reward * reward;
        Some(reward)
    }

    /// Point-in-time per-arm statistics (pulls, mean reward, control
    /// flag) — the serving layer folds these into its metrics snapshot.
    pub fn arm_stats(&self) -> Vec<ArmStats> {
        self.arms
            .iter()
            .enumerate()
            .map(|(i, a)| ArmStats {
                key: a.key.clone(),
                quality: a.quality,
                pulls: a.pulls,
                mean_reward: a.mean(),
                is_control: i == self.control,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn picks_tight_fit() {
        assert_eq!(pick_batch(1, &[1, 8]), Some(1));
        assert_eq!(pick_batch(2, &[1, 8]), Some(8));
        assert_eq!(pick_batch(8, &[1, 8]), Some(8));
        assert_eq!(pick_batch(12, &[1, 8]), Some(8));
        assert_eq!(pick_batch(3, &[8]), Some(8));
        assert_eq!(pick_batch(0, &[8]), None);
        assert_eq!(pick_batch(3, &[]), None);
    }

    #[test]
    fn chunking_covers_everything() {
        assert_eq!(chunks(12, 8), vec![8, 4]);
        assert_eq!(chunks(8, 8), vec![8]);
        assert_eq!(chunks(3, 8), vec![3]);
    }

    #[test]
    fn weighted_pick_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let w = [0.9, 0.1];
        for _ in 0..100 {
            assert_eq!(pick_weighted(&mut a, &w), pick_weighted(&mut b, &w));
        }
    }

    #[test]
    fn weighted_pick_respects_proportions() {
        let mut rng = Rng::new(4242);
        let w = [0.9, 0.1];
        let n = 10_000;
        let mut counts = [0usize; 2];
        for _ in 0..n {
            counts[pick_weighted(&mut rng, &w)] += 1;
        }
        let frac0 = counts[0] as f64 / n as f64;
        assert!((frac0 - 0.9).abs() < 0.02, "arm 0 got {frac0}");
        // single arm always wins
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(pick_weighted(&mut rng, &[5.0]), 0);
        }
    }

    fn two_arm_config(strategy: BanditStrategy) -> BanditConfig {
        let mut cfg = BanditConfig::new(
            vec![
                (VariantSpec::parse("plan:good").unwrap(), 0.9),
                (VariantSpec::parse("plan:ctrl").unwrap(), 0.3),
            ],
            1,
        );
        cfg.strategy = strategy;
        cfg
    }

    #[test]
    fn bandit_converges_to_better_arm_with_floor() {
        for strategy in [BanditStrategy::Thompson, BanditStrategy::Ucb] {
            let mut b = BanditRouter::new(two_arm_config(strategy)).unwrap();
            let n = 1000usize;
            for _ in 0..n {
                let spec = b.pick();
                // identical latency on both arms: quality decides
                b.observe(&spec.key(), 700.0);
            }
            let stats = b.arm_stats();
            let total: u64 = stats.iter().map(|a| a.pulls).sum();
            assert_eq!(total, n as u64);
            let frac_good = stats[0].pulls as f64 / n as f64;
            assert!(frac_good >= 0.7, "{strategy:?}: good arm got {frac_good}");
            let frac_ctrl = stats[1].pulls as f64 / n as f64;
            assert!(
                frac_ctrl >= 0.5 * b.explore_floor(),
                "{strategy:?}: control starved at {frac_ctrl}"
            );
            assert!(stats[1].is_control && !stats[0].is_control);
            assert!(stats[0].mean_reward > stats[1].mean_reward);
        }
    }

    #[test]
    fn bandit_prefers_faster_arm_at_equal_quality() {
        let mut cfg = two_arm_config(BanditStrategy::Thompson);
        cfg.arms[0].1 = 0.8;
        cfg.arms[1].1 = 0.8;
        let mut b = BanditRouter::new(cfg).unwrap();
        for _ in 0..1000 {
            let spec = b.pick();
            // the control arm is 10x slower
            let e2e = if spec.key() == "plan:ctrl" { 9000.0 } else { 900.0 };
            b.observe(&spec.key(), e2e);
        }
        let stats = b.arm_stats();
        assert!(
            stats[0].pulls as f64 / 1000.0 >= 0.7,
            "fast arm got {}",
            stats[0].pulls
        );
    }

    #[test]
    fn bandit_is_deterministic_in_seed() {
        let run = || {
            let mut b = BanditRouter::new(two_arm_config(BanditStrategy::Thompson)).unwrap();
            let mut picks = Vec::new();
            for i in 0..200 {
                let spec = b.pick();
                picks.push(spec.key());
                // deterministic synthetic latency stream
                b.observe(&spec.key(), 500.0 + (i % 7) as f64 * 100.0);
            }
            picks
        };
        assert_eq!(run(), run(), "seeded bandit is not reproducible");
    }

    #[test]
    fn bandit_observe_ignores_foreign_keys() {
        let mut b = BanditRouter::new(two_arm_config(BanditStrategy::Thompson)).unwrap();
        assert_eq!(b.observe("plan:other", 100.0), None);
        let r = b.observe("plan:good", 0.0).unwrap();
        assert!((r - 0.9).abs() < 1e-12, "zero-latency reward is the quality prior");
        assert_eq!(b.arm_stats()[0].pulls, 1);
    }

    #[test]
    fn bandit_rejects_bad_configs() {
        let arms = || {
            vec![
                (VariantSpec::parse("plan:a").unwrap(), 0.9),
                (VariantSpec::parse("plan:b").unwrap(), 0.3),
            ]
        };
        // too few arms
        let mut c = BanditConfig::new(arms(), 0);
        c.arms.truncate(1);
        assert!(BanditRouter::new(c).is_err());
        // control out of range
        assert!(BanditRouter::new(BanditConfig::new(arms(), 2)).is_err());
        // zero / oversized floor
        let mut c = BanditConfig::new(arms(), 0);
        c.explore_floor = 0.0;
        assert!(BanditRouter::new(c).is_err());
        let mut c = BanditConfig::new(arms(), 0);
        c.explore_floor = 0.6;
        assert!(BanditRouter::new(c).is_err());
        // quality outside [0, 1]
        let mut c = BanditConfig::new(arms(), 0);
        c.arms[0].1 = 1.5;
        assert!(BanditRouter::new(c).is_err());
        // split arm
        let mut c = BanditConfig::new(arms(), 0);
        c.arms[0].0 = VariantSpec::parse("split:plan:a@1,plan:b@1").unwrap();
        assert!(BanditRouter::new(c).is_err());
        // duplicate arms
        let mut c = BanditConfig::new(arms(), 0);
        c.arms[1].0 = VariantSpec::parse("plan:a").unwrap();
        assert!(BanditRouter::new(c).is_err());
        // bad tau
        let mut c = BanditConfig::new(arms(), 0);
        c.tau_us = 0.0;
        assert!(BanditRouter::new(c).is_err());
        // strategy strings
        assert_eq!("thompson".parse::<BanditStrategy>().unwrap(), BanditStrategy::Thompson);
        assert_eq!("ucb".parse::<BanditStrategy>().unwrap(), BanditStrategy::Ucb);
        assert!("greedy".parse::<BanditStrategy>().is_err());
    }

    #[test]
    fn prop_chunks_sum() {
        check("chunks sum to pending", 100, |rng| {
            let pending = 1 + rng.index(100);
            let exe = 1 + rng.index(16);
            let cs = chunks(pending, exe);
            assert_eq!(cs.iter().sum::<usize>(), pending);
            assert!(cs.iter().all(|&c| c > 0 && c <= exe));
        });
    }
}
