//! Routing: executable batch-size selection, group chunking, and the
//! deterministic weighted router behind A/B traffic splits.

use crate::util::rng::Rng;

/// Choose the compiled batch size for `pending` requests from the
/// `available` (ascending) sizes: the smallest size that fits them all,
/// else the largest available (the group is split across launches).
pub fn pick_batch(pending: usize, available: &[usize]) -> Option<usize> {
    if available.is_empty() || pending == 0 {
        return None;
    }
    for &b in available {
        if b >= pending {
            return Some(b);
        }
    }
    available.last().copied()
}

/// Split a group into execution chunks of at most `exe_batch`.
pub fn chunks(pending: usize, exe_batch: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = pending;
    while left > 0 {
        let take = left.min(exe_batch);
        out.push(take);
        left -= take;
    }
    out
}

/// Pick an arm index proportionally to `weights` with one uniform draw
/// from `rng`. Weights must be positive; the caller validates. Because
/// the RNG is owned by the shard and seeded at build time, the arm
/// sequence for a given request order is reproducible — A/B experiments
/// can be replayed exactly.
pub fn pick_weighted(rng: &mut Rng, weights: &[f64]) -> usize {
    assert!(!weights.is_empty(), "pick_weighted needs at least one arm");
    let total: f64 = weights.iter().sum();
    let mut x = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        x -= w;
        if x < 0.0 {
            return i;
        }
    }
    weights.len() - 1 // fp rounding landed exactly on `total`
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn picks_tight_fit() {
        assert_eq!(pick_batch(1, &[1, 8]), Some(1));
        assert_eq!(pick_batch(2, &[1, 8]), Some(8));
        assert_eq!(pick_batch(8, &[1, 8]), Some(8));
        assert_eq!(pick_batch(12, &[1, 8]), Some(8));
        assert_eq!(pick_batch(3, &[8]), Some(8));
        assert_eq!(pick_batch(0, &[8]), None);
        assert_eq!(pick_batch(3, &[]), None);
    }

    #[test]
    fn chunking_covers_everything() {
        assert_eq!(chunks(12, 8), vec![8, 4]);
        assert_eq!(chunks(8, 8), vec![8]);
        assert_eq!(chunks(3, 8), vec![3]);
    }

    #[test]
    fn weighted_pick_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let w = [0.9, 0.1];
        for _ in 0..100 {
            assert_eq!(pick_weighted(&mut a, &w), pick_weighted(&mut b, &w));
        }
    }

    #[test]
    fn weighted_pick_respects_proportions() {
        let mut rng = Rng::new(4242);
        let w = [0.9, 0.1];
        let n = 10_000;
        let mut counts = [0usize; 2];
        for _ in 0..n {
            counts[pick_weighted(&mut rng, &w)] += 1;
        }
        let frac0 = counts[0] as f64 / n as f64;
        assert!((frac0 - 0.9).abs() < 0.02, "arm 0 got {frac0}");
        // single arm always wins
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(pick_weighted(&mut rng, &[5.0]), 0);
        }
    }

    #[test]
    fn prop_chunks_sum() {
        check("chunks sum to pending", 100, |rng| {
            let pending = 1 + rng.index(100);
            let exe = 1 + rng.index(16);
            let cs = chunks(pending, exe);
            assert_eq!(cs.iter().sum::<usize>(), pending);
            assert!(cs.iter().all(|&c| c > 0 && c <= exe));
        });
    }
}
