//! Serving metrics: counters + latency summaries, shared via a mutex.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::stats::Summary;

/// Live metrics (behind [`SharedMetrics`]).
#[derive(Default)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub queue_us: Summary,
    pub e2e_us: Summary,
    pub exec_us: Summary,
    pub batch_size: Summary,
}

pub type SharedMetrics = Arc<Mutex<Metrics>>;

pub fn shared() -> SharedMetrics {
    Arc::new(Mutex::new(Metrics::default()))
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p_max_e2e_us: f64,
    pub mean_exec_us: f64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn record_batch(&mut self, batch: usize, padded: usize, exec: Duration) {
        self.batches += 1;
        self.requests += batch as u64;
        self.padded_slots += padded as u64;
        self.exec_us.add(exec.as_micros() as f64);
        self.batch_size.add(batch as f64);
    }

    pub fn record_request(&mut self, queue: Duration, e2e: Duration) {
        self.queue_us.add(queue.as_micros() as f64);
        self.e2e_us.add(e2e.as_micros() as f64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_slots: self.padded_slots,
            mean_queue_us: self.queue_us.mean(),
            mean_e2e_us: self.e2e_us.mean(),
            p_max_e2e_us: self.e2e_us.max,
            mean_exec_us: self.exec_us.mean(),
            mean_batch: self.batch_size.mean(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.record_batch(4, 4, Duration::from_micros(100));
            g.record_batch(8, 0, Duration::from_micros(300));
            g.record_request(Duration::from_micros(10), Duration::from_micros(500));
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 4);
        assert!((s.mean_exec_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.mean_e2e_us, 500.0);
    }
}
