//! Serving metrics: counters + latency summaries, shared via a mutex.
//!
//! Latencies are tracked globally and per resolved variant (the
//! [`super::variant::VariantSpec`] key), so an A/B traffic split can be
//! read back as per-arm request counts and latency percentiles.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::stats::Summary;

/// Per-variant latency accounting.
#[derive(Default)]
pub struct VariantMetrics {
    /// Requests served through this variant.
    pub requests: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
}

/// Live metrics (behind [`SharedMetrics`]).
#[derive(Default)]
pub struct Metrics {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
    /// Backend execution-time summary (µs, per batch).
    pub exec_us: Summary,
    /// Executed batch-size summary.
    pub batch_size: Summary,
    /// Per-variant accounting, keyed by the resolved variant string.
    pub per_variant: BTreeMap<String, VariantMetrics>,
}

/// The handle both the worker (writes) and client handles (snapshots)
/// hold: metrics behind a mutex, shared across clones.
pub type SharedMetrics = Arc<Mutex<Metrics>>;

/// Fresh, zeroed [`SharedMetrics`].
pub fn shared() -> SharedMetrics {
    Arc::new(Mutex::new(Metrics::default()))
}

/// Point-in-time per-variant copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct VariantSnapshot {
    /// Requests served through this variant.
    pub requests: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    pub p_max_e2e_us: f64,
    pub mean_exec_us: f64,
    pub mean_batch: f64,
    /// Keyed by the resolved variant string (e.g. `plan:a`, `fp32`).
    pub per_variant: BTreeMap<String, VariantSnapshot>,
}

impl Metrics {
    /// Account one executed batch (`padded` = wasted executable slots).
    pub fn record_batch(&mut self, batch: usize, padded: usize, exec: Duration) {
        self.batches += 1;
        self.requests += batch as u64;
        self.padded_slots += padded as u64;
        self.exec_us.add(exec.as_micros() as f64);
        self.batch_size.add(batch as f64);
    }

    /// Account one served request under its resolved variant key.
    pub fn record_request(&mut self, variant: &str, queue: Duration, e2e: Duration) {
        let (q_us, e_us) = (queue.as_micros() as f64, e2e.as_micros() as f64);
        self.queue_us.add(q_us);
        self.e2e_us.add(e_us);
        // avoid a per-request String allocation once the key exists
        if !self.per_variant.contains_key(variant) {
            self.per_variant
                .insert(variant.to_string(), VariantMetrics::default());
        }
        let v = self.per_variant.get_mut(variant).unwrap();
        v.requests += 1;
        v.queue_us.add(q_us);
        v.e2e_us.add(e_us);
    }

    /// Zero all counters and summaries — e.g. to drop warmup traffic
    /// before a measurement window, or between A/B experiment epochs.
    pub fn reset(&mut self) {
        *self = Metrics::default();
    }

    /// Point-in-time copy with derived means/percentiles.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_slots: self.padded_slots,
            mean_queue_us: self.queue_us.mean(),
            mean_e2e_us: self.e2e_us.mean(),
            p50_e2e_us: self.e2e_us.percentile(50.0),
            p95_e2e_us: self.e2e_us.percentile(95.0),
            p_max_e2e_us: self.e2e_us.max,
            mean_exec_us: self.exec_us.mean(),
            mean_batch: self.batch_size.mean(),
            per_variant: self
                .per_variant
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        VariantSnapshot {
                            requests: v.requests,
                            mean_queue_us: v.queue_us.mean(),
                            mean_e2e_us: v.e2e_us.mean(),
                            p50_e2e_us: v.e2e_us.percentile(50.0),
                            p95_e2e_us: v.e2e_us.percentile(95.0),
                        },
                    )
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.record_batch(4, 4, Duration::from_micros(100));
            g.record_batch(8, 0, Duration::from_micros(300));
            g.record_request(
                "plan:a",
                Duration::from_micros(10),
                Duration::from_micros(500),
            );
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 4);
        assert!((s.mean_exec_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.mean_e2e_us, 500.0);
    }

    #[test]
    fn per_variant_counts_and_percentiles() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            for i in 1..=100u64 {
                let variant = if i % 10 == 0 { "plan:b" } else { "plan:a" };
                g.record_request(
                    variant,
                    Duration::from_micros(1),
                    Duration::from_micros(i),
                );
            }
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.per_variant.len(), 2);
        assert_eq!(s.per_variant["plan:a"].requests, 90);
        assert_eq!(s.per_variant["plan:b"].requests, 10);
        // overall e2e stream is 1..=100 µs (nearest-rank percentiles)
        assert!((49.0..=52.0).contains(&s.p50_e2e_us), "{}", s.p50_e2e_us);
        assert!((94.0..=96.0).contains(&s.p95_e2e_us), "{}", s.p95_e2e_us);
        assert_eq!(s.p_max_e2e_us, 100.0);
        // plan:b saw 10, 20, ..., 100
        let b = &s.per_variant["plan:b"];
        assert!(b.p50_e2e_us >= 40.0 && b.p50_e2e_us <= 60.0, "{}", b.p50_e2e_us);
        assert_eq!(b.p95_e2e_us, 100.0);
    }
}
