//! Serving metrics: counters + latency summaries, shared via a mutex.
//!
//! Latencies are tracked globally and per resolved variant (the
//! [`super::variant::VariantSpec`] key), so an A/B traffic split can be
//! read back as per-arm request counts and latency percentiles. When
//! outcome-aware routing is on ([`super::router::BanditRouter`]), each
//! variant additionally accumulates bandit pulls and rewards, and the
//! snapshot derives cumulative regret against the pinned control arm;
//! the plan watcher ([`super::watch`]) surfaces its swap/rejection
//! counters here too, so one [`MetricsSnapshot`] answers "what is the
//! router doing and is hot-reload healthy" (docs/operations.md).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::sync::{Arc, Mutex};

use crate::util::stats::Summary;

/// Per-variant latency accounting.
#[derive(Default)]
pub struct VariantMetrics {
    /// Requests served through this variant.
    pub requests: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
    /// Bandit pulls observed on this variant (0 under fixed routing).
    pub pulls: u64,
    /// Sum of bandit rewards observed on this variant.
    pub reward_sum: f64,
}

/// Live metrics (behind [`SharedMetrics`]).
#[derive(Default)]
pub struct Metrics {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
    /// Backend execution-time summary (µs, per batch).
    pub exec_us: Summary,
    /// Executed batch-size summary.
    pub batch_size: Summary,
    /// Per-variant accounting, keyed by the resolved variant string.
    pub per_variant: BTreeMap<String, VariantMetrics>,
    /// Variant key of the bandit's pinned control arm, when outcome-
    /// aware routing is installed. Configuration, not measurement: it
    /// survives [`Metrics::reset`].
    pub control_arm: Option<String>,
    /// Plans swapped in by the plan watcher ([`super::watch`]).
    pub plan_swaps: u64,
    /// Plan files the watcher rejected (old plan left serving).
    pub watch_errors: u64,
    /// Most recent watcher rejection, for operator diagnosis.
    pub last_watch_error: Option<String>,
}

/// The handle both the worker (writes) and client handles (snapshots)
/// hold: metrics behind a mutex, shared across clones.
pub type SharedMetrics = Arc<Mutex<Metrics>>;

/// Fresh, zeroed [`SharedMetrics`].
pub fn shared() -> SharedMetrics {
    Arc::new(Mutex::new(Metrics::default()))
}

/// Point-in-time per-variant copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct VariantSnapshot {
    /// Requests served through this variant.
    pub requests: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    /// Bandit pulls observed on this variant (0 under fixed routing).
    pub pulls: u64,
    /// Mean bandit reward (0.0 before the first pull).
    pub mean_reward: f64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    pub p_max_e2e_us: f64,
    pub mean_exec_us: f64,
    pub mean_batch: f64,
    /// Keyed by the resolved variant string (e.g. `plan:a`, `fp32`).
    pub per_variant: BTreeMap<String, VariantSnapshot>,
    /// The bandit's pinned control arm, when outcome-aware routing is
    /// installed.
    pub control_arm: Option<String>,
    /// Cumulative regret relative to always playing the control arm:
    /// `Σ_arm pulls(arm) · (mean_reward(control) − mean_reward(arm))`.
    /// *Negative* regret means the bandit beat the control — the healthy
    /// steady state when a tuned plan outperforms the baseline.
    pub regret_vs_control: f64,
    /// Plans swapped in by the plan watcher.
    pub plan_swaps: u64,
    /// Plan files the watcher rejected (old plan left serving).
    pub watch_errors: u64,
    /// Most recent watcher rejection, for operator diagnosis.
    pub last_watch_error: Option<String>,
}

impl Metrics {
    /// Account one executed batch (`padded` = wasted executable slots).
    pub fn record_batch(&mut self, batch: usize, padded: usize, exec: Duration) {
        self.batches += 1;
        self.requests += batch as u64;
        self.padded_slots += padded as u64;
        self.exec_us.add(exec.as_micros() as f64);
        self.batch_size.add(batch as f64);
    }

    /// Account one served request under its resolved variant key.
    pub fn record_request(&mut self, variant: &str, queue: Duration, e2e: Duration) {
        let (q_us, e_us) = (queue.as_micros() as f64, e2e.as_micros() as f64);
        self.queue_us.add(q_us);
        self.e2e_us.add(e_us);
        // avoid a per-request String allocation once the key exists
        if !self.per_variant.contains_key(variant) {
            self.per_variant
                .insert(variant.to_string(), VariantMetrics::default());
        }
        let v = self.per_variant.get_mut(variant).unwrap();
        v.requests += 1;
        v.queue_us.add(q_us);
        v.e2e_us.add(e_us);
    }

    /// Account one bandit reward observation under its arm's key.
    pub fn record_reward(&mut self, variant: &str, reward: f64) {
        if !self.per_variant.contains_key(variant) {
            self.per_variant
                .insert(variant.to_string(), VariantMetrics::default());
        }
        let v = self.per_variant.get_mut(variant).unwrap();
        v.pulls += 1;
        v.reward_sum += reward;
    }

    /// Account one plan swap applied by the plan watcher.
    pub fn record_plan_swap(&mut self) {
        self.plan_swaps += 1;
    }

    /// Account one plan file the watcher rejected.
    pub fn record_watch_error(&mut self, msg: &str) {
        self.watch_errors += 1;
        self.last_watch_error = Some(msg.to_string());
    }

    /// Zero all counters and summaries — e.g. to drop warmup traffic
    /// before a measurement window, or between A/B experiment epochs.
    /// The control-arm pin survives: it is routing configuration, and a
    /// fresh measurement window still needs to know which arm regret is
    /// computed against.
    pub fn reset(&mut self) {
        let control = self.control_arm.take();
        *self = Metrics::default();
        self.control_arm = control;
    }

    /// Point-in-time copy with derived means/percentiles.
    pub fn snapshot(&self) -> MetricsSnapshot {
        // regret vs control: defined once the control arm has been
        // observed at least once; 0.0 (not NaN) before that
        let regret = match self
            .control_arm
            .as_ref()
            .and_then(|c| self.per_variant.get(c))
            .filter(|c| c.pulls > 0)
        {
            Some(c) => {
                let mu_c = c.reward_sum / c.pulls as f64;
                self.per_variant
                    .values()
                    .filter(|v| v.pulls > 0)
                    .map(|v| v.pulls as f64 * (mu_c - v.reward_sum / v.pulls as f64))
                    .sum()
            }
            None => 0.0,
        };
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_slots: self.padded_slots,
            mean_queue_us: self.queue_us.mean(),
            mean_e2e_us: self.e2e_us.mean(),
            p50_e2e_us: self.e2e_us.percentile(50.0),
            p95_e2e_us: self.e2e_us.percentile(95.0),
            p_max_e2e_us: self.e2e_us.max,
            mean_exec_us: self.exec_us.mean(),
            mean_batch: self.batch_size.mean(),
            per_variant: self
                .per_variant
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        VariantSnapshot {
                            requests: v.requests,
                            mean_queue_us: v.queue_us.mean(),
                            mean_e2e_us: v.e2e_us.mean(),
                            p50_e2e_us: v.e2e_us.percentile(50.0),
                            p95_e2e_us: v.e2e_us.percentile(95.0),
                            pulls: v.pulls,
                            mean_reward: if v.pulls > 0 {
                                v.reward_sum / v.pulls as f64
                            } else {
                                0.0
                            },
                        },
                    )
                })
                .collect(),
            control_arm: self.control_arm.clone(),
            regret_vs_control: regret,
            plan_swaps: self.plan_swaps,
            watch_errors: self.watch_errors,
            last_watch_error: self.last_watch_error.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.record_batch(4, 4, Duration::from_micros(100));
            g.record_batch(8, 0, Duration::from_micros(300));
            g.record_request(
                "plan:a",
                Duration::from_micros(10),
                Duration::from_micros(500),
            );
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 4);
        assert!((s.mean_exec_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.mean_e2e_us, 500.0);
    }

    #[test]
    fn per_variant_counts_and_percentiles() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            for i in 1..=100u64 {
                let variant = if i % 10 == 0 { "plan:b" } else { "plan:a" };
                g.record_request(
                    variant,
                    Duration::from_micros(1),
                    Duration::from_micros(i),
                );
            }
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.per_variant.len(), 2);
        assert_eq!(s.per_variant["plan:a"].requests, 90);
        assert_eq!(s.per_variant["plan:b"].requests, 10);
        // overall e2e stream is 1..=100 µs (nearest-rank percentiles)
        assert!((49.0..=52.0).contains(&s.p50_e2e_us), "{}", s.p50_e2e_us);
        assert!((94.0..=96.0).contains(&s.p95_e2e_us), "{}", s.p95_e2e_us);
        assert_eq!(s.p_max_e2e_us, 100.0);
        // plan:b saw 10, 20, ..., 100
        let b = &s.per_variant["plan:b"];
        assert!(b.p50_e2e_us >= 40.0 && b.p50_e2e_us <= 60.0, "{}", b.p50_e2e_us);
        assert_eq!(b.p95_e2e_us, 100.0);
    }

    #[test]
    fn rewards_and_regret_vs_control() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            // control: 4 pulls at reward 0.25; tuned: 6 pulls at 0.75
            for _ in 0..4 {
                g.record_reward("plan:base", 0.25);
            }
            for _ in 0..6 {
                g.record_reward("plan:tuned", 0.75);
            }
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.control_arm.as_deref(), Some("plan:base"));
        assert_eq!(s.per_variant["plan:base"].pulls, 4);
        assert_eq!(s.per_variant["plan:tuned"].pulls, 6);
        assert!((s.per_variant["plan:tuned"].mean_reward - 0.75).abs() < 1e-12);
        // regret = 4·(0.25−0.25) + 6·(0.25−0.75) = −3.0: beating control
        assert!((s.regret_vs_control - (-3.0)).abs() < 1e-12, "{}", s.regret_vs_control);
    }

    #[test]
    fn regret_is_zero_before_control_observed() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            g.record_reward("plan:tuned", 0.9);
        }
        assert_eq!(m.lock().unwrap().snapshot().regret_vs_control, 0.0);
    }

    #[test]
    fn reset_keeps_control_arm_and_zeros_watch_counters() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            g.record_reward("plan:base", 0.5);
            g.record_plan_swap();
            g.record_watch_error("plans/bad.plan.json: parse error");
            assert_eq!(g.plan_swaps, 1);
            assert_eq!(g.watch_errors, 1);
            g.reset();
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.control_arm.as_deref(), Some("plan:base"));
        assert_eq!(s.plan_swaps, 0);
        assert_eq!(s.watch_errors, 0);
        assert_eq!(s.last_watch_error, None);
        assert!(s.per_variant.is_empty());
    }
}
