//! Serving metrics: counters + latency summaries, shared via a mutex.
//!
//! Latencies are tracked globally and per resolved variant (the
//! [`super::variant::VariantSpec`] key), so an A/B traffic split can be
//! read back as per-arm request counts and latency percentiles. When
//! outcome-aware routing is on ([`super::router::BanditRouter`]), each
//! variant additionally accumulates bandit pulls and rewards, and the
//! snapshot derives cumulative regret against the pinned control arm;
//! the plan watcher ([`super::watch`]) surfaces its swap/rejection
//! counters here too, so one [`MetricsSnapshot`] answers "what is the
//! router doing and is hot-reload healthy" (docs/operations.md).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::sync::{Arc, Mutex};

use super::batcher::ShedReason;
use crate::obs::counters::{EncSnapshot, VariantObsSnapshot};
use crate::util::json::Value;
use crate::util::stats::Summary;

/// Per-tenant admission accounting.
#[derive(Default, Clone, Debug)]
pub struct TenantMetrics {
    /// Requests this tenant got past admission control.
    pub admitted: u64,
    /// Requests shed at admission (queue full or over quota).
    pub shed: u64,
}

/// Per-variant latency accounting.
#[derive(Default)]
pub struct VariantMetrics {
    /// Requests served through this variant.
    pub requests: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
    /// Bandit pulls observed on this variant (0 under fixed routing).
    pub pulls: u64,
    /// Sum of bandit rewards observed on this variant.
    pub reward_sum: f64,
}

/// Live metrics (behind [`SharedMetrics`]).
#[derive(Default)]
pub struct Metrics {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Queue-wait latency summary (µs).
    pub queue_us: Summary,
    /// End-to-end latency summary (µs).
    pub e2e_us: Summary,
    /// Backend execution-time summary (µs, per batch).
    pub exec_us: Summary,
    /// Executed batch-size summary.
    pub batch_size: Summary,
    /// Per-variant accounting, keyed by the resolved variant string.
    pub per_variant: BTreeMap<String, VariantMetrics>,
    /// Requests admitted past the bounded submission queue.
    pub admitted: u64,
    /// Requests shed because the queue was at `max_depth`.
    pub shed_queue_full: u64,
    /// Requests shed because their tenant was over quota.
    pub shed_tenant_quota: u64,
    /// Admitted requests whose deadline expired while queued (they got
    /// an explicit `DeadlineExceeded` reply, never a silent drop).
    pub deadline_exceeded: u64,
    /// Batches executed per replica (index = replica id; grows as
    /// replicas are added). A frozen entry while others grow is the
    /// signature of a dead replica no longer pulling work.
    pub replica_batches: Vec<u64>,
    /// Replica workers that died mid-batch (panic isolation). Lifecycle
    /// health, not traffic: survives [`Metrics::reset`].
    pub replica_failures: u64,
    /// Per-tenant admission accounting.
    pub per_tenant: BTreeMap<String, TenantMetrics>,
    /// Variant key of the bandit's pinned control arm, when outcome-
    /// aware routing is installed. Configuration, not measurement: it
    /// survives [`Metrics::reset`].
    pub control_arm: Option<String>,
    /// Plans swapped in by the plan watcher ([`super::watch`]).
    pub plan_swaps: u64,
    /// Plan files the watcher rejected (old plan left serving).
    pub watch_errors: u64,
    /// Most recent watcher rejection, for operator diagnosis.
    pub last_watch_error: Option<String>,
}

/// The handle both the worker (writes) and client handles (snapshots)
/// hold: metrics behind a mutex, shared across clones.
pub type SharedMetrics = Arc<Mutex<Metrics>>;

/// Fresh, zeroed [`SharedMetrics`].
pub fn shared() -> SharedMetrics {
    Arc::new(Mutex::new(Metrics::default()))
}

/// Point-in-time per-variant copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct VariantSnapshot {
    /// Requests served through this variant.
    pub requests: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    pub p99_e2e_us: f64,
    /// Bandit pulls observed on this variant (0 under fixed routing).
    pub pulls: u64,
    /// Mean bandit reward (0.0 before the first pull).
    pub mean_reward: f64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests executed (all variants).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Padding slots wasted by fixed-batch executables.
    pub padded_slots: u64,
    /// Mean queue wait (µs).
    pub mean_queue_us: f64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    pub p99_e2e_us: f64,
    pub p_max_e2e_us: f64,
    pub mean_exec_us: f64,
    pub mean_batch: f64,
    /// Occupied end-to-end latency buckets as `(upper_us, count)`,
    /// non-cumulative (see [`crate::obs::hist::Hist::buckets`]); the
    /// Prometheus renderer accumulates them into the `le` convention.
    pub e2e_buckets: Vec<(f64, u64)>,
    /// Sum of all end-to-end latencies (µs), for the histogram `_sum`.
    pub e2e_sum_us: f64,
    /// Keyed by the resolved variant string (e.g. `plan:a`, `fp32`).
    pub per_variant: BTreeMap<String, VariantSnapshot>,
    /// Requests admitted past the bounded submission queue.
    pub admitted: u64,
    /// Requests shed at admission: queue at `max_depth`.
    pub shed_queue_full: u64,
    /// Requests shed at admission: tenant over quota.
    pub shed_tenant_quota: u64,
    /// Shed fraction of all admission decisions:
    /// `shed / (shed + admitted)`, 0.0 before any traffic.
    pub shed_rate: f64,
    /// Admitted requests expired in the queue (explicit error reply).
    pub deadline_exceeded: u64,
    /// Live waiting-request count (filled by `ModelHandle::metrics`).
    pub queue_depth: usize,
    /// High-water mark of the waiting-request count.
    pub queue_peak_depth: usize,
    /// Configured replica count (filled by `ModelHandle::metrics`).
    pub replicas_target: usize,
    /// Replicas currently alive (target minus dead/retired).
    pub replicas_alive: usize,
    /// Replica workers that died mid-batch so far.
    pub replica_failures: u64,
    /// Batches executed per replica (index = replica id).
    pub replica_batches: Vec<u64>,
    /// Per-tenant admitted/shed counts.
    pub per_tenant: BTreeMap<String, TenantMetrics>,
    /// The bandit's pinned control arm, when outcome-aware routing is
    /// installed.
    pub control_arm: Option<String>,
    /// Cumulative regret relative to always playing the control arm:
    /// `Σ_arm pulls(arm) · (mean_reward(control) − mean_reward(arm))`.
    /// *Negative* regret means the bandit beat the control — the healthy
    /// steady state when a tuned plan outperforms the baseline.
    pub regret_vs_control: f64,
    /// Plans swapped in by the plan watcher.
    pub plan_swaps: u64,
    /// Plan files the watcher rejected (old plan left serving).
    pub watch_errors: u64,
    /// Most recent watcher rejection, for operator diagnosis.
    pub last_watch_error: Option<String>,
}

impl Metrics {
    /// Account one executed batch (`padded` = wasted executable slots).
    pub fn record_batch(&mut self, batch: usize, padded: usize, exec: Duration) {
        self.batches += 1;
        self.requests += batch as u64;
        self.padded_slots += padded as u64;
        self.exec_us.add(exec.as_micros() as f64);
        self.batch_size.add(batch as f64);
    }

    /// Account one served request under its resolved variant key.
    pub fn record_request(&mut self, variant: &str, queue: Duration, e2e: Duration) {
        let (q_us, e_us) = (queue.as_micros() as f64, e2e.as_micros() as f64);
        self.queue_us.add(q_us);
        self.e2e_us.add(e_us);
        // avoid a per-request String allocation once the key exists
        if !self.per_variant.contains_key(variant) {
            self.per_variant
                .insert(variant.to_string(), VariantMetrics::default());
        }
        let v = self.per_variant.get_mut(variant).unwrap();
        v.requests += 1;
        v.queue_us.add(q_us);
        v.e2e_us.add(e_us);
    }

    /// Account one bandit reward observation under its arm's key.
    pub fn record_reward(&mut self, variant: &str, reward: f64) {
        if !self.per_variant.contains_key(variant) {
            self.per_variant
                .insert(variant.to_string(), VariantMetrics::default());
        }
        let v = self.per_variant.get_mut(variant).unwrap();
        v.pulls += 1;
        v.reward_sum += reward;
    }

    /// Account one request admitted past the submission queue.
    pub fn record_admitted(&mut self, tenant: &str) {
        self.admitted += 1;
        self.per_tenant.entry(tenant.to_string()).or_default().admitted += 1;
    }

    /// Account one request shed at admission.
    pub fn record_shed(&mut self, tenant: &str, reason: &ShedReason) {
        match reason {
            ShedReason::QueueFull { .. } => self.shed_queue_full += 1,
            ShedReason::TenantQuota { .. } => self.shed_tenant_quota += 1,
        }
        self.per_tenant.entry(tenant.to_string()).or_default().shed += 1;
    }

    /// Account admitted requests that expired while queued.
    pub fn record_deadline_exceeded(&mut self, n: usize) {
        self.deadline_exceeded += n as u64;
    }

    /// Account one batch executed by replica `id`.
    pub fn record_replica_batch(&mut self, id: usize) {
        if self.replica_batches.len() <= id {
            self.replica_batches.resize(id + 1, 0);
        }
        self.replica_batches[id] += 1;
    }

    /// Account one replica worker dying mid-batch.
    pub fn record_replica_failure(&mut self) {
        self.replica_failures += 1;
    }

    /// Account one plan swap applied by the plan watcher.
    pub fn record_plan_swap(&mut self) {
        self.plan_swaps += 1;
    }

    /// Account one plan file the watcher rejected.
    pub fn record_watch_error(&mut self, msg: &str) {
        self.watch_errors += 1;
        self.last_watch_error = Some(msg.to_string());
    }

    /// Zero all traffic counters and summaries — e.g. to drop warmup
    /// traffic before a measurement window, or between A/B experiment
    /// epochs. Configuration and lifecycle state survive: the
    /// control-arm pin (a fresh window still needs to know which arm
    /// regret is computed against) and the plan-watcher counters
    /// (`plan_swaps` / `watch_errors` / `last_watch_error` describe
    /// hot-reload health over the process lifetime, not traffic —
    /// zeroing them each window would hide a flapping watcher).
    pub fn reset(&mut self) {
        let control = self.control_arm.take();
        let (swaps, werrs) = (self.plan_swaps, self.watch_errors);
        let last = self.last_watch_error.take();
        let failures = self.replica_failures;
        *self = Metrics::default();
        self.control_arm = control;
        self.plan_swaps = swaps;
        self.watch_errors = werrs;
        self.last_watch_error = last;
        // replica deaths are lifecycle health like the watch counters:
        // a measurement window must not hide an earlier crash
        self.replica_failures = failures;
    }

    /// Point-in-time copy with derived means/percentiles.
    pub fn snapshot(&self) -> MetricsSnapshot {
        // regret vs control: defined once the control arm has been
        // observed at least once; 0.0 (not NaN) before that
        let regret = match self
            .control_arm
            .as_ref()
            .and_then(|c| self.per_variant.get(c))
            .filter(|c| c.pulls > 0)
        {
            Some(c) => {
                let mu_c = c.reward_sum / c.pulls as f64;
                self.per_variant
                    .values()
                    .filter(|v| v.pulls > 0)
                    .map(|v| v.pulls as f64 * (mu_c - v.reward_sum / v.pulls as f64))
                    .sum()
            }
            None => 0.0,
        };
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_slots: self.padded_slots,
            mean_queue_us: self.queue_us.mean(),
            mean_e2e_us: self.e2e_us.mean(),
            p50_e2e_us: self.e2e_us.percentile(50.0),
            p95_e2e_us: self.e2e_us.percentile(95.0),
            p99_e2e_us: self.e2e_us.percentile(99.0),
            p_max_e2e_us: self.e2e_us.max,
            mean_exec_us: self.exec_us.mean(),
            mean_batch: self.batch_size.mean(),
            e2e_buckets: self.e2e_us.hist().buckets(),
            e2e_sum_us: self.e2e_us.sum,
            per_variant: self
                .per_variant
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        VariantSnapshot {
                            requests: v.requests,
                            mean_queue_us: v.queue_us.mean(),
                            mean_e2e_us: v.e2e_us.mean(),
                            p50_e2e_us: v.e2e_us.percentile(50.0),
                            p95_e2e_us: v.e2e_us.percentile(95.0),
                            p99_e2e_us: v.e2e_us.percentile(99.0),
                            pulls: v.pulls,
                            mean_reward: if v.pulls > 0 {
                                v.reward_sum / v.pulls as f64
                            } else {
                                0.0
                            },
                        },
                    )
                })
                .collect(),
            control_arm: self.control_arm.clone(),
            regret_vs_control: regret,
            plan_swaps: self.plan_swaps,
            watch_errors: self.watch_errors,
            last_watch_error: self.last_watch_error.clone(),
            admitted: self.admitted,
            shed_queue_full: self.shed_queue_full,
            shed_tenant_quota: self.shed_tenant_quota,
            shed_rate: {
                let shed = self.shed_queue_full + self.shed_tenant_quota;
                let total = shed + self.admitted;
                if total > 0 {
                    shed as f64 / total as f64
                } else {
                    0.0
                }
            },
            deadline_exceeded: self.deadline_exceeded,
            // live queue/replica gauges are injected by ModelHandle::metrics
            queue_depth: 0,
            queue_peak_depth: 0,
            replicas_target: 0,
            replicas_alive: 0,
            replica_failures: self.replica_failures,
            replica_batches: self.replica_batches.clone(),
            per_tenant: self.per_tenant.clone(),
        }
    }
}

/// `# HELP` / `# TYPE` header for one exposition metric family.
fn head(o: &mut String, name: &str, kind: &str, help: &str) {
    o.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Prometheus sample value: integral floats print without a fraction,
/// non-finite values in the spelling the text format requires.
fn pnum(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// One family with a sample per served variant.
fn obs_family(
    o: &mut String,
    obs: &[VariantObsSnapshot],
    name: &str,
    kind: &str,
    help: &str,
    f: impl Fn(&VariantObsSnapshot) -> f64,
) {
    head(o, name, kind, help);
    for v in obs {
        o.push_str(&format!("{name}{{variant=\"{}\"}} {}\n", v.variant, pnum(f(v))));
    }
}

impl MetricsSnapshot {
    /// Render this snapshot, the per-variant OverQ counter snapshot and
    /// the tracing drop count in the Prometheus text exposition format
    /// — what `overq serve --telemetry-addr` serves at `/metrics`. The
    /// full metric catalog lives in docs/observability.md.
    pub fn render_prometheus(&self, obs: &[VariantObsSnapshot], trace_dropped: u64) -> String {
        let mut o = String::new();
        head(
            &mut o,
            "overq_requests_total",
            "counter",
            "Requests executed (all variants)",
        );
        o.push_str(&format!("overq_requests_total {}\n", self.requests));
        head(
            &mut o,
            "overq_batches_total",
            "counter",
            "Batches executed",
        );
        o.push_str(&format!("overq_batches_total {}\n", self.batches));
        head(
            &mut o,
            "overq_padded_slots_total",
            "counter",
            "Padded batch slots wasted",
        );
        o.push_str(&format!("overq_padded_slots_total {}\n", self.padded_slots));
        head(
            &mut o,
            "overq_plan_swaps_total",
            "counter",
            "Plans swapped in by the watcher",
        );
        o.push_str(&format!("overq_plan_swaps_total {}\n", self.plan_swaps));
        head(
            &mut o,
            "overq_watch_errors_total",
            "counter",
            "Plan files the watcher rejected",
        );
        o.push_str(&format!("overq_watch_errors_total {}\n", self.watch_errors));
        head(
            &mut o,
            "overq_trace_dropped_total",
            "counter",
            "Trace events dropped by the ring",
        );
        o.push_str(&format!("overq_trace_dropped_total {trace_dropped}\n"));
        head(
            &mut o,
            "overq_admitted_total",
            "counter",
            "Requests admitted past the bounded submission queue",
        );
        o.push_str(&format!("overq_admitted_total {}\n", self.admitted));
        head(
            &mut o,
            "overq_shed_total",
            "counter",
            "Requests shed at admission, by reason",
        );
        o.push_str(&format!(
            "overq_shed_total{{reason=\"queue_full\"}} {}\n",
            self.shed_queue_full
        ));
        o.push_str(&format!(
            "overq_shed_total{{reason=\"tenant_quota\"}} {}\n",
            self.shed_tenant_quota
        ));
        head(
            &mut o,
            "overq_shed_rate",
            "gauge",
            "Shed fraction of admission decisions",
        );
        o.push_str(&format!("overq_shed_rate {}\n", pnum(self.shed_rate)));
        head(
            &mut o,
            "overq_deadline_exceeded_total",
            "counter",
            "Admitted requests expired in the queue",
        );
        o.push_str(&format!(
            "overq_deadline_exceeded_total {}\n",
            self.deadline_exceeded
        ));
        head(
            &mut o,
            "overq_queue_depth",
            "gauge",
            "Requests waiting in the submission queue",
        );
        o.push_str(&format!("overq_queue_depth {}\n", self.queue_depth));
        head(
            &mut o,
            "overq_queue_peak_depth",
            "gauge",
            "High-water mark of the submission queue",
        );
        o.push_str(&format!("overq_queue_peak_depth {}\n", self.queue_peak_depth));
        head(
            &mut o,
            "overq_replicas",
            "gauge",
            "Replica workers for this model, by state",
        );
        o.push_str(&format!(
            "overq_replicas{{state=\"target\"}} {}\n",
            self.replicas_target
        ));
        o.push_str(&format!(
            "overq_replicas{{state=\"alive\"}} {}\n",
            self.replicas_alive
        ));
        head(
            &mut o,
            "overq_replica_failures_total",
            "counter",
            "Replica workers that died mid-batch",
        );
        o.push_str(&format!(
            "overq_replica_failures_total {}\n",
            self.replica_failures
        ));
        head(
            &mut o,
            "overq_replica_batches_total",
            "counter",
            "Batches executed per replica",
        );
        for (id, n) in self.replica_batches.iter().enumerate() {
            o.push_str(&format!(
                "overq_replica_batches_total{{replica=\"{id}\"}} {n}\n"
            ));
        }
        head(
            &mut o,
            "overq_tenant_admitted_total",
            "counter",
            "Requests admitted per tenant",
        );
        for (t, v) in &self.per_tenant {
            o.push_str(&format!(
                "overq_tenant_admitted_total{{tenant=\"{t}\"}} {}\n",
                v.admitted
            ));
        }
        head(
            &mut o,
            "overq_tenant_shed_total",
            "counter",
            "Requests shed per tenant",
        );
        for (t, v) in &self.per_tenant {
            o.push_str(&format!(
                "overq_tenant_shed_total{{tenant=\"{t}\"}} {}\n",
                v.shed
            ));
        }

        head(
            &mut o,
            "overq_e2e_us",
            "gauge",
            "End-to-end latency quantiles (us)",
        );
        let qs = [
            ("0.5", self.p50_e2e_us),
            ("0.95", self.p95_e2e_us),
            ("0.99", self.p99_e2e_us),
            ("max", self.p_max_e2e_us),
        ];
        for (q, x) in qs {
            o.push_str(&format!("overq_e2e_us{{quantile=\"{q}\"}} {}\n", pnum(x)));
        }

        head(
            &mut o,
            "overq_e2e_latency_us",
            "histogram",
            "End-to-end latency histogram (us)",
        );
        let mut cum = 0u64;
        for &(ub, c) in &self.e2e_buckets {
            cum += c;
            let le = pnum(ub);
            o.push_str(&format!("overq_e2e_latency_us_bucket{{le=\"{le}\"}} {cum}\n"));
        }
        o.push_str(&format!("overq_e2e_latency_us_bucket{{le=\"+Inf\"}} {cum}\n"));
        o.push_str(&format!("overq_e2e_latency_us_sum {}\n", pnum(self.e2e_sum_us)));
        o.push_str(&format!("overq_e2e_latency_us_count {cum}\n"));

        head(
            &mut o,
            "overq_variant_requests_total",
            "counter",
            "Requests served per variant",
        );
        for (k, v) in &self.per_variant {
            let n = v.requests;
            o.push_str(&format!("overq_variant_requests_total{{variant=\"{k}\"}} {n}\n"));
        }
        head(
            &mut o,
            "overq_variant_e2e_us",
            "gauge",
            "Per-variant e2e latency quantiles (us)",
        );
        for (k, v) in &self.per_variant {
            let qs = [
                ("0.5", v.p50_e2e_us),
                ("0.95", v.p95_e2e_us),
                ("0.99", v.p99_e2e_us),
            ];
            for (q, x) in qs {
                o.push_str(&format!(
                    "overq_variant_e2e_us{{variant=\"{k}\",quantile=\"{q}\"}} {}\n",
                    pnum(x)
                ));
            }
        }
        head(
            &mut o,
            "overq_bandit_pulls_total",
            "counter",
            "Bandit pulls observed per arm",
        );
        for (k, v) in &self.per_variant {
            let n = v.pulls;
            o.push_str(&format!("overq_bandit_pulls_total{{variant=\"{k}\"}} {n}\n"));
        }
        head(
            &mut o,
            "overq_bandit_mean_reward",
            "gauge",
            "Mean bandit reward per arm",
        );
        for (k, v) in &self.per_variant {
            o.push_str(&format!(
                "overq_bandit_mean_reward{{variant=\"{k}\"}} {}\n",
                pnum(v.mean_reward)
            ));
        }
        head(
            &mut o,
            "overq_regret_vs_control",
            "gauge",
            "Cumulative regret vs the control arm",
        );
        let regret = pnum(self.regret_vs_control);
        o.push_str(&format!("overq_regret_vs_control {regret}\n"));

        obs_family(
            &mut o,
            obs,
            "overq_coverage",
            "gauge",
            "Live outlier coverage per variant (covered_ro / outliers; 1 when none seen)",
            |v| v.coverage,
        );
        obs_family(
            &mut o,
            obs,
            "overq_outliers_total",
            "counter",
            "Outlier activations seen per variant",
            |v| v.outliers as f64,
        );
        obs_family(
            &mut o,
            obs,
            "overq_covered_ro_total",
            "counter",
            "Outliers handled via range overwrite per variant",
            |v| v.covered_ro as f64,
        );
        obs_family(
            &mut o,
            obs,
            "overq_covered_pr_total",
            "counter",
            "Precision-overwrite LSB parks per variant",
            |v| v.covered_pr as f64,
        );
        obs_family(
            &mut o,
            obs,
            "overq_dropped_outliers_total",
            "counter",
            "Outliers clamped to qmax per variant",
            |v| v.dropped as f64,
        );
        obs_family(
            &mut o,
            obs,
            "overq_zero_availability",
            "gauge",
            "Exact-zero fraction of activation slots per variant",
            |v| v.zero_availability,
        );

        head(
            &mut o,
            "overq_cascade_depth",
            "histogram",
            "Cascade depth of covered outliers",
        );
        for v in obs {
            let key = &v.variant;
            let mut depths: BTreeMap<usize, u64> = BTreeMap::new();
            for e in &v.enc {
                for &(d, c) in &e.cascade {
                    *depths.entry(d).or_insert(0) += c;
                }
            }
            let (mut dcum, mut dsum) = (0u64, 0u64);
            for (d, c) in &depths {
                dcum += c;
                dsum += *d as u64 * c;
                o.push_str(&format!(
                    "overq_cascade_depth_bucket{{variant=\"{key}\",le=\"{d}\"}} {dcum}\n"
                ));
            }
            o.push_str(&format!(
                "overq_cascade_depth_bucket{{variant=\"{key}\",le=\"+Inf\"}} {dcum}\n"
            ));
            o.push_str(&format!("overq_cascade_depth_sum{{variant=\"{key}\"}} {dsum}\n"));
            o.push_str(&format!("overq_cascade_depth_count{{variant=\"{key}\"}} {dcum}\n"));
        }

        enc_family(
            &mut o,
            obs,
            "overq_enc_coverage",
            "Live outlier coverage per enc point",
            |e| Some(e.coverage),
        );
        enc_family(
            &mut o,
            obs,
            "overq_act_mean",
            "Live raw-activation mean per enc point",
            |e| Some(e.act_mean),
        );
        enc_family(
            &mut o,
            obs,
            "overq_act_var",
            "Live raw-activation variance per enc point",
            |e| Some(e.act_var),
        );
        enc_family(
            &mut o,
            obs,
            "overq_clip_rate",
            "Live clip rate (outliers / values) per enc point",
            |e| Some(e.clip_rate),
        );
        enc_family(
            &mut o,
            obs,
            "overq_baseline_act_mean",
            "Profile-time activation mean from the plan drift block",
            |e| e.baseline.map(|b| b.mean),
        );
        enc_family(
            &mut o,
            obs,
            "overq_baseline_act_var",
            "Profile-time activation variance from the plan drift block",
            |e| e.baseline.map(|b| b.var),
        );
        enc_family(
            &mut o,
            obs,
            "overq_baseline_clip_rate",
            "Profile-time clip rate from the plan drift block",
            |e| e.baseline.map(|b| b.clip_rate),
        );
        o
    }

    /// Machine-readable rendering of this snapshot plus the OverQ
    /// counters — what `--telemetry-addr` serves at `/snapshot.json`
    /// and `overq stats` consumes.
    pub fn stats_json(&self, obs: &[VariantObsSnapshot], trace_dropped: u64) -> Value {
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), Value::Num(self.requests as f64));
        m.insert("batches".to_string(), Value::Num(self.batches as f64));
        m.insert(
            "padded_slots".to_string(),
            Value::Num(self.padded_slots as f64),
        );
        m.insert("mean_queue_us".to_string(), Value::Num(self.mean_queue_us));
        m.insert("mean_e2e_us".to_string(), Value::Num(self.mean_e2e_us));
        m.insert("p50_e2e_us".to_string(), Value::Num(self.p50_e2e_us));
        m.insert("p95_e2e_us".to_string(), Value::Num(self.p95_e2e_us));
        m.insert("p99_e2e_us".to_string(), Value::Num(self.p99_e2e_us));
        m.insert("p_max_e2e_us".to_string(), Value::Num(self.p_max_e2e_us));
        m.insert("mean_exec_us".to_string(), Value::Num(self.mean_exec_us));
        m.insert("mean_batch".to_string(), Value::Num(self.mean_batch));
        m.insert(
            "regret_vs_control".to_string(),
            Value::Num(self.regret_vs_control),
        );
        m.insert("plan_swaps".to_string(), Value::Num(self.plan_swaps as f64));
        m.insert(
            "watch_errors".to_string(),
            Value::Num(self.watch_errors as f64),
        );
        m.insert(
            "trace_dropped".to_string(),
            Value::Num(trace_dropped as f64),
        );
        m.insert("admitted".to_string(), Value::Num(self.admitted as f64));
        m.insert(
            "shed_queue_full".to_string(),
            Value::Num(self.shed_queue_full as f64),
        );
        m.insert(
            "shed_tenant_quota".to_string(),
            Value::Num(self.shed_tenant_quota as f64),
        );
        m.insert("shed_rate".to_string(), Value::Num(self.shed_rate));
        m.insert(
            "deadline_exceeded".to_string(),
            Value::Num(self.deadline_exceeded as f64),
        );
        m.insert(
            "queue_depth".to_string(),
            Value::Num(self.queue_depth as f64),
        );
        m.insert(
            "queue_peak_depth".to_string(),
            Value::Num(self.queue_peak_depth as f64),
        );
        m.insert(
            "replicas_target".to_string(),
            Value::Num(self.replicas_target as f64),
        );
        m.insert(
            "replicas_alive".to_string(),
            Value::Num(self.replicas_alive as f64),
        );
        m.insert(
            "replica_failures".to_string(),
            Value::Num(self.replica_failures as f64),
        );
        m.insert(
            "replica_batches".to_string(),
            Value::Arr(
                self.replica_batches
                    .iter()
                    .map(|&n| Value::Num(n as f64))
                    .collect(),
            ),
        );
        let tenants: BTreeMap<String, Value> = self
            .per_tenant
            .iter()
            .map(|(t, v)| {
                let mut tm = BTreeMap::new();
                tm.insert("admitted".to_string(), Value::Num(v.admitted as f64));
                tm.insert("shed".to_string(), Value::Num(v.shed as f64));
                (t.clone(), Value::Obj(tm))
            })
            .collect();
        m.insert("per_tenant".to_string(), Value::Obj(tenants));
        if let Some(c) = &self.control_arm {
            m.insert("control_arm".to_string(), Value::Str(c.clone()));
        }
        if let Some(e) = &self.last_watch_error {
            m.insert("last_watch_error".to_string(), Value::Str(e.clone()));
        }
        let pv: BTreeMap<String, Value> = self
            .per_variant
            .iter()
            .map(|(k, v)| (k.clone(), variant_json(v)))
            .collect();
        m.insert("per_variant".to_string(), Value::Obj(pv));
        let cov: BTreeMap<String, Value> = obs
            .iter()
            .map(|v| (v.variant.clone(), coverage_json(v)))
            .collect();
        m.insert("coverage".to_string(), Value::Obj(cov));
        Value::Obj(m)
    }
}

/// JSON view of one variant's serving metrics (for [`MetricsSnapshot::stats_json`]).
fn variant_json(v: &VariantSnapshot) -> Value {
    let mut vm = BTreeMap::new();
    vm.insert("requests".to_string(), Value::Num(v.requests as f64));
    vm.insert("mean_queue_us".to_string(), Value::Num(v.mean_queue_us));
    vm.insert("mean_e2e_us".to_string(), Value::Num(v.mean_e2e_us));
    vm.insert("p50_e2e_us".to_string(), Value::Num(v.p50_e2e_us));
    vm.insert("p95_e2e_us".to_string(), Value::Num(v.p95_e2e_us));
    vm.insert("p99_e2e_us".to_string(), Value::Num(v.p99_e2e_us));
    vm.insert("pulls".to_string(), Value::Num(v.pulls as f64));
    vm.insert("mean_reward".to_string(), Value::Num(v.mean_reward));
    Value::Obj(vm)
}

/// JSON view of one variant's OverQ counters (for [`MetricsSnapshot::stats_json`]).
fn coverage_json(v: &VariantObsSnapshot) -> Value {
    let mut vm = BTreeMap::new();
    vm.insert("coverage".to_string(), Value::Num(v.coverage));
    vm.insert("outliers".to_string(), Value::Num(v.outliers as f64));
    vm.insert("covered_ro".to_string(), Value::Num(v.covered_ro as f64));
    vm.insert("covered_pr".to_string(), Value::Num(v.covered_pr as f64));
    vm.insert("dropped".to_string(), Value::Num(v.dropped as f64));
    vm.insert(
        "zero_availability".to_string(),
        Value::Num(v.zero_availability),
    );
    let enc: Vec<Value> = v
        .enc
        .iter()
        .map(|e| {
            let mut em = BTreeMap::new();
            em.insert("enc".to_string(), Value::Num(e.enc as f64));
            em.insert("coverage".to_string(), Value::Num(e.coverage));
            em.insert(
                "zero_availability".to_string(),
                Value::Num(e.zero_availability),
            );
            em.insert("act_mean".to_string(), Value::Num(e.act_mean));
            em.insert("act_var".to_string(), Value::Num(e.act_var));
            em.insert("clip_rate".to_string(), Value::Num(e.clip_rate));
            if let Some(b) = e.baseline {
                let mut bm = BTreeMap::new();
                bm.insert("mean".to_string(), Value::Num(b.mean));
                bm.insert("var".to_string(), Value::Num(b.var));
                bm.insert("clip_rate".to_string(), Value::Num(b.clip_rate));
                em.insert("baseline".to_string(), Value::Obj(bm));
            }
            Value::Obj(em)
        })
        .collect();
    vm.insert("enc".to_string(), Value::Arr(enc));
    Value::Obj(vm)
}

/// One gauge family with a sample per (variant, enc point). `f`
/// returning `None` skips the sample (e.g. no stored baseline).
fn enc_family(
    o: &mut String,
    obs: &[VariantObsSnapshot],
    name: &str,
    help: &str,
    f: impl Fn(&EncSnapshot) -> Option<f64>,
) {
    head(o, name, "gauge", help);
    for v in obs {
        for e in &v.enc {
            if let Some(x) = f(e) {
                o.push_str(&format!(
                    "{name}{{variant=\"{}\",enc=\"{}\"}} {}\n",
                    v.variant,
                    e.enc,
                    pnum(x)
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.record_batch(4, 4, Duration::from_micros(100));
            g.record_batch(8, 0, Duration::from_micros(300));
            g.record_request(
                "plan:a",
                Duration::from_micros(10),
                Duration::from_micros(500),
            );
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 4);
        assert!((s.mean_exec_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.mean_e2e_us, 500.0);
    }

    #[test]
    fn per_variant_counts_and_percentiles() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            for i in 1..=100u64 {
                let variant = if i % 10 == 0 { "plan:b" } else { "plan:a" };
                g.record_request(
                    variant,
                    Duration::from_micros(1),
                    Duration::from_micros(i),
                );
            }
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.per_variant.len(), 2);
        assert_eq!(s.per_variant["plan:a"].requests, 90);
        assert_eq!(s.per_variant["plan:b"].requests, 10);
        // overall e2e stream is 1..=100 µs (nearest-rank percentiles)
        assert!((49.0..=52.0).contains(&s.p50_e2e_us), "{}", s.p50_e2e_us);
        assert!((94.0..=96.0).contains(&s.p95_e2e_us), "{}", s.p95_e2e_us);
        assert_eq!(s.p_max_e2e_us, 100.0);
        // plan:b saw 10, 20, ..., 100 — the histogram reports the
        // owning bucket's midpoint, within one 2^(1/8) growth factor
        let b = &s.per_variant["plan:b"];
        assert!(b.p50_e2e_us >= 40.0 && b.p50_e2e_us <= 65.0, "{}", b.p50_e2e_us);
        assert_eq!(b.p95_e2e_us, 100.0);
        assert_eq!(b.p99_e2e_us, 100.0);
    }

    #[test]
    fn rewards_and_regret_vs_control() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            // control: 4 pulls at reward 0.25; tuned: 6 pulls at 0.75
            for _ in 0..4 {
                g.record_reward("plan:base", 0.25);
            }
            for _ in 0..6 {
                g.record_reward("plan:tuned", 0.75);
            }
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.control_arm.as_deref(), Some("plan:base"));
        assert_eq!(s.per_variant["plan:base"].pulls, 4);
        assert_eq!(s.per_variant["plan:tuned"].pulls, 6);
        assert!((s.per_variant["plan:tuned"].mean_reward - 0.75).abs() < 1e-12);
        // regret = 4·(0.25−0.25) + 6·(0.25−0.75) = −3.0: beating control
        assert!((s.regret_vs_control - (-3.0)).abs() < 1e-12, "{}", s.regret_vs_control);
    }

    #[test]
    fn regret_is_zero_before_control_observed() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            g.record_reward("plan:tuned", 0.9);
        }
        assert_eq!(m.lock().unwrap().snapshot().regret_vs_control, 0.0);
    }

    #[test]
    fn reset_keeps_control_arm_and_watch_counters() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:base".into());
            g.record_reward("plan:base", 0.5);
            g.record_request(
                "plan:base",
                Duration::from_micros(5),
                Duration::from_micros(50),
            );
            g.record_plan_swap();
            g.record_watch_error("plans/bad.plan.json: parse error");
            assert_eq!(g.plan_swaps, 1);
            assert_eq!(g.watch_errors, 1);
            g.reset();
        }
        let s = m.lock().unwrap().snapshot();
        // traffic zeroes...
        assert_eq!(s.requests, 0);
        assert!(s.per_variant.is_empty());
        assert!(s.e2e_buckets.is_empty());
        // ...but configuration and lifecycle state survive
        assert_eq!(s.control_arm.as_deref(), Some("plan:base"));
        assert_eq!(s.plan_swaps, 1);
        assert_eq!(s.watch_errors, 1);
        assert_eq!(s.last_watch_error.as_deref(), Some("plans/bad.plan.json: parse error"));
    }

    #[test]
    fn admission_shed_and_replica_accounting() {
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            for _ in 0..6 {
                g.record_admitted("acme");
            }
            g.record_admitted("beta");
            g.record_shed("acme", &ShedReason::QueueFull { depth: 8 });
            g.record_shed(
                "acme",
                &ShedReason::TenantQuota {
                    tenant: "acme".into(),
                    quota: 4,
                },
            );
            g.record_deadline_exceeded(3);
            g.record_replica_batch(0);
            g.record_replica_batch(2); // replica 1 never executed
            g.record_replica_failure();
        }
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.admitted, 7);
        assert_eq!(s.shed_queue_full, 1);
        assert_eq!(s.shed_tenant_quota, 1);
        assert!((s.shed_rate - 2.0 / 9.0).abs() < 1e-12, "{}", s.shed_rate);
        assert_eq!(s.deadline_exceeded, 3);
        assert_eq!(s.replica_batches, vec![1, 0, 1]);
        assert_eq!(s.replica_failures, 1);
        assert_eq!(s.per_tenant["acme"].admitted, 6);
        assert_eq!(s.per_tenant["acme"].shed, 2);
        assert_eq!(s.per_tenant["beta"].shed, 0);

        // shed/admission counters are traffic (reset), replica deaths
        // are lifecycle health (survive)
        m.lock().unwrap().reset();
        let s = m.lock().unwrap().snapshot();
        assert_eq!(s.admitted, 0);
        assert_eq!(s.shed_rate, 0.0);
        assert!(s.per_tenant.is_empty());
        assert_eq!(s.replica_failures, 1);

        // the new families render under the exposition grammar
        let text = m.lock().unwrap().snapshot().render_prometheus(&[], 0);
        assert!(text.contains("overq_shed_total{reason=\"queue_full\"} 0"));
        assert!(text.contains("overq_replica_failures_total 1"));
        assert!(text.contains("overq_queue_depth 0"));
    }

    /// 50 requests on `plan:p` plus one enc point's OverQ counters
    /// (coverage 95/100) — shared by the exporter tests.
    fn telemetry_fixture() -> (MetricsSnapshot, Vec<VariantObsSnapshot>) {
        use crate::obs::counters::{record, set_ctx, EncSample, Registry};
        let m = shared();
        {
            let mut g = m.lock().unwrap();
            g.control_arm = Some("plan:p".into());
            g.record_batch(50, 2, Duration::from_micros(900));
            g.record_reward("plan:p", 0.5);
            for i in 1..=50u64 {
                g.record_request(
                    "plan:p",
                    Duration::from_micros(2),
                    Duration::from_micros(i * 10),
                );
            }
        }
        let reg = Registry::new();
        {
            let _g = set_ctx(reg.variant("plan:p"));
            let mut s = EncSample {
                values: 1000,
                zeros: 400,
                outliers: 100,
                covered_ro: 95,
                covered_pr: 10,
                dropped: 5,
                act_n: 1000,
                act_mean: 0.1,
                act_m2: 10.0,
                ..EncSample::default()
            };
            s.cascade[0] = 80;
            s.cascade[1] = 15;
            record(0, &s);
        }
        let snap = m.lock().unwrap().snapshot();
        (snap, reg.snapshot())
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let (snap, obs) = telemetry_fixture();
        let text = snap.render_prometheus(&obs, 3);

        assert!(text.contains("# TYPE overq_e2e_latency_us histogram"));
        assert!(text.contains("overq_requests_total 50"));
        assert!(text.contains("overq_trace_dropped_total 3"));
        assert!(text.contains("overq_coverage{variant=\"plan:p\"} 0.95"));
        assert!(text.contains("overq_cascade_depth_bucket{variant=\"plan:p\",le=\"+Inf\"} 95"));
        assert!(text.contains("overq_e2e_latency_us_count 50"));
        assert!(text.contains("overq_clip_rate{variant=\"plan:p\",enc=\"0\"} 0.1"));

        // every sample line obeys the text exposition grammar:
        // metric_name[{labels}] value, with a parseable value
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad value in {line}");
            let name = &series[..series.find('{').unwrap_or(series.len())];
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "bad metric name in {line}"
            );
        }

        // histogram bucket counts are cumulative (monotone in le order)
        let cums: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("overq_e2e_latency_us_bucket"))
            .map(|l| l.rsplit_once(' ').unwrap().1.parse().unwrap())
            .collect();
        assert!(!cums.is_empty());
        assert!(cums.windows(2).all(|w| w[0] <= w[1]), "{cums:?}");
        assert_eq!(*cums.last().unwrap(), 50);
    }

    #[test]
    fn stats_json_roundtrips_through_the_parser() {
        let (snap, obs) = telemetry_fixture();
        let text = snap.stats_json(&obs, 7).to_json();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.at(&["trace_dropped"]).as_f64(), Some(7.0));
        assert_eq!(v.at(&["control_arm"]).as_str(), Some("plan:p"));
        assert_eq!(v.at(&["coverage", "plan:p", "coverage"]).as_f64(), Some(0.95));
        assert_eq!(v.at(&["per_variant", "plan:p", "requests"]).as_f64(), Some(50.0));
        let p99 = v.at(&["per_variant", "plan:p", "p99_e2e_us"]).as_f64();
        assert!(p99.unwrap() > 400.0, "{p99:?}");
    }
}
