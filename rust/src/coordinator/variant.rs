//! Typed serving variants — the unit of routing in the coordinator.
//!
//! The serving API used to pass `variant: String` all the way into the
//! worker, where ad-hoc prefix matching decided what to run and typos
//! only surfaced as per-request failures deep in the group loop. A
//! [`VariantSpec`] is parsed once at the edge (`FromStr`) and validated
//! against the target shard at `submit` time, so unknown variants fail
//! fast with a useful error instead of inside the worker.
//!
//! Grammar (round-trips through `Display`):
//!
//! ```text
//! fp32                      fp32 on the best available backend
//! native_fp32               fp32 pinned to the in-process engine
//! pjrt_fp32                 fp32 pinned to the compiled (PJRT) path
//! plan:<name>               registered deployment plan, native engine
//! <name>                    AOT-compiled HLO variant (e.g. full_c4)
//! split:<v>@<w>,<v>@<w>...  weighted traffic split over the above
//! ```

use std::fmt;
use std::str::FromStr;

use anyhow::{Context, Result};

/// Which execution backend an fp32 request is pinned to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Compiled path when an artifact exists (and the `pjrt` feature is
    /// on), the native engine otherwise.
    Auto,
    /// The in-process rust engine.
    Native,
    /// The AOT-compiled PJRT executable; fails if unavailable.
    Pjrt,
}

/// A parsed serving variant.
///
/// The grammar round-trips through `Display`/[`FromStr`]. These are the
/// normative examples from `docs/serving.md`, verified as doc-tests by
/// `cargo test`:
///
/// ```
/// use overq::coordinator::{Backend, VariantSpec};
///
/// // fp32 on the best available backend, or pinned to one
/// assert_eq!(
///     VariantSpec::parse("fp32")?,
///     VariantSpec::Fp32 { backend: Backend::Auto }
/// );
/// assert_eq!(
///     "native_fp32".parse::<VariantSpec>()?,
///     VariantSpec::Fp32 { backend: Backend::Native }
/// );
///
/// // a registered deployment plan, and an AOT-compiled HLO variant
/// assert_eq!(
///     VariantSpec::parse("plan:resnet18m-auto")?,
///     VariantSpec::Plan("resnet18m-auto".into())
/// );
/// assert_eq!(
///     VariantSpec::parse("full_c4")?,
///     VariantSpec::Compiled("full_c4".into())
/// );
///
/// // weighted A/B split; Display reproduces the exact input string
/// let split = VariantSpec::parse("split:plan:a@0.9,plan:b@0.1")?;
/// assert!(split.is_split());
/// assert_eq!(split.to_string(), "split:plan:a@0.9,plan:b@0.1");
///
/// // parsing is strict: empty names, bad weights, nesting all fail
/// assert!(VariantSpec::parse("plan:").is_err());
/// assert!(VariantSpec::parse("split:plan:a").is_err()); // missing @weight
/// assert!(VariantSpec::parse("split:plan:a@0").is_err()); // weight must be > 0
/// assert!(VariantSpec::parse("split:split:plan:a@1@1").is_err()); // nested
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Clone, Debug, PartialEq)]
pub enum VariantSpec {
    /// The fp32 reference path.
    Fp32 { backend: Backend },
    /// An AOT-compiled HLO variant by artifact name (e.g. `full_c4`).
    Compiled(String),
    /// A registered deployment plan, served on the native engine.
    Plan(String),
    /// A weighted split over non-split specs; the router resolves each
    /// request to one arm deterministically at submit time.
    Split(Vec<(VariantSpec, f64)>),
}

impl VariantSpec {
    /// Parse from the string grammar. Prefer this over `FromStr` when
    /// you want `anyhow` context on the failure.
    pub fn parse(s: &str) -> Result<VariantSpec> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty variant");
        if let Some(body) = s.strip_prefix("split:") {
            let mut arms = Vec::new();
            for part in body.split(',') {
                let part = part.trim();
                let (spec, w) = part
                    .rsplit_once('@')
                    .with_context(|| format!("split arm {part:?} needs <variant>@<weight>"))?;
                let weight: f64 = w
                    .trim()
                    .parse()
                    .ok()
                    .with_context(|| format!("bad split weight {w:?} in {part:?}"))?;
                arms.push((VariantSpec::parse(spec)?, weight));
            }
            VariantSpec::validate_split(&arms)?;
            return Ok(VariantSpec::Split(arms));
        }
        if let Some(name) = s.strip_prefix("plan:") {
            anyhow::ensure!(!name.is_empty(), "plan variant needs a name (plan:<name>)");
            // same charset as compiled names — '@' and ',' would break
            // the split grammar's Display ↔ FromStr round-trip
            anyhow::ensure!(
                name.chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'),
                "plan name {name:?} has characters outside [A-Za-z0-9_.-]"
            );
            return Ok(VariantSpec::Plan(name.to_string()));
        }
        match s {
            "fp32" => Ok(VariantSpec::Fp32 {
                backend: Backend::Auto,
            }),
            "native_fp32" => Ok(VariantSpec::Fp32 {
                backend: Backend::Native,
            }),
            "pjrt_fp32" => Ok(VariantSpec::Fp32 {
                backend: Backend::Pjrt,
            }),
            name => {
                anyhow::ensure!(
                    name.chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'),
                    "variant {name:?} has characters outside [A-Za-z0-9_.-]"
                );
                Ok(VariantSpec::Compiled(name.to_string()))
            }
        }
    }

    /// Build a split from `(variant, weight)` string pairs (the
    /// `set_traffic_split` argument shape).
    ///
    /// This is the fixed-weight A/B routing example from
    /// `docs/serving.md` and `docs/operations.md`, runnable:
    ///
    /// ```
    /// use overq::coordinator::VariantSpec;
    ///
    /// // 90% of routed traffic to the tuned plan, 10% to the control
    /// let split = VariantSpec::split(&[("plan:a", 0.9), ("plan:b", 0.1)])?;
    /// assert_eq!(split.to_string(), "split:plan:a@0.9,plan:b@0.1");
    ///
    /// // the same invariants as the parsed grammar apply
    /// assert!(VariantSpec::split(&[]).is_err());
    /// assert!(VariantSpec::split(&[("plan:a", -0.5)]).is_err());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn split(pairs: &[(&str, f64)]) -> Result<VariantSpec> {
        let mut arms = Vec::with_capacity(pairs.len());
        for (v, w) in pairs {
            arms.push((VariantSpec::parse(v)?, *w));
        }
        VariantSpec::validate_split(&arms)?;
        Ok(VariantSpec::Split(arms))
    }

    /// The split-arm invariants every producer must uphold, in one
    /// place: at least one arm, no nesting, positive finite weights.
    pub fn validate_split(arms: &[(VariantSpec, f64)]) -> Result<()> {
        anyhow::ensure!(!arms.is_empty(), "empty traffic split");
        for (arm, w) in arms {
            anyhow::ensure!(
                !matches!(arm, VariantSpec::Split(_)),
                "nested traffic splits are not supported"
            );
            anyhow::ensure!(
                w.is_finite() && *w > 0.0,
                "split weight for {arm} must be positive and finite, got {w}"
            );
        }
        Ok(())
    }

    /// True for `Split` specs.
    pub fn is_split(&self) -> bool {
        matches!(self, VariantSpec::Split(_))
    }

    // (the old worker-side grouping key is gone: batches are grouped
    // by the cached `InferRequest::group` string in the submit queue)

    /// The metrics key for a resolved (non-split) spec — its canonical
    /// string form.
    pub fn key(&self) -> String {
        self.to_string()
    }
}

impl fmt::Display for VariantSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VariantSpec::Fp32 { backend } => match backend {
                Backend::Auto => write!(f, "fp32"),
                Backend::Native => write!(f, "native_fp32"),
                Backend::Pjrt => write!(f, "pjrt_fp32"),
            },
            VariantSpec::Compiled(name) => write!(f, "{name}"),
            VariantSpec::Plan(name) => write!(f, "plan:{name}"),
            VariantSpec::Split(arms) => {
                write!(f, "split:")?;
                for (i, (spec, w)) in arms.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{spec}@{w}")?;
                }
                Ok(())
            }
        }
    }
}

impl FromStr for VariantSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<VariantSpec> {
        VariantSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_legacy_strings() {
        assert_eq!(
            VariantSpec::parse("fp32").unwrap(),
            VariantSpec::Fp32 {
                backend: Backend::Auto
            }
        );
        assert_eq!(
            VariantSpec::parse("native_fp32").unwrap(),
            VariantSpec::Fp32 {
                backend: Backend::Native
            }
        );
        assert_eq!(
            VariantSpec::parse("pjrt_fp32").unwrap(),
            VariantSpec::Fp32 {
                backend: Backend::Pjrt
            }
        );
        assert_eq!(
            VariantSpec::parse("full_c4").unwrap(),
            VariantSpec::Compiled("full_c4".into())
        );
        assert_eq!(
            VariantSpec::parse("plan:resnet18m-auto").unwrap(),
            VariantSpec::Plan("resnet18m-auto".into())
        );
    }

    #[test]
    fn parses_splits() {
        let s = VariantSpec::parse("split:plan:a@0.9,plan:b@0.1").unwrap();
        match &s {
            VariantSpec::Split(arms) => {
                assert_eq!(arms.len(), 2);
                assert_eq!(arms[0].0, VariantSpec::Plan("a".into()));
                assert!((arms[0].1 - 0.9).abs() < 1e-12);
                assert_eq!(arms[1].0, VariantSpec::Plan("b".into()));
                assert!((arms[1].1 - 0.1).abs() < 1e-12);
            }
            other => panic!("expected split, got {other:?}"),
        }
        // mixed arm kinds are fine
        let s = VariantSpec::parse("split:native_fp32@3,full_c4@1").unwrap();
        assert!(s.is_split());
    }

    #[test]
    fn display_fromstr_roundtrip() {
        for text in [
            "fp32",
            "native_fp32",
            "pjrt_fp32",
            "full_c4",
            "plan:resnet18m-auto",
            "split:plan:a@0.9,plan:b@0.1",
            "split:native_fp32@3,full_c4@1",
        ] {
            let spec: VariantSpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text, "display of {spec:?}");
            let back: VariantSpec = spec.to_string().parse().unwrap();
            assert_eq!(back, spec, "round-trip of {text:?}");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(VariantSpec::parse("").is_err());
        assert!(VariantSpec::parse("plan:").is_err());
        assert!(VariantSpec::parse("split:").is_err());
        assert!(VariantSpec::parse("split:plan:a").is_err()); // no weight
        assert!(VariantSpec::parse("split:plan:a@zero").is_err());
        assert!(VariantSpec::parse("split:plan:a@0").is_err()); // weight must be > 0
        assert!(VariantSpec::parse("split:plan:a@-1").is_err());
        assert!(VariantSpec::parse("split:split:plan:a@1@1").is_err()); // nested
        assert!(VariantSpec::parse("bad variant name").is_err());
        assert!(VariantSpec::parse("plan:a,b").is_err()); // ',' breaks splits
        assert!(VariantSpec::parse("plan:a@b").is_err()); // '@' breaks splits
        assert!(VariantSpec::split(&[]).is_err());
        assert!(VariantSpec::split(&[("plan:a", f64::NAN)]).is_err());
    }
}
