//! Dependency-free telemetry endpoint: a one-thread blocking HTTP/1.0
//! listener exporting a [`ModelHandle`]'s telemetry planes —
//! `/metrics` (Prometheus text exposition, version 0.0.4),
//! `/snapshot.json` (the machine-readable stats document) and `/trace`
//! (drains the shard's span ring as JSONL). One thread and one
//! connection at a time is deliberate: a scrape must never compete with
//! the serving workers for anything beyond a snapshot lock, and a
//! half-open client can at worst stall the scraper, never serving.
//!
//! The matching client side ([`http_get`]) backs `overq stats` and
//! `overq trace`, plus the integration tests.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::span::events_jsonl;
use crate::util::sync::Arc;

use super::server::ModelHandle;

/// Accept-loop poll interval while checking the stop flag.
const POLL: Duration = Duration::from_millis(25);

/// A running telemetry listener; dropping it stops the thread.
pub struct TelemetryServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TelemetryServer {
    /// The bound address (resolves a `:0` request to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:9185`, port 0 for ephemeral) and serve
/// the handle's telemetry until the returned server is dropped.
pub fn spawn(handle: ModelHandle, addr: &str) -> Result<TelemetryServer> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding telemetry listener on {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let join = std::thread::Builder::new()
        .name("overq-telemetry".into())
        .spawn(move || accept_loop(listener, handle, flag))?;
    Ok(TelemetryServer {
        addr: local,
        stop,
        join: Some(join),
    })
}

fn accept_loop(listener: TcpListener, handle: ModelHandle, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            // per-connection errors (timeouts, resets) only lose that
            // one scrape; the listener keeps going
            Ok((stream, _)) => {
                let _ = serve_one(stream, &handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(_) => std::thread::sleep(POLL),
        }
    }
}

fn serve_one(mut stream: TcpStream, handle: &ModelHandle) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let path = read_request_path(&mut stream)?;
    let (status, ctype, body) = match path.as_str() {
        "/metrics" => ("200 OK", "text/plain; version=0.0.4", handle.prometheus()),
        "/snapshot.json" => {
            let doc = handle.stats_json();
            ("200 OK", "application/json", doc.to_json())
        }
        "/trace" => {
            let events = handle.drain_events();
            ("200 OK", "application/x-ndjson", events_jsonl(&events))
        }
        _ => (
            "404 Not Found",
            "text/plain",
            "unknown path; try /metrics /snapshot.json /trace\n".to_string(),
        ),
    };
    let head = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn read_request_path(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf);
    let line = text.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let _method = parts.next();
    Ok(parts.next().unwrap_or("/").to_string())
}

/// Minimal HTTP/1.0 GET returning the response body. `addr` is
/// `host:port`, no scheme. The client half of [`spawn`]'s listener.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to telemetry endpoint {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let req = format!("GET {path} HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .with_context(|| format!("malformed HTTP response from {addr}"))?;
    let status = head.lines().next().unwrap_or("");
    anyhow::ensure!(
        status.contains(" 200 "),
        "telemetry endpoint {addr}{path} returned {status:?}"
    );
    Ok(body.to_string())
}
