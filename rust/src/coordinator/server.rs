//! The inference server: request channel → batcher → PJRT executables.
//!
//! One worker thread owns the (non-`Send`) PJRT client and executables —
//! the actor pattern. Clients hold a cheap cloneable [`Server`] handle.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::models::Artifacts;
use crate::runtime::artifacts::ExecutableCache;
use crate::runtime::pjrt::Input;
use crate::tensor::TensorF;

use super::batcher::{collect, BatchPolicy};
use super::metrics::{shared, MetricsSnapshot, SharedMetrics};
use super::router::pick_batch;

/// A single inference request (one image).
pub struct InferRequest {
    /// (H, W, C) normalized image.
    pub image: TensorF,
    /// Which compiled variant to run ("fp32", "base", "full_c4", ...).
    pub variant: String,
    pub submitted: Instant,
    pub resp: SyncSender<InferResponse>,
}

/// Reply for one request.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub logits: Vec<f32>,
    pub batch_size: usize,
    pub queue: Duration,
    pub e2e: Duration,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: String,
    pub policy: BatchPolicy,
    /// Activation scales per enc point, for quantized variants.
    pub act_scales: Vec<f32>,
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<InferRequest>>,
    metrics: SharedMetrics,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the worker; compiles executables lazily on first use.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let arts = Artifacts::locate()?;
        let (tx, rx) = std::sync::mpsc::channel::<InferRequest>();
        let metrics = shared();
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("overq-worker".into())
            .spawn(move || {
                if let Err(e) = worker_loop(arts, cfg, rx, m2) {
                    eprintln!("[server] worker exited with error: {e:#}");
                }
            })
            .context("spawn worker")?;
        Ok(Server {
            tx: Some(tx),
            metrics,
            worker: Some(worker),
        })
    }

    /// Submit one request and block for its response.
    pub fn infer(&self, image: TensorF, variant: &str) -> Result<InferResponse> {
        let rx = self.submit(image, variant)?;
        rx.recv().context("worker dropped the response")
    }

    /// Warm a variant: trigger compilation of every batch size by
    /// pushing enough dummy requests to hit the largest executable.
    /// Returns the wall time spent (the one-time compile cost).
    pub fn warmup(&self, variant: &str, dims: &[usize], max_batch: usize) -> Result<Duration> {
        let t0 = Instant::now();
        // single request exercises the b1 executable (if present)
        let _ = self.infer(TensorF::zeros(dims), variant)?;
        // a burst exercises the batched executable
        let burst: Vec<_> = (0..max_batch)
            .map(|_| self.submit(TensorF::zeros(dims), variant))
            .collect::<Result<_>>()?;
        for rx in burst {
            rx.recv().context("warmup response lost")?;
        }
        Ok(t0.elapsed())
    }

    /// Submit without blocking; returns the response channel.
    pub fn submit(&self, image: TensorF, variant: &str) -> Result<Receiver<InferResponse>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(InferRequest {
                image,
                variant: variant.to_string(),
                submitted: Instant::now(),
                resp: rtx,
            })
            .ok()
            .context("worker gone")?;
        Ok(rrx)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    arts: Artifacts,
    cfg: ServerConfig,
    rx: std::sync::mpsc::Receiver<InferRequest>,
    metrics: SharedMetrics,
) -> Result<()> {
    let mut cache = ExecutableCache::new(&arts)?;
    let scales = TensorF::from_vec(&[cfg.act_scales.len()], cfg.act_scales.clone());
    while let Some(mut batch) = collect(&rx, &cfg.policy) {
        // group by variant, preserving FIFO within groups
        batch.sort_by(|a, b| a.variant.cmp(&b.variant));
        let mut i = 0;
        while i < batch.len() {
            let mut j = i + 1;
            while j < batch.len() && batch[j].variant == batch[i].variant {
                j += 1;
            }
            let group = &batch[i..j];
            run_group(&cfg, &mut cache, group, &scales, &metrics)?;
            i = j;
        }
    }
    Ok(())
}

fn run_group(
    cfg: &ServerConfig,
    cache: &mut ExecutableCache,
    group: &[InferRequest],
    scales: &TensorF,
    metrics: &SharedMetrics,
) -> Result<()> {
    let variant = &group[0].variant;
    let available = cache.batch_sizes(&cfg.model, variant);
    let Some(exe_batch) = pick_batch(group.len(), &available) else {
        anyhow::bail!("no executable for {}/{}", cfg.model, variant);
    };
    let dims = group[0].image.dims().to_vec(); // (H, W, C)
    let img_sz: usize = dims.iter().product();
    let needs_scales = variant != "fp32";

    let mut done = 0;
    while done < group.len() {
        let take = exe_batch.min(group.len() - done);
        // build padded batch tensor
        let mut xb = TensorF::zeros(&[exe_batch, dims[0], dims[1], dims[2]]);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        let exe = cache.get(&cfg.model, variant, exe_batch)?;
        let inputs: Vec<Input> = if needs_scales {
            vec![Input::F32(xb), Input::F32(scales.clone())]
        } else {
            vec![Input::F32(xb)]
        };
        let t0 = Instant::now();
        let logits = exe.run_f32(&inputs)?;
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        {
            let mut m = metrics.lock().unwrap();
            m.record_batch(take, exe_batch - take, exec);
            for req in &group[done..done + take] {
                m.record_request(queue_start - req.submitted, req.submitted.elapsed());
            }
        }
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(resp); // client may have gone away
        }
        done += take;
    }
    Ok(())
}
