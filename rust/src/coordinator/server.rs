//! The multi-model serving coordinator: typed requests → per-model
//! shards → batcher → execution backends.
//!
//! A [`Coordinator`] owns N model shards. Each shard is one worker
//! thread owning all execution state for its model — the actor pattern —
//! with its own engine, [`ExecutableCache`] and registered deployment
//! plans. Clients resolve a cheap, cloneable [`ModelHandle`] once
//! (`coordinator.model("resnet18m")?`) and submit typed
//! [`VariantSpec`]s; unknown variants fail at `submit` time, not inside
//! the worker. Two backends hang off the same batching/metrics pipeline:
//!
//! * **PJRT** — AOT-compiled HLO executables from `make artifacts`
//!   (requires the `pjrt` feature), keyed (model, variant, batch).
//! * **native** — the in-process rust engine. Mixed-precision deployment
//!   plans are served here: [`ModelHandle::register_plan`] installs a
//!   [`DeploymentPlan`] and requests for `plan:<name>` run the native
//!   quantized forward with that plan's per-enc-point config. No
//!   artifacts are needed when the model is handed over in-process
//!   ([`ServerBuilder::model_local`]).
//!
//! The admin plane lives on the handle: [`ModelHandle::register_plan`],
//! [`ModelHandle::swap_plan`] (hot-swap the plan behind an alias without
//! dropping in-flight requests), [`ModelHandle::set_traffic_split`]
//! (deterministic seeded A/B routing), [`ModelHandle::set_routing_policy`]
//! (outcome-aware bandit routing), [`ModelHandle::watch_plans`] (plan
//! hot-reload from disk), and per-variant [`MetricsSnapshot`]s.
//!
//! So does the telemetry plane: each shard owns a trace ring
//! ([`ModelHandle::set_tracing`] / [`ModelHandle::drain_events`]) and an
//! OverQ coverage/drift counter registry fed by the worker's quantized
//! forward passes ([`ModelHandle::obs_snapshot`]); both export through
//! [`ModelHandle::prometheus`] / [`ModelHandle::stats_json`]
//! (docs/observability.md).

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::sync::{lock, Arc, Mutex};

use crate::models::zoo::LoadedModel;
use crate::models::Artifacts;
use crate::nn::QuantConfig;
use crate::obs::counters::{self, Registry, VariantObsSnapshot};
use crate::obs::span::{self, Event, Ring};
use crate::policy::DeploymentPlan;
use crate::runtime::artifacts::ExecutableCache;
use crate::runtime::pjrt::Input;
use crate::tensor::TensorF;
use crate::util::rng::Rng;

use super::batcher::{collect, BatchPolicy};
use super::metrics::{shared, MetricsSnapshot, SharedMetrics};
use super::router::{chunks, pick_batch, pick_weighted, ArmStats, BanditConfig, BanditRouter};
use super::variant::{Backend, VariantSpec};
use super::watch;

/// The outcome-aware router shared between the submit path (picks) and
/// the shard worker (reward feedback); `None` = fixed-weight routing.
type SharedBandit = Arc<Mutex<Option<BanditRouter>>>;

/// Per-shard trace ring capacity (events). Beyond it the oldest events
/// are dropped and counted ([`ModelHandle::trace_dropped`]), never
/// blocking the request path.
const TRACE_RING_CAPACITY: usize = 4096;

/// How [`ModelHandle::submit_routed`] resolves a variant for each
/// request (installed via [`ModelHandle::set_routing_policy`]).
pub enum RoutingPolicy {
    /// Fixed-weight routing: the installed traffic split
    /// ([`ModelHandle::set_traffic_split`]), or `fp32` when none is set.
    /// Installing this clears any bandit state.
    Fixed,
    /// Outcome-aware routing: a seeded [`BanditRouter`] over the given
    /// arms learns per-arm rewards from live latency and shifts traffic
    /// toward the winner, with the control arm pinned at the exploration
    /// floor (docs/operations.md).
    Bandit(BanditConfig),
}

/// A single inference request (one image), already resolved to a
/// non-split variant.
pub struct InferRequest {
    /// (H, W, C) normalized image.
    pub image: TensorF,
    /// Resolved (non-split) variant to execute.
    pub spec: VariantSpec,
    /// When the client submitted (for queue/e2e latency accounting).
    pub submitted: Instant,
    /// Where the worker sends this request's [`InferResult`].
    pub resp: SyncSender<InferResult>,
}

/// Reply for one request.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// Classifier logits, one per class.
    pub logits: Vec<f32>,
    /// Size of the batch this request executed in.
    pub batch_size: usize,
    /// Time spent queued before execution started.
    pub queue: Duration,
    /// Submit-to-response wall time.
    pub e2e: Duration,
}

/// Per-request outcome: backend failures reach the client instead of
/// killing the worker.
pub type InferResult = std::result::Result<InferResponse, String>;

/// Messages into a shard worker.
enum Msg {
    Infer(InferRequest),
    /// Install `plan` so that requests for `plan:<alias>` run it.
    InstallPlan { alias: String, plan: DeploymentPlan },
}

/// One model registration inside [`ServerBuilder`].
struct ModelSpec {
    name: String,
    local: Option<LoadedModel>,
    act_scales: Vec<f32>,
    input_dims: Vec<usize>,
}

/// Builder for a [`Coordinator`] — replaces the old bare `ServerConfig`.
///
/// ```no_run
/// use overq::coordinator::Coordinator;
/// # fn main() -> anyhow::Result<()> {
/// let coord = Coordinator::builder()
///     .model("resnet18m")
///     .model("resnet50m")
///     .seed(7)
///     .build()?;
/// let handle = coord.model("resnet18m")?;
/// # Ok(())
/// # }
/// ```
pub struct ServerBuilder {
    policy: BatchPolicy,
    seed: u64,
    models: Vec<ModelSpec>,
    /// A builder-misuse message (e.g. per-model setter before any
    /// model); surfaced as an error from [`ServerBuilder::build`].
    misuse: Option<String>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    /// Empty builder; add shards with [`ServerBuilder::model`] /
    /// [`ServerBuilder::model_local`], then [`ServerBuilder::build`].
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            policy: BatchPolicy::default(),
            seed: 0x0A0B_5EED,
            models: Vec::new(),
            misuse: None,
        }
    }

    /// Batching policy applied to every shard.
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Seed for the deterministic traffic-split routers.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Add an artifact-backed model shard (requires `make artifacts`).
    pub fn model(mut self, name: &str) -> Self {
        self.models.push(ModelSpec {
            name: name.to_string(),
            local: None,
            act_scales: Vec::new(),
            input_dims: vec![16, 16, 3],
        });
        self
    }

    /// Add a shard around an in-process model — no artifacts required.
    /// Only native variants (`plan:<name>`, `native_fp32`, `fp32`) are
    /// servable unless artifacts are also present.
    pub fn model_local(mut self, model: LoadedModel) -> Self {
        self.models.push(ModelSpec {
            name: model.name.clone(),
            local: Some(model),
            act_scales: Vec::new(),
            input_dims: vec![16, 16, 3],
        });
        self
    }

    /// Activation scales (per enc point) for the most recently added
    /// model — used by HLO-quantized variants. Calling this before any
    /// `model`/`model_local` is a build-time error, not a silent no-op.
    pub fn act_scales(mut self, scales: Vec<f32>) -> Self {
        match self.models.last_mut() {
            Some(m) => m.act_scales = scales,
            None => {
                self.misuse
                    .get_or_insert_with(|| "act_scales() called before any model".to_string());
            }
        }
        self
    }

    /// Expected request image shape for the most recently added model
    /// (default `[16, 16, 3]`); submits with other shapes fail fast.
    /// Calling this before any `model`/`model_local` is a build-time
    /// error, not a silent no-op.
    pub fn input_dims(mut self, dims: &[usize]) -> Self {
        match self.models.last_mut() {
            Some(m) => m.input_dims = dims.to_vec(),
            None => {
                self.misuse
                    .get_or_insert_with(|| "input_dims() called before any model".to_string());
            }
        }
        self
    }

    /// Spawn one worker per registered model.
    pub fn build(self) -> Result<Coordinator> {
        let ServerBuilder {
            policy,
            seed,
            models,
            misuse,
        } = self;
        if let Some(m) = misuse {
            anyhow::bail!("ServerBuilder misuse: {m}");
        }
        anyhow::ensure!(!models.is_empty(), "ServerBuilder needs at least one model");
        let arts_root = Artifacts::locate().ok().map(|a| a.root);

        // validate every spec BEFORE spawning any worker, so a failed
        // build never leaves orphaned shard threads behind
        let probe = match &arts_root {
            Some(r) => Some(Artifacts::open(r)?),
            None => None,
        };
        let art_models: Vec<String> = probe.as_ref().map(|a| a.model_names()).unwrap_or_default();
        let mut seen: HashSet<String> = HashSet::new();
        for spec in &models {
            anyhow::ensure!(
                seen.insert(spec.name.clone()),
                "duplicate model {:?} in builder",
                spec.name
            );
            anyhow::ensure!(
                spec.local.is_some() || art_models.iter().any(|n| n == &spec.name),
                "model {:?} is not in the artifact manifest and no in-process \
                 model was given (ServerBuilder::model_local)",
                spec.name
            );
        }

        let mut shards: Vec<Arc<Shard>> = Vec::with_capacity(models.len());
        for (i, spec) in models.into_iter().enumerate() {
            let arts = match &arts_root {
                Some(r) => Some(Artifacts::open(r)?),
                None => None,
            };
            let compiled: HashSet<String> = arts
                .as_ref()
                .map(|a| {
                    a.hlo_entries()
                        .into_iter()
                        .filter(|(m, _, _, _)| m == &spec.name)
                        .map(|(_, v, _, _)| v)
                        .collect()
                })
                .unwrap_or_default();
            let (tx, rx) = std::sync::mpsc::channel::<Msg>();
            let metrics = shared();
            let bandit: SharedBandit = Arc::new(Mutex::new(None));
            let ring = Ring::new(TRACE_RING_CAPACITY);
            let obs = Registry::new();
            let telemetry = WorkerShared {
                metrics: metrics.clone(),
                bandit: bandit.clone(),
                ring: ring.clone(),
                obs: obs.clone(),
            };
            let worker_name = spec.name.clone();
            let scales = spec.act_scales.clone();
            // plan-independent abstract weight bounds for the static
            // certification gate, extracted before the model moves into
            // the worker (artifact-backed shards have no in-process
            // engine and skip that gate)
            let bounds = spec
                .local
                .as_ref()
                .and_then(|m| crate::analysis::absint::GraphBounds::from_model(m).ok())
                .map(Arc::new);
            let local = spec.local;
            let worker = std::thread::Builder::new()
                .name(format!("overq-shard-{}", spec.name))
                .spawn(move || {
                    if let Err(e) =
                        worker_loop(arts, worker_name, policy, scales, local, rx, telemetry)
                    {
                        eprintln!("[coordinator] shard worker exited with error: {e:#}");
                    }
                })
                .context("spawn shard worker")?;
            shards.push(Arc::new(Shard {
                name: spec.name,
                input_dims: spec.input_dims,
                compiled,
                tx: Mutex::new(Some(tx)),
                worker: Mutex::new(Some(worker)),
                metrics,
                ring,
                obs,
                plans: Mutex::new(HashSet::new()),
                split: Mutex::new(None),
                bandit,
                rng: Mutex::new(Rng::new(seed ^ (0x51AB_D001u64 + i as u64))),
                bounds,
            }));
        }
        Ok(Coordinator { shards })
    }
}

/// Client-side state for one model shard. The native engine is always
/// servable: `ServerBuilder::build` refuses models that are neither
/// in-process nor loadable from the artifact manifest.
struct Shard {
    name: String,
    input_dims: Vec<usize>,
    /// HLO variant names present in the artifact manifest for this model.
    compiled: HashSet<String>,
    tx: Mutex<Option<Sender<Msg>>>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    metrics: SharedMetrics,
    /// Per-shard trace ring; disabled (one relaxed atomic load per span
    /// site) until [`ModelHandle::set_tracing`] turns it on.
    ring: Arc<Ring>,
    /// Per-shard OverQ coverage/drift counters, fed by the worker's
    /// quantized forward passes and the plans' stored drift baselines.
    obs: Arc<Registry>,
    /// Registered plan aliases — the submit-time fail-fast view of the
    /// worker's plan map. Kept in step with `install_plan` (inserted
    /// before the control message is sent), so a client's own
    /// registrations are always visible to its later submits.
    plans: Mutex<HashSet<String>>,
    /// Installed A/B traffic split, if any.
    split: Mutex<Option<Vec<(VariantSpec, f64)>>>,
    /// Outcome-aware router, if installed; shared with the worker for
    /// reward feedback. Takes precedence over `split` for routed
    /// submits.
    bandit: SharedBandit,
    /// Seeded router state for deterministic weighted arm picks.
    rng: Mutex<Rng>,
    /// Abstract weight bounds of the in-process engine, for the static
    /// certification gate on `install_plan` (`None` for artifact-backed
    /// shards, which skip that gate).
    bounds: Option<Arc<crate::analysis::absint::GraphBounds>>,
}

/// Handle to a running multi-model coordinator. Owns one worker thread
/// per model shard; dropping it (or calling [`Coordinator::shutdown`])
/// drains the queues and joins the workers.
pub struct Coordinator {
    shards: Vec<Arc<Shard>>,
}

impl Coordinator {
    /// Entry point: `Coordinator::builder().model(...).build()`.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// Cheap handle to one hosted model.
    pub fn model(&self, name: &str) -> Result<ModelHandle> {
        self.shards
            .iter()
            .find(|s| s.name == name)
            .map(|s| ModelHandle { shard: s.clone() })
            .with_context(|| {
                format!(
                    "coordinator hosts no model {name:?} (available: {:?})",
                    self.model_names()
                )
            })
    }

    /// Names of the hosted models, in registration order.
    pub fn model_names(&self) -> Vec<String> {
        self.shards.iter().map(|s| s.name.clone()).collect()
    }

    /// Graceful shutdown: close every queue and join the workers.
    /// In-flight requests are drained, not dropped.
    pub fn shutdown(self) {
        // Drop does the work; this is the explicit spelling.
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for s in &self.shards {
            drop(lock(&s.tx).take());
        }
        for s in &self.shards {
            let handle = lock(&s.worker).take();
            if let Some(w) = handle {
                let _ = w.join();
            }
        }
    }
}

/// Cheap, cloneable per-model handle: the request plane (`submit`,
/// `infer`, `infer_routed`) plus the admin plane (`register_plan`,
/// `swap_plan`, `set_traffic_split`, `metrics`).
#[derive(Clone)]
pub struct ModelHandle {
    shard: Arc<Shard>,
}

impl ModelHandle {
    /// The model this handle targets.
    pub fn model_name(&self) -> &str {
        &self.shard.name
    }

    /// Validate a non-split spec against what this shard can serve.
    fn check_leaf(&self, leaf: &VariantSpec) -> Result<()> {
        match leaf {
            VariantSpec::Split(_) => {
                anyhow::bail!("nested traffic splits are not supported")
            }
            VariantSpec::Plan(name) => {
                anyhow::ensure!(
                    lock(&self.shard.plans).contains(name),
                    "no registered plan {name:?} on model {:?}",
                    self.shard.name
                );
            }
            VariantSpec::Compiled(name) => {
                anyhow::ensure!(
                    self.shard.compiled.contains(name),
                    "unknown variant {name:?} for model {:?}: no compiled artifact \
                     (and it is not a plan/fp32 variant)",
                    self.shard.name
                );
                anyhow::ensure!(
                    cfg!(feature = "pjrt"),
                    "variant {name:?} needs the compiled (PJRT) backend, but this \
                     binary was built without the `pjrt` feature",
                );
            }
            VariantSpec::Fp32 { backend } => {
                // the native engine is always available (build() refuses
                // shards without it), so only the pinned-PJRT path can fail
                if matches!(backend, Backend::Pjrt) {
                    anyhow::ensure!(
                        self.shard.compiled.contains("fp32") && cfg!(feature = "pjrt"),
                        "pjrt_fp32 unavailable for model {:?}: needs an fp32 HLO \
                         artifact and the `pjrt` feature",
                        self.shard.name
                    );
                }
            }
        }
        Ok(())
    }

    /// Draw one split arm with the deterministic seeded router. The
    /// arms must already satisfy [`VariantSpec::validate_split`] —
    /// callers validate once at install (`set_traffic_split_spec`) or
    /// per hand-built spec (`submit`).
    fn draw_arm(&self, arms: &[(VariantSpec, f64)]) -> VariantSpec {
        let weights: Vec<f64> = arms.iter().map(|(_, w)| *w).collect();
        let i = pick_weighted(&mut lock(&self.shard.rng), &weights);
        arms[i].0.clone()
    }

    /// Validate shape + leaf and enqueue one request. The leaf check
    /// runs under the queue lock so it is atomic with a concurrent
    /// [`ModelHandle::register_plan`] from another handle clone (which
    /// inserts its alias and sends the control message under the same
    /// lock): if this check sees a plan alias, the worker-side install
    /// is already ahead of this request in the FIFO channel.
    fn submit_leaf(&self, image: TensorF, leaf: VariantSpec) -> Result<Receiver<InferResult>> {
        anyhow::ensure!(
            image.dims() == &self.shard.input_dims[..],
            "request image shape {:?} != model {:?} input shape {:?}",
            image.dims(),
            self.shard.name,
            self.shard.input_dims
        );
        let (rtx, rrx) = sync_channel(1);
        let guard = lock(&self.shard.tx);
        let tx = guard.as_ref().context("coordinator stopped")?;
        self.check_leaf(&leaf)?;
        tx.send(Msg::Infer(InferRequest {
            image,
            spec: leaf,
            submitted: Instant::now(),
            resp: rtx,
        }))
        .ok()
        .context("worker gone")?;
        Ok(rrx)
    }

    /// Submit one request without blocking; returns the response channel.
    /// Splits take one deterministic weighted draw from the shard
    /// router; unknown variants and wrong image shapes fail fast.
    pub fn submit(&self, image: TensorF, spec: &VariantSpec) -> Result<Receiver<InferResult>> {
        let leaf = match spec {
            VariantSpec::Split(arms) => {
                // hand-built Split values bypass the parse/split
                // constructors, so enforce the invariants here
                VariantSpec::validate_split(arms)?;
                self.draw_arm(arms)
            }
            other => other.clone(),
        };
        self.submit_leaf(image, leaf)
    }

    /// [`ModelHandle::submit`] with a string variant (parsed first).
    pub fn submit_variant(&self, image: TensorF, variant: &str) -> Result<Receiver<InferResult>> {
        self.submit(image, &VariantSpec::parse(variant)?)
    }

    /// Submit one request and block for its response.
    pub fn infer(&self, image: TensorF, spec: &VariantSpec) -> Result<InferResponse> {
        let rx = self.submit(image, spec)?;
        rx.recv()
            .context("worker dropped the response")?
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// [`ModelHandle::infer`] with a string variant (parsed first).
    pub fn infer_variant(&self, image: TensorF, variant: &str) -> Result<InferResponse> {
        self.infer(image, &VariantSpec::parse(variant)?)
    }

    /// Submit through the installed routing policy: the bandit router
    /// when one is installed ([`ModelHandle::set_routing_policy`]), else
    /// the fixed traffic split ([`ModelHandle::set_traffic_split`]),
    /// else `fp32`.
    pub fn submit_routed(&self, image: TensorF) -> Result<Receiver<InferResult>> {
        let t0 = self.shard.ring.enabled().then(Instant::now);
        let bandit_leaf = lock(&self.shard.bandit).as_mut().map(|b| b.pick());
        let leaf = match bandit_leaf {
            Some(leaf) => leaf,
            None => {
                let split = lock(&self.shard.split);
                match &*split {
                    // validated when installed by set_traffic_split_spec
                    Some(arms) => self.draw_arm(arms),
                    None => VariantSpec::Fp32 {
                        backend: Backend::Auto,
                    },
                }
            }
        };
        if let Some(t0) = t0 {
            let d = format!("variant={}", leaf.key());
            self.shard.ring.record("route", d, t0, Instant::now());
        }
        self.submit_leaf(image, leaf)
    }

    /// Blocking version of [`ModelHandle::submit_routed`].
    pub fn infer_routed(&self, image: TensorF) -> Result<InferResponse> {
        let rx = self.submit_routed(image)?;
        rx.recv()
            .context("worker dropped the response")?
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Install (or replace) a deployment plan under its own name;
    /// requests may then target `plan:<plan.name>`. Ordered with respect
    /// to this handle's later `submit`s.
    pub fn register_plan(&self, plan: DeploymentPlan) -> Result<()> {
        let alias = plan.name.clone();
        self.install_plan(alias, plan)
    }

    /// Hot-swap: requests targeting `plan:<alias>` switch to `plan`
    /// without clients changing their variant strings and without
    /// dropping in-flight requests (they run on whichever plan the
    /// worker holds when their batch executes).
    pub fn swap_plan(&self, alias: &str, plan: DeploymentPlan) -> Result<()> {
        anyhow::ensure!(!alias.is_empty(), "plan alias must be non-empty");
        self.install_plan(alias.to_string(), plan)
    }

    fn install_plan(&self, alias: String, plan: DeploymentPlan) -> Result<()> {
        anyhow::ensure!(
            plan.model == self.shard.name,
            "plan {:?} was tuned for model {:?}, this shard serves {:?}",
            plan.name,
            plan.model,
            self.shard.name
        );
        // static analysis gate: Error-level lint findings make a plan
        // unservable, so refuse before anything is published. Warnings
        // (area drift etc.) serve fine — `overq lint` is where they gate.
        let report = crate::analysis::lint_plan(&plan);
        if let Some(d) = report.first_error() {
            anyhow::bail!("plan {:?} failed lint: {d}", plan.name);
        }
        // second static gate: abstract interpretation over the model
        // graph (`analysis::absint`). A plan whose scales provably
        // saturate the cascade capacity on every input (OQ020) is
        // refused before anything is published; warnings pass, same
        // contract as lint. Covers `register_plan`, `swap_plan` and the
        // `PlanWatch` hot-reload path, which all land here.
        if let Some(gb) = &self.shard.bounds {
            let cert = crate::analysis::absint::verify_plan_with_bounds(
                gb,
                &plan,
                crate::analysis::absint::DEFAULT_INPUT_RANGE,
                &crate::analysis::absint::AbsintConfig::default(),
            );
            if let Some(d) = cert.report.first_error() {
                anyhow::bail!("plan {:?} failed static certification: {d}", plan.name);
            }
        }
        // alias-insert + control-message send happen under the queue
        // lock (same lock as submit_leaf's validate + send), so ANY
        // handle that passes the fail-fast check is guaranteed the
        // worker-side install is ahead of its request in the channel
        let guard = lock(&self.shard.tx);
        let tx = guard.as_ref().context("coordinator stopped")?;
        // publish the plan's profile-time drift baselines before the
        // install becomes visible, so coverage snapshots can compare
        // live activation stats from the first request onward
        let drift = plan.layers.iter().map(|l| l.drift).collect();
        self.shard.obs.set_baselines(&format!("plan:{alias}"), drift);
        lock(&self.shard.plans).insert(alias.clone());
        tx.send(Msg::InstallPlan { alias, plan })
            .ok()
            .context("worker gone")?;
        Ok(())
    }

    /// Install a weighted A/B split, e.g.
    /// `handle.set_traffic_split(&[("plan:a", 0.9), ("plan:b", 0.1)])`.
    /// Every arm is validated against this shard; requests submitted via
    /// [`ModelHandle::submit_routed`] then draw arms from the seeded
    /// router, so the arm sequence is reproducible run-to-run.
    pub fn set_traffic_split(&self, split: &[(&str, f64)]) -> Result<()> {
        self.set_traffic_split_spec(&VariantSpec::split(split)?)
    }

    /// [`ModelHandle::set_traffic_split`] for an already-parsed
    /// [`VariantSpec::Split`] (e.g. straight from `VariantSpec::parse`).
    pub fn set_traffic_split_spec(&self, spec: &VariantSpec) -> Result<()> {
        let VariantSpec::Split(arms) = spec else {
            anyhow::bail!("set_traffic_split needs a split variant, got {spec}")
        };
        VariantSpec::validate_split(arms)?;
        for (arm, _) in arms {
            self.check_leaf(arm)?;
        }
        *lock(&self.shard.split) = Some(arms.clone());
        Ok(())
    }

    /// The currently installed traffic split, if any.
    pub fn traffic_split(&self) -> Option<Vec<(VariantSpec, f64)>> {
        lock(&self.shard.split).clone()
    }

    /// Install the routing policy behind [`ModelHandle::submit_routed`].
    ///
    /// `Bandit` validates every arm against this shard (same fail-fast
    /// contract as [`ModelHandle::set_traffic_split`]), builds the
    /// seeded [`BanditRouter`], and pins its control arm as the metrics
    /// regret reference. `Fixed` tears the bandit down again; the plain
    /// traffic split (if any) takes back over. In-flight requests are
    /// unaffected either way — the policy only decides future submits.
    pub fn set_routing_policy(&self, policy: RoutingPolicy) -> Result<()> {
        match policy {
            RoutingPolicy::Fixed => {
                *lock(&self.shard.bandit) = None;
                lock(&self.shard.metrics).control_arm = None;
            }
            RoutingPolicy::Bandit(cfg) => {
                for (arm, _) in &cfg.arms {
                    if !arm.is_split() {
                        self.check_leaf(arm)?;
                    }
                }
                // rejects splits, duplicate arms, bad floors/priors
                let router = BanditRouter::new(cfg)?;
                let control = router.control_key().to_string();
                *lock(&self.shard.bandit) = Some(router);
                lock(&self.shard.metrics).control_arm = Some(control);
            }
        }
        Ok(())
    }

    /// Per-arm bandit statistics (pulls, mean reward, control pin), or
    /// `None` under fixed routing.
    pub fn bandit_arms(&self) -> Option<Vec<ArmStats>> {
        lock(&self.shard.bandit).as_ref().map(|b| b.arm_stats())
    }

    /// Watch `dir` for new/changed `*.plan.json` files and hot-swap
    /// matching plans through the admin plane every `interval`
    /// (docs/operations.md has the full lifecycle). Plan files already
    /// on disk are applied synchronously before this returns, so their
    /// `plan:<name>` variants are immediately servable. Rejected files
    /// leave the previously served plan untouched and are surfaced via
    /// [`MetricsSnapshot::watch_errors`]. Dropping the returned
    /// [`watch::PlanWatcher`] stops the background poller.
    pub fn watch_plans(
        &self,
        dir: impl AsRef<Path>,
        interval: Duration,
    ) -> Result<watch::PlanWatcher> {
        let mut w = watch::PlanWatch::new(self.clone(), dir)?;
        let _ = w.poll();
        Ok(watch::spawn(w, interval))
    }

    /// Metrics hook for the plan watcher: one applied swap.
    pub(crate) fn note_plan_swap(&self) {
        lock(&self.shard.metrics).record_plan_swap();
    }

    /// Metrics hook for the plan watcher: one rejected plan file.
    pub(crate) fn note_watch_error(&self, msg: &str) {
        eprintln!("[coordinator] plan watch: {msg}");
        lock(&self.shard.metrics).record_watch_error(msg);
    }

    /// Point-in-time metrics for this shard (global + per-variant).
    pub fn metrics(&self) -> MetricsSnapshot {
        lock(&self.shard.metrics).snapshot()
    }

    /// Zero this shard's metrics and OverQ coverage counters — e.g. to
    /// exclude warmup traffic from a measurement window, or between A/B
    /// experiment epochs. Requests already in the queue still count
    /// when they execute. Configuration and lifecycle state survive:
    /// the control-arm pin, the plan-watcher health counters
    /// (`plan_swaps` / `watch_errors` / `last_watch_error`), and the
    /// plans' stored drift baselines.
    pub fn reset_metrics(&self) {
        lock(&self.shard.metrics).reset();
        self.shard.obs.reset();
    }

    /// Turn request tracing for this shard on or off. While off a span
    /// site costs one relaxed atomic load; buffered events survive a
    /// disable and wait for [`ModelHandle::drain_events`].
    pub fn set_tracing(&self, on: bool) {
        self.shard.ring.set_enabled(on);
    }

    /// Drain this shard's buffered trace events, oldest first. `overq
    /// trace` renders them as JSONL
    /// ([`crate::obs::span::events_jsonl`]).
    pub fn drain_events(&self) -> Vec<Event> {
        self.shard.ring.drain()
    }

    /// Trace events dropped to the ring bound so far (process
    /// lifetime; exported as `overq_trace_dropped_total`).
    pub fn trace_dropped(&self) -> u64 {
        self.shard.ring.dropped()
    }

    /// Point-in-time OverQ coverage/drift counters for this shard, one
    /// entry per observed variant, sorted by variant key.
    pub fn obs_snapshot(&self) -> Vec<VariantObsSnapshot> {
        self.shard.obs.snapshot()
    }

    /// Prometheus text exposition of this shard's serving metrics plus
    /// the OverQ coverage counters — the body served by `overq serve
    /// --telemetry-addr` under `/metrics` (docs/observability.md).
    pub fn prometheus(&self) -> String {
        let snap = self.metrics();
        snap.render_prometheus(&self.obs_snapshot(), self.trace_dropped())
    }

    /// One JSON document with serving metrics, per-variant coverage and
    /// trace health — what `overq stats` tabulates and the telemetry
    /// listener serves under `/snapshot.json`.
    pub fn stats_json(&self) -> crate::util::json::Value {
        let snap = self.metrics();
        snap.stats_json(&self.obs_snapshot(), self.trace_dropped())
    }

    /// Warm a variant: trigger compilation of every batch size by
    /// pushing enough dummy requests to hit the largest executable.
    /// Returns the wall time spent (the one-time compile cost).
    pub fn warmup(&self, spec: &VariantSpec, max_batch: usize) -> Result<Duration> {
        let dims = self.shard.input_dims.clone();
        let t0 = Instant::now();
        // single request exercises the b1 executable (if present)
        let _ = self.infer(TensorF::zeros(&dims), spec)?;
        // a burst exercises the batched executable
        let burst: Vec<_> = (0..max_batch)
            .map(|_| self.submit(TensorF::zeros(&dims), spec))
            .collect::<Result<_>>()?;
        for rx in burst {
            rx.recv()
                .context("warmup response lost")?
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(t0.elapsed())
    }
}

/// The shared state a shard worker and its client-side [`Shard`] both
/// hold: metrics, the bandit router, and the telemetry sinks.
struct WorkerShared {
    metrics: SharedMetrics,
    bandit: SharedBandit,
    ring: Arc<Ring>,
    obs: Arc<Registry>,
}

/// Worker-side state shared across batches of one shard.
struct WorkerState {
    model_name: String,
    policy: BatchPolicy,
    arts: Option<Artifacts>,
    cache: ExecutableCache,
    native: Option<LoadedModel>,
    plans: HashMap<String, DeploymentPlan>,
    scales: TensorF,
    metrics: SharedMetrics,
    bandit: SharedBandit,
    ring: Arc<Ring>,
    obs: Arc<Registry>,
}

fn worker_loop(
    arts: Option<Artifacts>,
    model_name: String,
    policy: BatchPolicy,
    act_scales: Vec<f32>,
    native: Option<LoadedModel>,
    rx: std::sync::mpsc::Receiver<Msg>,
    telemetry: WorkerShared,
) -> Result<()> {
    let cache = match &arts {
        Some(a) => ExecutableCache::new(a)?,
        None => ExecutableCache::empty(),
    };
    let scales = TensorF::from_vec(&[act_scales.len()], act_scales);
    let WorkerShared {
        metrics,
        bandit,
        ring,
        obs,
    } = telemetry;
    let mut st = WorkerState {
        model_name,
        policy,
        arts,
        cache,
        native,
        plans: HashMap::new(),
        scales,
        metrics,
        bandit,
        ring,
        obs,
    };
    while let Some(batch) = collect(&rx, &st.policy) {
        // apply control messages, then group inference FIFO by variant
        let mut infers: Vec<InferRequest> = Vec::with_capacity(batch.len());
        for msg in batch {
            match msg {
                Msg::InstallPlan { alias, plan } => {
                    st.plans.insert(alias, plan);
                }
                Msg::Infer(req) => infers.push(req),
            }
        }
        // stable, allocation-free grouping by variant (FIFO within)
        infers.sort_by(|a, b| a.spec.group_key().cmp(&b.spec.group_key()));
        let mut i = 0;
        while i < infers.len() {
            let mut j = i + 1;
            while j < infers.len() && infers[j].spec == infers[i].spec {
                j += 1;
            }
            let group = &infers[i..j];
            if let Err(e) = run_group(&mut st, group) {
                // per-group failure (missing artifact, backend error):
                // reply to every request and keep serving
                let msg = format!("{e:#}");
                for req in group {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
            i = j;
        }
    }
    Ok(())
}

fn run_group(st: &mut WorkerState, group: &[InferRequest]) -> Result<()> {
    match &group[0].spec {
        VariantSpec::Plan(name) => {
            let plan = st
                .plans
                .get(name)
                .with_context(|| format!("no registered plan {name:?}"))?;
            anyhow::ensure!(
                plan.model == st.model_name,
                "plan {name:?} was tuned for model {:?}, shard serves {:?}",
                plan.model,
                st.model_name
            );
            let qc = plan.to_quant_config();
            run_group_native(st, group, Some(&qc))
        }
        VariantSpec::Fp32 {
            backend: Backend::Native,
        } => run_group_native(st, group, None),
        VariantSpec::Fp32 {
            backend: Backend::Auto,
        } => {
            // fp32 prefers PJRT when it can actually run — an HLO
            // artifact exists and the binary has the `pjrt` feature —
            // and falls back to the native engine otherwise.
            let available = st.cache.batch_sizes(&st.model_name, "fp32");
            if !available.is_empty() && cfg!(feature = "pjrt") {
                run_group_pjrt(st, group, "fp32", &available)
            } else {
                run_group_native(st, group, None)
            }
        }
        VariantSpec::Fp32 {
            backend: Backend::Pjrt,
        } => {
            let available = st.cache.batch_sizes(&st.model_name, "fp32");
            run_group_pjrt(st, group, "fp32", &available)
        }
        VariantSpec::Compiled(name) => {
            let available = st.cache.batch_sizes(&st.model_name, name);
            run_group_pjrt(st, group, name, &available)
        }
        VariantSpec::Split(_) => {
            anyhow::bail!("split variants must be resolved before the worker")
        }
    }
}

/// Account one executed chunk: feed each request's e2e latency to the
/// bandit (when outcome-aware routing is on), then record the batch,
/// per-request latencies, and rewards under one metrics lock — batch
/// and request counters stay mutually consistent for snapshots. The
/// bandit and metrics locks are taken sequentially, never nested.
fn account_chunk(
    metrics: &SharedMetrics,
    bandit: &SharedBandit,
    key: &str,
    reqs: &[InferRequest],
    queue_start: Instant,
    padded: usize,
    exec: Duration,
) {
    let lats: Vec<(Duration, Duration)> = reqs
        .iter()
        .map(|r| (queue_start - r.submitted, r.submitted.elapsed()))
        .collect();
    let rewards: Vec<Option<f64>> = {
        let mut guard = lock(&bandit);
        match guard.as_mut() {
            Some(b) => lats
                .iter()
                .map(|(_, e2e)| b.observe(key, e2e.as_micros() as f64))
                .collect(),
            None => vec![None; lats.len()],
        }
    };
    let mut m = lock(&metrics);
    m.record_batch(reqs.len(), padded, exec);
    for ((queue, e2e), reward) in lats.iter().zip(&rewards) {
        m.record_request(key, *queue, *e2e);
        if let Some(r) = reward {
            m.record_reward(key, *r);
        }
    }
}

/// Ensure the native model is loaded (in-process handoff or artifacts).
fn native_model(st: &mut WorkerState) -> Result<&LoadedModel> {
    if st.native.is_none() {
        let arts = st
            .arts
            .as_ref()
            .context("native backend needs an in-process model or artifacts")?;
        st.native = Some(arts.load_model(&st.model_name)?);
    }
    Ok(st.native.as_ref().unwrap())
}

fn run_group_native(
    st: &mut WorkerState,
    group: &[InferRequest],
    qc: Option<&QuantConfig>,
) -> Result<()> {
    let max_batch = st.policy.max_batch.max(1);
    let key = group[0].spec.key();
    let metrics = st.metrics.clone();
    let bandit = st.bandit.clone();
    let ring = st.ring.clone();
    // pin the trace ring and this variant's counter slot to the worker
    // thread, so deep engine code (forward_quant's encode sites) can
    // record spans and coverage without seeing the shard
    let _sink = span::set_sink(ring.clone());
    let _ctx = counters::set_ctx(st.obs.variant(&key));
    let model = native_model(st)?;
    if let Some(qc) = qc {
        anyhow::ensure!(
            qc.num_enc_points() >= model.engine.graph.num_enc_points(),
            "plan covers {} enc points, model {} has {}",
            qc.num_enc_points(),
            model.name,
            model.engine.graph.num_enc_points()
        );
    }
    let dims = group[0].image.dims().to_vec();
    let img_sz: usize = dims.iter().product();
    let mut done = 0;
    for take in chunks(group.len(), max_batch) {
        let mut bdims = vec![take];
        bdims.extend_from_slice(&dims);
        let mut xb = TensorF::zeros(&bdims);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            anyhow::ensure!(
                req.image.numel() == img_sz,
                "request image shape {:?} != group shape {:?}",
                req.image.dims(),
                dims
            );
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        if ring.enabled() {
            let qd = format!("variant={key}");
            for req in &group[done..done + take] {
                ring.record("queue", qd.clone(), req.submitted, queue_start);
            }
        }
        let _batch = ring.span("batch", format!("variant={key} batch={take}"));
        let t0 = Instant::now();
        let logits = {
            let _exec = ring.span("execute", format!("variant={key} batch={take}"));
            match qc {
                Some(qc) => model.engine.forward_quant(&xb, qc)?,
                None => model.engine.forward_f32(&xb, &[])?.0,
            }
        };
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        account_chunk(
            &metrics,
            &bandit,
            &key,
            &group[done..done + take],
            queue_start,
            0,
            exec,
        );
        let _decode = ring.span("decode", format!("variant={key} batch={take}"));
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}

fn run_group_pjrt(
    st: &mut WorkerState,
    group: &[InferRequest],
    variant: &str,
    available: &[usize],
) -> Result<()> {
    let Some(exe_batch) = pick_batch(group.len(), available) else {
        anyhow::bail!("no executable for {}/{}", st.model_name, variant);
    };
    let key = group[0].spec.key();
    let ring = st.ring.clone();
    let dims = group[0].image.dims().to_vec(); // (H, W, C)
    let img_sz: usize = dims.iter().product();
    let needs_scales = variant != "fp32";

    let mut done = 0;
    for take in chunks(group.len(), exe_batch) {
        // build padded batch tensor (shape-generic, like the native path)
        let mut bdims = vec![exe_batch];
        bdims.extend_from_slice(&dims);
        let mut xb = TensorF::zeros(&bdims);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        if ring.enabled() {
            let qd = format!("variant={key}");
            for req in &group[done..done + take] {
                ring.record("queue", qd.clone(), req.submitted, queue_start);
            }
        }
        let exe = st.cache.get(&st.model_name, variant, exe_batch)?;
        let inputs: Vec<Input> = if needs_scales {
            vec![Input::F32(xb), Input::F32(st.scales.clone())]
        } else {
            vec![Input::F32(xb)]
        };
        let t0 = Instant::now();
        let logits = {
            let _exec = ring.span("execute", format!("variant={key} batch={exe_batch}"));
            exe.run_f32(&inputs)?
        };
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        account_chunk(
            &st.metrics,
            &st.bandit,
            &key,
            &group[done..done + take],
            queue_start,
            exe_batch - take,
            exec,
        );
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}
