//! The inference server: request channel → batcher → execution backends.
//!
//! One worker thread owns all execution state — the actor pattern.
//! Clients hold a cheap [`Server`] handle. Two backends hang off the
//! same batching/metrics pipeline:
//!
//! * **PJRT** — AOT-compiled HLO executables from `make artifacts`
//!   (requires the `pjrt` feature), keyed (model, variant, batch).
//! * **native** — the in-process rust engine. This is how mixed-precision
//!   deployment plans are served: [`Server::register_plan`] installs a
//!   [`DeploymentPlan`] and requests for variant `plan:<name>` run the
//!   native quantized forward with that plan's per-enc-point config.
//!   `native_fp32` runs the fp32 reference path. No artifacts needed
//!   when the model is handed over in-process ([`Server::start_local`]).

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::models::zoo::LoadedModel;
use crate::models::Artifacts;
use crate::nn::QuantConfig;
use crate::policy::DeploymentPlan;
use crate::runtime::artifacts::ExecutableCache;
use crate::runtime::pjrt::Input;
use crate::tensor::TensorF;

use super::batcher::{collect, BatchPolicy};
use super::metrics::{shared, MetricsSnapshot, SharedMetrics};
use super::router::pick_batch;

/// A single inference request (one image).
pub struct InferRequest {
    /// (H, W, C) normalized image.
    pub image: TensorF,
    /// Which variant to run ("fp32", "full_c4", "plan:<name>",
    /// "native_fp32", ...).
    pub variant: String,
    pub submitted: Instant,
    pub resp: SyncSender<InferResult>,
}

/// Reply for one request.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub logits: Vec<f32>,
    pub batch_size: usize,
    pub queue: Duration,
    pub e2e: Duration,
}

/// Per-request outcome: bad variants / backend failures reach the
/// client instead of killing the worker.
pub type InferResult = std::result::Result<InferResponse, String>;

/// Messages into the worker.
enum Msg {
    Infer(InferRequest),
    RegisterPlan(DeploymentPlan),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: String,
    pub policy: BatchPolicy,
    /// Activation scales per enc point, for HLO-quantized variants.
    pub act_scales: Vec<f32>,
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<Msg>>,
    metrics: SharedMetrics,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the worker against the artifact directory; compiles HLO
    /// executables lazily and loads the native model on first use.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        Server::spawn(cfg, None)
    }

    /// Start with an in-process model — no artifacts required. Only
    /// native variants (`plan:<name>`, `native_fp32`) are servable
    /// unless artifacts are also present.
    pub fn start_local(cfg: ServerConfig, model: LoadedModel) -> Result<Server> {
        Server::spawn(cfg, Some(model))
    }

    fn spawn(cfg: ServerConfig, native: Option<LoadedModel>) -> Result<Server> {
        let arts = Artifacts::locate().ok();
        let (tx, rx) = std::sync::mpsc::channel::<Msg>();
        let metrics = shared();
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("overq-worker".into())
            .spawn(move || {
                if let Err(e) = worker_loop(arts, cfg, native, rx, m2) {
                    eprintln!("[server] worker exited with error: {e:#}");
                }
            })
            .context("spawn worker")?;
        Ok(Server {
            tx: Some(tx),
            metrics,
            worker: Some(worker),
        })
    }

    /// Install (or replace) a deployment plan; requests may then target
    /// variant `plan:<name>`. Ordered with respect to later `submit`s.
    pub fn register_plan(&self, plan: DeploymentPlan) -> Result<()> {
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Msg::RegisterPlan(plan))
            .ok()
            .context("worker gone")
    }

    /// Submit one request and block for its response.
    pub fn infer(&self, image: TensorF, variant: &str) -> Result<InferResponse> {
        let rx = self.submit(image, variant)?;
        rx.recv()
            .context("worker dropped the response")?
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Warm a variant: trigger compilation of every batch size by
    /// pushing enough dummy requests to hit the largest executable.
    /// Returns the wall time spent (the one-time compile cost).
    pub fn warmup(&self, variant: &str, dims: &[usize], max_batch: usize) -> Result<Duration> {
        let t0 = Instant::now();
        // single request exercises the b1 executable (if present)
        let _ = self.infer(TensorF::zeros(dims), variant)?;
        // a burst exercises the batched executable
        let burst: Vec<_> = (0..max_batch)
            .map(|_| self.submit(TensorF::zeros(dims), variant))
            .collect::<Result<_>>()?;
        for rx in burst {
            rx.recv()
                .context("warmup response lost")?
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(t0.elapsed())
    }

    /// Submit without blocking; returns the response channel.
    pub fn submit(&self, image: TensorF, variant: &str) -> Result<Receiver<InferResult>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Msg::Infer(InferRequest {
                image,
                variant: variant.to_string(),
                submitted: Instant::now(),
                resp: rtx,
            }))
            .ok()
            .context("worker gone")?;
        Ok(rrx)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Worker-side state shared across batches.
struct WorkerState {
    cfg: ServerConfig,
    arts: Option<Artifacts>,
    cache: ExecutableCache,
    native: Option<LoadedModel>,
    plans: HashMap<String, DeploymentPlan>,
    scales: TensorF,
    metrics: SharedMetrics,
}

fn worker_loop(
    arts: Option<Artifacts>,
    cfg: ServerConfig,
    native: Option<LoadedModel>,
    rx: std::sync::mpsc::Receiver<Msg>,
    metrics: SharedMetrics,
) -> Result<()> {
    let cache = match &arts {
        Some(a) => ExecutableCache::new(a)?,
        None => ExecutableCache::empty(),
    };
    let scales = TensorF::from_vec(&[cfg.act_scales.len()], cfg.act_scales.clone());
    let mut st = WorkerState {
        cfg,
        arts,
        cache,
        native,
        plans: HashMap::new(),
        scales,
        metrics,
    };
    while let Some(batch) = collect(&rx, &st.cfg.policy) {
        // apply control messages, then group inference FIFO by variant
        let mut infers: Vec<InferRequest> = Vec::with_capacity(batch.len());
        for msg in batch {
            match msg {
                Msg::RegisterPlan(plan) => {
                    st.plans.insert(plan.name.clone(), plan);
                }
                Msg::Infer(req) => infers.push(req),
            }
        }
        infers.sort_by(|a, b| a.variant.cmp(&b.variant));
        let mut i = 0;
        while i < infers.len() {
            let mut j = i + 1;
            while j < infers.len() && infers[j].variant == infers[i].variant {
                j += 1;
            }
            let group = &infers[i..j];
            if let Err(e) = run_group(&mut st, group) {
                // per-group failure (unknown variant, backend error):
                // reply to every request and keep serving
                let msg = format!("{e:#}");
                for req in group {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
            i = j;
        }
    }
    Ok(())
}

fn run_group(st: &mut WorkerState, group: &[InferRequest]) -> Result<()> {
    let variant = group[0].variant.as_str();
    if let Some(plan_name) = variant.strip_prefix("plan:") {
        let plan = st
            .plans
            .get(plan_name)
            .with_context(|| format!("no registered plan {plan_name:?}"))?;
        anyhow::ensure!(
            plan.model == st.cfg.model,
            "plan {plan_name:?} was tuned for model {:?}, server is serving {:?}",
            plan.model,
            st.cfg.model
        );
        let qc = plan.to_quant_config();
        return run_group_native(st, group, Some(&qc));
    }
    if variant == "native_fp32" {
        return run_group_native(st, group, None);
    }
    let available = st.cache.batch_sizes(&st.cfg.model, variant);
    // fp32 falls back to the native engine whenever PJRT can't actually
    // run it — no HLO artifact, or the binary was built without the
    // `pjrt` feature (the stub would reject the compiled path) — as
    // long as a native model is in-process or loadable from artifacts.
    if variant == "fp32"
        && (available.is_empty() || !cfg!(feature = "pjrt"))
        && (st.native.is_some() || st.arts.is_some())
    {
        return run_group_native(st, group, None);
    }
    run_group_pjrt(st, group, &available)
}

/// Ensure the native model is loaded (in-process handoff or artifacts).
fn native_model<'a>(st: &'a mut WorkerState) -> Result<&'a LoadedModel> {
    if st.native.is_none() {
        let arts = st
            .arts
            .as_ref()
            .context("native backend needs an in-process model or artifacts")?;
        st.native = Some(arts.load_model(&st.cfg.model)?);
    }
    Ok(st.native.as_ref().unwrap())
}

fn run_group_native(
    st: &mut WorkerState,
    group: &[InferRequest],
    qc: Option<&QuantConfig>,
) -> Result<()> {
    let max_batch = st.cfg.policy.max_batch.max(1);
    let metrics = st.metrics.clone();
    let model = native_model(st)?;
    if let Some(qc) = qc {
        anyhow::ensure!(
            qc.num_enc_points() >= model.engine.graph.num_enc_points(),
            "plan covers {} enc points, model {} has {}",
            qc.num_enc_points(),
            model.name,
            model.engine.graph.num_enc_points()
        );
    }
    let dims = group[0].image.dims().to_vec();
    let img_sz: usize = dims.iter().product();
    let mut done = 0;
    while done < group.len() {
        let take = max_batch.min(group.len() - done);
        let mut bdims = vec![take];
        bdims.extend_from_slice(&dims);
        let mut xb = TensorF::zeros(&bdims);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            anyhow::ensure!(
                req.image.numel() == img_sz,
                "request image shape {:?} != group shape {:?}",
                req.image.dims(),
                dims
            );
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        let t0 = Instant::now();
        let logits = match qc {
            Some(qc) => model.engine.forward_quant(&xb, qc)?,
            None => model.engine.forward_f32(&xb, &[])?.0,
        };
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        {
            let mut m = metrics.lock().unwrap();
            m.record_batch(take, 0, exec);
            for req in &group[done..done + take] {
                m.record_request(queue_start - req.submitted, req.submitted.elapsed());
            }
        }
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}

fn run_group_pjrt(
    st: &mut WorkerState,
    group: &[InferRequest],
    available: &[usize],
) -> Result<()> {
    let variant = &group[0].variant;
    let Some(exe_batch) = pick_batch(group.len(), available) else {
        anyhow::bail!("no executable for {}/{}", st.cfg.model, variant);
    };
    let dims = group[0].image.dims().to_vec(); // (H, W, C)
    let img_sz: usize = dims.iter().product();
    let needs_scales = variant != "fp32";

    let mut done = 0;
    while done < group.len() {
        let take = exe_batch.min(group.len() - done);
        // build padded batch tensor
        let mut xb = TensorF::zeros(&[exe_batch, dims[0], dims[1], dims[2]]);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        let exe = st.cache.get(&st.cfg.model, variant, exe_batch)?;
        let inputs: Vec<Input> = if needs_scales {
            vec![Input::F32(xb), Input::F32(st.scales.clone())]
        } else {
            vec![Input::F32(xb)]
        };
        let t0 = Instant::now();
        let logits = exe.run_f32(&inputs)?;
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        {
            let mut m = st.metrics.lock().unwrap();
            m.record_batch(take, exe_batch - take, exec);
            for req in &group[done..done + take] {
                m.record_request(queue_start - req.submitted, req.submitted.elapsed());
            }
        }
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}
