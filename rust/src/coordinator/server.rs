//! The multi-model serving coordinator: typed requests → per-model
//! shards → shared submission queue → replica workers → execution
//! backends.
//!
//! A [`Coordinator`] owns N model shards. Each shard is a bounded
//! [`SubmitQueue`] pulled by a *fleet* of replica worker threads
//! ([`ServerBuilder::replicas`]); every replica owns its own execution
//! state (engine handle, [`ExecutableCache`]) while the queue, the
//! published plan map, metrics and telemetry are shared. Clients
//! resolve a cheap, cloneable [`ModelHandle`] once
//! (`coordinator.model("resnet18m")?`) and submit typed
//! [`VariantSpec`]s; unknown variants fail at `submit` time, not inside
//! a worker. Two backends hang off the same batching/metrics pipeline:
//!
//! * **PJRT** — AOT-compiled HLO executables from `make artifacts`
//!   (requires the `pjrt` feature), keyed (model, variant, batch).
//! * **native** — the in-process rust engine. Mixed-precision deployment
//!   plans are served here: [`ModelHandle::register_plan`] installs a
//!   [`DeploymentPlan`] and requests for `plan:<name>` run the native
//!   quantized forward with that plan's per-enc-point config. No
//!   artifacts are needed when the model is handed over in-process
//!   ([`ServerBuilder::model_local`]).
//!
//! The serving layer is load-safe by construction (docs/serving.md,
//! "Fleet scaling"):
//!
//! * **Backpressure** — the queue is bounded ([`ServerBuilder::max_queue`])
//!   with optional per-tenant admission quotas
//!   ([`ServerBuilder::tenant_quota`]); overload sheds synchronously
//!   with a typed [`ServeError::Shed`] instead of queueing unboundedly.
//! * **Deadlines** — [`SubmitOpts::deadline`] bounds queue residency;
//!   expired requests get [`ServeError::DeadlineExceeded`], never a
//!   stale execution.
//! * **Fail-stop replicas** — a panicking replica errors out its
//!   in-flight batch ([`ServeError::ReplicaFailed`]), marks itself
//!   dead and stops pulling work; the surviving replicas keep serving.
//!   [`ModelHandle::set_replicas`] respawns capacity.
//! * **Cross-shard placement** — co-hosted models share one PE-area
//!   budget ([`ServerBuilder::area_budget`]); `install_plan` charges
//!   `plan.total_area × replicas` against it and either shrinks the
//!   fleet to fit or refuses the plan.
//!
//! The admin plane lives on the handle: [`ModelHandle::register_plan`],
//! [`ModelHandle::swap_plan`] (hot-swap the plan behind an alias without
//! dropping in-flight requests), [`ModelHandle::set_traffic_split`]
//! (deterministic seeded A/B routing), [`ModelHandle::set_routing_policy`]
//! (outcome-aware bandit routing), [`ModelHandle::watch_plans`] (plan
//! hot-reload from disk), and per-variant [`MetricsSnapshot`]s.
//!
//! So does the telemetry plane: each shard owns a trace ring
//! ([`ModelHandle::set_tracing`] / [`ModelHandle::drain_events`]) and an
//! OverQ coverage/drift counter registry fed by the workers' quantized
//! forward passes ([`ModelHandle::obs_snapshot`]); both export through
//! [`ModelHandle::prometheus`] / [`ModelHandle::stats_json`]
//! (docs/observability.md).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::sync::{lock, Arc, Mutex};

use crate::models::zoo::LoadedModel;
use crate::models::Artifacts;
use crate::nn::QuantConfig;
use crate::obs::counters::{self, Registry, VariantObsSnapshot};
use crate::obs::span::{self, Event, Ring};
use crate::policy::DeploymentPlan;
use crate::runtime::artifacts::ExecutableCache;
use crate::runtime::pjrt::Input;
use crate::tensor::TensorF;
use crate::util::rng::Rng;

use super::batcher::{BatchItem, BatchPolicy, Drained, PushError, QueueConfig, ShedReason,
                     SubmitQueue};
use super::metrics::{shared, MetricsSnapshot, SharedMetrics};
use super::router::{chunks, pick_batch, pick_weighted, ArmStats, BanditConfig, BanditRouter};
use super::variant::{Backend, VariantSpec};
use super::watch;

/// The outcome-aware router shared between the submit path (picks) and
/// the shard workers (reward feedback); `None` = fixed-weight routing.
type SharedBandit = Arc<Mutex<Option<BanditRouter>>>;

/// Published plans, shared between the admin plane (writes) and every
/// replica (reads at batch execution). A plan body is inserted here
/// *before* its alias becomes submit-visible, so any request passing
/// the fail-fast check finds its plan.
type SharedPlans = Arc<Mutex<HashMap<String, Arc<DeploymentPlan>>>>;

/// The armed test-only replica fault, if any (see
/// [`ModelHandle::inject_replica_fault`]).
type SharedFault = Arc<Mutex<Option<ReplicaFault>>>;

/// Per-shard trace ring capacity (events). Beyond it the oldest events
/// are dropped and counted ([`ModelHandle::trace_dropped`]), never
/// blocking the request path.
const TRACE_RING_CAPACITY: usize = 4096;

/// How [`ModelHandle::submit_routed`] resolves a variant for each
/// request (installed via [`ModelHandle::set_routing_policy`]).
pub enum RoutingPolicy {
    /// Fixed-weight routing: the installed traffic split
    /// ([`ModelHandle::set_traffic_split`]), or `fp32` when none is set.
    /// Installing this clears any bandit state.
    Fixed,
    /// Outcome-aware routing: a seeded [`BanditRouter`] over the given
    /// arms learns per-arm rewards from live latency and shifts traffic
    /// toward the winner, with the control arm pinned at the exploration
    /// floor (docs/operations.md).
    Bandit(BanditConfig),
}

/// Typed per-request failure. Reaches clients through [`InferResult`];
/// the blocking helpers ([`ModelHandle::infer`]) wrap it in `anyhow`
/// so callers can `downcast_ref::<ServeError>()` to branch on the kind.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Shed at admission — queue full or tenant over quota. The request
    /// never entered the queue.
    Shed(ShedReason),
    /// The request's deadline passed while it waited in the queue.
    DeadlineExceeded {
        /// How long the request had been queued when it was swept.
        queued: Duration,
    },
    /// The replica executing this request's batch died mid-batch
    /// (fail-stop); retry is safe, surviving replicas keep serving.
    ReplicaFailed(String),
    /// The execution backend failed (missing artifact, engine error).
    Backend(String),
    /// The coordinator is shut down or this shard has no live replica.
    Stopped,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed(r) => write!(f, "request shed: {r}"),
            ServeError::DeadlineExceeded { queued } => {
                write!(f, "deadline exceeded after {queued:?} queued")
            }
            ServeError::ReplicaFailed(m) => write!(f, "replica died mid-batch: {m}"),
            // backend failures render bare: they carry their own context
            ServeError::Backend(m) => write!(f, "{m}"),
            ServeError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// The shed reason, when this is an admission-control rejection.
    pub fn shed_reason(&self) -> Option<&ShedReason> {
        match self {
            ServeError::Shed(r) => Some(r),
            _ => None,
        }
    }
}

/// Per-submit options: tenant attribution for admission control and an
/// optional queue-residency deadline.
#[derive(Clone, Debug, Default)]
pub struct SubmitOpts {
    /// Admission-control tenant (default `"default"`). Quotas
    /// ([`ServerBuilder::tenant_quota`]) and the fair-dequeue order are
    /// keyed by this.
    pub tenant: Option<String>,
    /// Longest this request may wait in the queue, measured from
    /// submit. Past it the request is swept with
    /// [`ServeError::DeadlineExceeded`] instead of executing stale.
    pub deadline: Option<Duration>,
}

impl SubmitOpts {
    /// Options for `tenant`, no deadline.
    pub fn tenant(t: &str) -> SubmitOpts {
        SubmitOpts {
            tenant: Some(t.to_string()),
            deadline: None,
        }
    }

    /// Options with a queue-residency `deadline`, default tenant.
    pub fn deadline(d: Duration) -> SubmitOpts {
        SubmitOpts {
            tenant: None,
            deadline: Some(d),
        }
    }
}

/// Test-only fault injection (see [`ModelHandle::inject_replica_fault`]):
/// the *next* replica to pick up a batch trips the armed fault.
#[derive(Clone, Debug)]
pub enum ReplicaFault {
    /// Panic mid-batch: the replica fail-stops, its batch gets
    /// [`ServeError::ReplicaFailed`] replies.
    PanicNextBatch,
    /// Stall for the duration before executing the batch (a wedged
    /// replica; it stays alive).
    StallNextBatch(Duration),
}

/// A single inference request (one image), already resolved to a
/// non-split variant.
pub struct InferRequest {
    /// (H, W, C) normalized image.
    pub image: TensorF,
    /// Resolved (non-split) variant to execute.
    pub spec: VariantSpec,
    /// Batch-compatibility key (the resolved variant key); cached so
    /// the queue never re-derives it under its lock.
    pub group: String,
    /// Admission-control tenant.
    pub tenant: String,
    /// Absolute queue-residency deadline, if any.
    pub deadline: Option<Instant>,
    /// When the client submitted (for queue/e2e latency accounting).
    pub submitted: Instant,
    /// Where the executing replica sends this request's [`InferResult`].
    pub resp: SyncSender<InferResult>,
}

impl BatchItem for InferRequest {
    fn group(&self) -> &str {
        &self.group
    }
    fn tenant(&self) -> &str {
        &self.tenant
    }
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
}

/// Reply for one request.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// Classifier logits, one per class.
    pub logits: Vec<f32>,
    /// Size of the batch this request executed in.
    pub batch_size: usize,
    /// Time spent queued before execution started.
    pub queue: Duration,
    /// Submit-to-response wall time.
    pub e2e: Duration,
}

/// Per-request outcome: typed failures ([`ServeError`]) reach the
/// client instead of killing the worker.
pub type InferResult = std::result::Result<InferResponse, ServeError>;

/// One model registration inside [`ServerBuilder`].
struct ModelSpec {
    name: String,
    local: Option<LoadedModel>,
    act_scales: Vec<f32>,
    input_dims: Vec<usize>,
    replicas: usize,
}

/// Builder for a [`Coordinator`] — replaces the old bare `ServerConfig`.
///
/// ```no_run
/// use overq::coordinator::Coordinator;
/// # fn main() -> anyhow::Result<()> {
/// let coord = Coordinator::builder()
///     .model("resnet18m")
///     .replicas(2)
///     .model("resnet50m")
///     .seed(7)
///     .max_queue(512)
///     .tenant_quota(128)
///     .build()?;
/// let handle = coord.model("resnet18m")?;
/// # Ok(())
/// # }
/// ```
pub struct ServerBuilder {
    policy: BatchPolicy,
    seed: u64,
    models: Vec<ModelSpec>,
    queue_cfg: QueueConfig,
    area_budget: Option<f64>,
    /// A builder-misuse message (e.g. per-model setter before any
    /// model); surfaced as an error from [`ServerBuilder::build`].
    misuse: Option<String>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    /// Empty builder; add shards with [`ServerBuilder::model`] /
    /// [`ServerBuilder::model_local`], then [`ServerBuilder::build`].
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            policy: BatchPolicy::default(),
            seed: 0x0A0B_5EED,
            models: Vec::new(),
            queue_cfg: QueueConfig::default(),
            area_budget: None,
            misuse: None,
        }
    }

    /// Batching policy applied to every shard.
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Seed for the deterministic traffic-split routers.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Bound every shard's submission queue: pushes beyond `depth`
    /// waiting requests shed with [`ServeError::Shed`] instead of
    /// queueing (default 4096).
    pub fn max_queue(mut self, depth: usize) -> Self {
        self.queue_cfg.max_depth = depth.max(1);
        self
    }

    /// Per-tenant admission quota: one tenant may hold at most `quota`
    /// waiting requests per shard; beyond it that tenant (and only that
    /// tenant) sheds. Default: no per-tenant cap.
    pub fn tenant_quota(mut self, quota: usize) -> Self {
        self.queue_cfg.tenant_quota = Some(quota.max(1));
        self
    }

    /// Shared PE-area budget across *all* hosted models: each model is
    /// charged its largest installed plan's `total_area` times its
    /// replica count, and `install_plan` shrinks the fleet to fit or
    /// refuses plans that cannot (docs/operations.md). Default: no
    /// budget.
    pub fn area_budget(mut self, budget: f64) -> Self {
        self.area_budget = Some(budget);
        self
    }

    /// Add an artifact-backed model shard (requires `make artifacts`).
    pub fn model(mut self, name: &str) -> Self {
        self.models.push(ModelSpec {
            name: name.to_string(),
            local: None,
            act_scales: Vec::new(),
            input_dims: vec![16, 16, 3],
            replicas: 1,
        });
        self
    }

    /// Add a shard around an in-process model — no artifacts required.
    /// Only native variants (`plan:<name>`, `native_fp32`, `fp32`) are
    /// servable unless artifacts are also present.
    pub fn model_local(mut self, model: LoadedModel) -> Self {
        self.models.push(ModelSpec {
            name: model.name.clone(),
            local: Some(model),
            act_scales: Vec::new(),
            input_dims: vec![16, 16, 3],
            replicas: 1,
        });
        self
    }

    /// Replica count for the most recently added model (default 1):
    /// that many worker threads pull batches from the shard's queue.
    /// Calling this before any `model`/`model_local`, or with 0, is a
    /// build-time error, not a silent no-op.
    pub fn replicas(mut self, n: usize) -> Self {
        if n == 0 {
            self.misuse
                .get_or_insert_with(|| "replicas(0): a model needs at least one".to_string());
            return self;
        }
        match self.models.last_mut() {
            Some(m) => m.replicas = n,
            None => {
                self.misuse
                    .get_or_insert_with(|| "replicas() called before any model".to_string());
            }
        }
        self
    }

    /// Activation scales (per enc point) for the most recently added
    /// model — used by HLO-quantized variants. Calling this before any
    /// `model`/`model_local` is a build-time error, not a silent no-op.
    pub fn act_scales(mut self, scales: Vec<f32>) -> Self {
        match self.models.last_mut() {
            Some(m) => m.act_scales = scales,
            None => {
                self.misuse
                    .get_or_insert_with(|| "act_scales() called before any model".to_string());
            }
        }
        self
    }

    /// Expected request image shape for the most recently added model
    /// (default `[16, 16, 3]`); submits with other shapes fail fast.
    /// Calling this before any `model`/`model_local` is a build-time
    /// error, not a silent no-op.
    pub fn input_dims(mut self, dims: &[usize]) -> Self {
        match self.models.last_mut() {
            Some(m) => m.input_dims = dims.to_vec(),
            None => {
                self.misuse
                    .get_or_insert_with(|| "input_dims() called before any model".to_string());
            }
        }
        self
    }

    /// Spawn the replica fleet for every registered model.
    pub fn build(self) -> Result<Coordinator> {
        let ServerBuilder {
            policy,
            seed,
            models,
            queue_cfg,
            area_budget,
            misuse,
        } = self;
        if let Some(m) = misuse {
            anyhow::bail!("ServerBuilder misuse: {m}");
        }
        anyhow::ensure!(!models.is_empty(), "ServerBuilder needs at least one model");
        let arts_root = Artifacts::locate().ok().map(|a| a.root);

        // validate every spec BEFORE spawning any worker, so a failed
        // build never leaves orphaned replica threads behind
        let probe = match &arts_root {
            Some(r) => Some(Artifacts::open(r)?),
            None => None,
        };
        let art_models: Vec<String> = probe.as_ref().map(|a| a.model_names()).unwrap_or_default();
        let mut seen: HashSet<String> = HashSet::new();
        for spec in &models {
            anyhow::ensure!(
                seen.insert(spec.name.clone()),
                "duplicate model {:?} in builder",
                spec.name
            );
            anyhow::ensure!(
                spec.local.is_some() || art_models.iter().any(|n| n == &spec.name),
                "model {:?} is not in the artifact manifest and no in-process \
                 model was given (ServerBuilder::model_local)",
                spec.name
            );
        }

        // one PE-area ledger shared by every shard (cross-shard placement)
        let area = Arc::new(Mutex::new(AreaLedger {
            budget: area_budget,
            usage: BTreeMap::new(),
        }));

        let mut shards: Vec<Arc<Shard>> = Vec::with_capacity(models.len());
        for (i, spec) in models.into_iter().enumerate() {
            let compiled: HashSet<String> = probe
                .as_ref()
                .map(|a| {
                    a.hlo_entries()
                        .into_iter()
                        .filter(|(m, _, _, _)| m == &spec.name)
                        .map(|(_, v, _, _)| v)
                        .collect()
                })
                .unwrap_or_default();
            let queue = Arc::new(SubmitQueue::new(queue_cfg));
            let metrics = shared();
            let bandit: SharedBandit = Arc::new(Mutex::new(None));
            let ring = Ring::new(TRACE_RING_CAPACITY);
            let obs = Registry::new();
            let plan_map: SharedPlans = Arc::new(Mutex::new(HashMap::new()));
            let replicas = Arc::new(ReplicaSet {
                target: AtomicUsize::new(spec.replicas),
                alive: AtomicUsize::new(0),
                next_id: AtomicUsize::new(0),
            });
            // plan-independent abstract weight bounds for the static
            // certification gate, extracted before the model is shared
            // out to the replicas (artifact-backed shards have no
            // in-process engine and skip that gate)
            let bounds = spec
                .local
                .as_ref()
                .and_then(|m| crate::analysis::absint::GraphBounds::from_model(m).ok())
                .map(Arc::new);
            let ctx = ReplicaCtx {
                model_name: spec.name.clone(),
                policy,
                arts_root: arts_root.clone(),
                act_scales: spec.act_scales.clone(),
                local: spec.local.map(Arc::new),
                queue: queue.clone(),
                plan_map: plan_map.clone(),
                metrics: metrics.clone(),
                bandit: bandit.clone(),
                ring: ring.clone(),
                obs: obs.clone(),
                replicas: replicas.clone(),
                fault: Arc::new(Mutex::new(None)),
            };
            let workers = Mutex::new(Vec::new());
            for _ in 0..spec.replicas {
                spawn_replica(ctx.clone(), &workers)?;
            }
            shards.push(Arc::new(Shard {
                name: spec.name,
                input_dims: spec.input_dims,
                compiled,
                queue,
                ctx,
                workers,
                replicas,
                metrics,
                ring,
                obs,
                plan_map,
                plans: Mutex::new(HashMap::new()),
                split: Mutex::new(None),
                bandit,
                rng: Mutex::new(Rng::new(seed ^ (0x51AB_D001u64 + i as u64))),
                bounds,
                area: area.clone(),
            }));
        }
        Ok(Coordinator { shards })
    }
}

/// Replica fleet bookkeeping for one shard. `target` is what the
/// operator asked for; `alive` is what is actually pulling work (a
/// panicked replica decrements it and is *not* auto-respawned —
/// fail-stop; [`ModelHandle::set_replicas`] relaunches capacity).
struct ReplicaSet {
    target: AtomicUsize,
    alive: AtomicUsize,
    next_id: AtomicUsize,
}

/// Everything a new replica thread needs — cloneable so
/// [`ModelHandle::set_replicas`] can spawn more after build.
#[derive(Clone)]
struct ReplicaCtx {
    model_name: String,
    policy: BatchPolicy,
    arts_root: Option<PathBuf>,
    act_scales: Vec<f32>,
    /// In-process model, shared by every replica of the shard (the
    /// engine's internal caches are mutex-guarded).
    local: Option<Arc<LoadedModel>>,
    queue: Arc<SubmitQueue<InferRequest>>,
    plan_map: SharedPlans,
    metrics: SharedMetrics,
    bandit: SharedBandit,
    ring: Arc<Ring>,
    obs: Arc<Registry>,
    replicas: Arc<ReplicaSet>,
    fault: SharedFault,
}

/// Cross-shard PE-area ledger: each model's charge is its largest
/// installed plan's `total_area` times its replica count.
struct AreaLedger {
    budget: Option<f64>,
    usage: BTreeMap<String, f64>,
}

impl AreaLedger {
    /// Area charged by every model except `name`.
    fn others(&self, name: &str) -> f64 {
        self.usage
            .iter()
            .filter(|(m, _)| m.as_str() != name)
            .map(|(_, c)| *c)
            .sum()
    }
}

/// Client-side state for one model shard. The native engine is always
/// servable: `ServerBuilder::build` refuses models that are neither
/// in-process nor loadable from the artifact manifest.
struct Shard {
    name: String,
    input_dims: Vec<usize>,
    /// HLO variant names present in the artifact manifest for this model.
    compiled: HashSet<String>,
    /// The bounded submission queue every replica pulls from.
    queue: Arc<SubmitQueue<InferRequest>>,
    /// Template for spawning more replicas after build.
    ctx: ReplicaCtx,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    replicas: Arc<ReplicaSet>,
    metrics: SharedMetrics,
    /// Per-shard trace ring; disabled (one relaxed atomic load per span
    /// site) until [`ModelHandle::set_tracing`] turns it on.
    ring: Arc<Ring>,
    /// Per-shard OverQ coverage/drift counters, fed by the workers'
    /// quantized forward passes and the plans' stored drift baselines.
    obs: Arc<Registry>,
    /// Published plan bodies, read by replicas at batch execution.
    plan_map: SharedPlans,
    /// Registered plan aliases → plan `total_area` — the submit-time
    /// fail-fast view. An alias lands here strictly *after* its body
    /// lands in `plan_map`, so any submit passing the fail-fast check
    /// finds the plan (model-checked publication protocol,
    /// `rust/tests/model_check.rs`).
    plans: Mutex<HashMap<String, f64>>,
    /// Installed A/B traffic split, if any.
    split: Mutex<Option<Vec<(VariantSpec, f64)>>>,
    /// Outcome-aware router, if installed; shared with the workers for
    /// reward feedback. Takes precedence over `split` for routed
    /// submits.
    bandit: SharedBandit,
    /// Seeded router state for deterministic weighted arm picks.
    rng: Mutex<Rng>,
    /// Abstract weight bounds of the in-process engine, for the static
    /// certification gate on `install_plan` (`None` for artifact-backed
    /// shards, which skip that gate).
    bounds: Option<Arc<crate::analysis::absint::GraphBounds>>,
    /// Cross-shard PE-area ledger (shared by all shards of the
    /// coordinator).
    area: Arc<Mutex<AreaLedger>>,
}

/// Handle to a running multi-model coordinator. Owns the replica
/// threads of every model shard; dropping it (or calling
/// [`Coordinator::shutdown`]) closes the queues, drains the admitted
/// backlog and joins the workers.
pub struct Coordinator {
    shards: Vec<Arc<Shard>>,
}

impl Coordinator {
    /// Entry point: `Coordinator::builder().model(...).build()`.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// Cheap handle to one hosted model.
    pub fn model(&self, name: &str) -> Result<ModelHandle> {
        self.shards
            .iter()
            .find(|s| s.name == name)
            .map(|s| ModelHandle { shard: s.clone() })
            .with_context(|| {
                format!(
                    "coordinator hosts no model {name:?} (available: {:?})",
                    self.model_names()
                )
            })
    }

    /// Names of the hosted models, in registration order.
    pub fn model_names(&self) -> Vec<String> {
        self.shards.iter().map(|s| s.name.clone()).collect()
    }

    /// Graceful shutdown: close every queue and join the workers.
    /// In-flight requests are drained, not dropped.
    pub fn shutdown(self) {
        // Drop does the work; this is the explicit spelling.
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for s in &self.shards {
            s.queue.close();
        }
        for s in &self.shards {
            let handles = std::mem::take(&mut *lock(&s.workers));
            for w in handles {
                let _ = w.join();
            }
        }
    }
}

/// Register one more replica thread pulling from the shard queue.
fn spawn_replica(
    ctx: ReplicaCtx,
    workers: &Mutex<Vec<std::thread::JoinHandle<()>>>,
) -> Result<()> {
    let id = ctx.replicas.next_id.fetch_add(1, Ordering::SeqCst);
    ctx.replicas.alive.fetch_add(1, Ordering::SeqCst);
    let name = format!("overq-{}-r{id}", ctx.model_name);
    let replicas = ctx.replicas.clone();
    match std::thread::Builder::new()
        .name(name)
        .spawn(move || replica_loop(id, ctx))
    {
        Ok(h) => {
            lock(workers).push(h);
            Ok(())
        }
        Err(e) => {
            replicas.alive.fetch_sub(1, Ordering::SeqCst);
            Err(e).context("spawn shard replica")
        }
    }
}

/// Cheap, cloneable per-model handle: the request plane (`submit`,
/// `infer`, `infer_routed`) plus the admin plane (`register_plan`,
/// `swap_plan`, `set_traffic_split`, `set_replicas`, `metrics`).
#[derive(Clone)]
pub struct ModelHandle {
    shard: Arc<Shard>,
}

impl ModelHandle {
    /// The model this handle targets.
    pub fn model_name(&self) -> &str {
        &self.shard.name
    }

    /// Validate a non-split spec against what this shard can serve.
    fn check_leaf(&self, leaf: &VariantSpec) -> Result<()> {
        match leaf {
            VariantSpec::Split(_) => {
                anyhow::bail!("nested traffic splits are not supported")
            }
            VariantSpec::Plan(name) => {
                anyhow::ensure!(
                    lock(&self.shard.plans).contains_key(name),
                    "no registered plan {name:?} on model {:?}",
                    self.shard.name
                );
            }
            VariantSpec::Compiled(name) => {
                anyhow::ensure!(
                    self.shard.compiled.contains(name),
                    "unknown variant {name:?} for model {:?}: no compiled artifact \
                     (and it is not a plan/fp32 variant)",
                    self.shard.name
                );
                anyhow::ensure!(
                    cfg!(feature = "pjrt"),
                    "variant {name:?} needs the compiled (PJRT) backend, but this \
                     binary was built without the `pjrt` feature",
                );
            }
            VariantSpec::Fp32 { backend } => {
                // the native engine is always available (build() refuses
                // shards without it), so only the pinned-PJRT path can fail
                if matches!(backend, Backend::Pjrt) {
                    anyhow::ensure!(
                        self.shard.compiled.contains("fp32") && cfg!(feature = "pjrt"),
                        "pjrt_fp32 unavailable for model {:?}: needs an fp32 HLO \
                         artifact and the `pjrt` feature",
                        self.shard.name
                    );
                }
            }
        }
        Ok(())
    }

    /// Draw one split arm with the deterministic seeded router. The
    /// arms must already satisfy [`VariantSpec::validate_split`] —
    /// callers validate once at install (`set_traffic_split_spec`) or
    /// per hand-built spec (`submit`).
    fn draw_arm(&self, arms: &[(VariantSpec, f64)]) -> VariantSpec {
        let weights: Vec<f64> = arms.iter().map(|(_, w)| *w).collect();
        let i = pick_weighted(&mut lock(&self.shard.rng), &weights);
        arms[i].0.clone()
    }

    /// Validate shape + leaf, then run admission control: push into the
    /// bounded shard queue or shed. A shed comes back as a typed
    /// [`ServeError`] inside the `anyhow` error
    /// (`err.downcast_ref::<ServeError>()`), and is counted in the
    /// shard metrics before this returns.
    fn submit_leaf(
        &self,
        image: TensorF,
        leaf: VariantSpec,
        opts: &SubmitOpts,
    ) -> Result<Receiver<InferResult>> {
        anyhow::ensure!(
            image.dims() == &self.shard.input_dims[..],
            "request image shape {:?} != model {:?} input shape {:?}",
            image.dims(),
            self.shard.name,
            self.shard.input_dims
        );
        self.check_leaf(&leaf)?;
        if self.shard.replicas.alive.load(Ordering::SeqCst) == 0 {
            // all replicas fail-stopped (or never started); refuse
            // rather than queue a request no one will execute
            return Err(anyhow::Error::new(ServeError::Stopped))
                .with_context(|| format!("model {:?} has no live replica", self.shard.name));
        }
        let tenant = opts.tenant.clone().unwrap_or_else(|| "default".to_string());
        let (rtx, rrx) = sync_channel(1);
        let req = InferRequest {
            image,
            group: leaf.key(),
            spec: leaf,
            tenant,
            deadline: opts.deadline.map(|d| Instant::now() + d),
            submitted: Instant::now(),
            resp: rtx,
        };
        match self.shard.queue.push(req) {
            Ok(_depth) => {
                lock(&self.shard.metrics).record_admitted(
                    opts.tenant.as_deref().unwrap_or("default"),
                );
                // fail-stop race: the last replica may have died (and
                // finished its orphan drain) between the alive check
                // above and this push — re-check and drain the backlog
                // ourselves so no admitted request is left in a queue
                // nobody reads. Both drains may run; each request still
                // gets exactly one reply because the queue pops once.
                if self.shard.replicas.alive.load(Ordering::SeqCst) == 0 {
                    drain_orphaned(&self.shard.ctx);
                }
                Ok(rrx)
            }
            Err(PushError::Shed { item, reason }) => {
                lock(&self.shard.metrics).record_shed(&item.tenant, &reason);
                self.shard
                    .ring
                    .record_now("shed", format!("tenant={} reason={reason}", item.tenant));
                Err(anyhow::Error::new(ServeError::Shed(reason)))
            }
            Err(PushError::Closed { .. }) => Err(anyhow::Error::new(ServeError::Stopped)),
        }
    }

    /// Submit one request without blocking; returns the response channel.
    /// Splits take one deterministic weighted draw from the shard
    /// router; unknown variants and wrong image shapes fail fast.
    pub fn submit(&self, image: TensorF, spec: &VariantSpec) -> Result<Receiver<InferResult>> {
        self.submit_opts(image, spec, &SubmitOpts::default())
    }

    /// [`ModelHandle::submit`] with per-request tenant/deadline options.
    pub fn submit_opts(
        &self,
        image: TensorF,
        spec: &VariantSpec,
        opts: &SubmitOpts,
    ) -> Result<Receiver<InferResult>> {
        let leaf = match spec {
            VariantSpec::Split(arms) => {
                // hand-built Split values bypass the parse/split
                // constructors, so enforce the invariants here
                VariantSpec::validate_split(arms)?;
                self.draw_arm(arms)
            }
            other => other.clone(),
        };
        self.submit_leaf(image, leaf, opts)
    }

    /// [`ModelHandle::submit`] with a string variant (parsed first).
    pub fn submit_variant(&self, image: TensorF, variant: &str) -> Result<Receiver<InferResult>> {
        self.submit(image, &VariantSpec::parse(variant)?)
    }

    /// Submit one request and block for its response.
    pub fn infer(&self, image: TensorF, spec: &VariantSpec) -> Result<InferResponse> {
        let rx = self.submit(image, spec)?;
        rx.recv()
            .context("worker dropped the response")?
            .map_err(anyhow::Error::new)
    }

    /// [`ModelHandle::infer`] with a string variant (parsed first).
    pub fn infer_variant(&self, image: TensorF, variant: &str) -> Result<InferResponse> {
        self.infer(image, &VariantSpec::parse(variant)?)
    }

    /// Submit through the installed routing policy: the bandit router
    /// when one is installed ([`ModelHandle::set_routing_policy`]), else
    /// the fixed traffic split ([`ModelHandle::set_traffic_split`]),
    /// else `fp32`.
    pub fn submit_routed(&self, image: TensorF) -> Result<Receiver<InferResult>> {
        self.submit_routed_opts(image, &SubmitOpts::default())
    }

    /// [`ModelHandle::submit_routed`] with per-request tenant/deadline
    /// options.
    pub fn submit_routed_opts(
        &self,
        image: TensorF,
        opts: &SubmitOpts,
    ) -> Result<Receiver<InferResult>> {
        let t0 = self.shard.ring.enabled().then(Instant::now);
        let bandit_leaf = lock(&self.shard.bandit).as_mut().map(|b| b.pick());
        let leaf = match bandit_leaf {
            Some(leaf) => leaf,
            None => {
                let split = lock(&self.shard.split);
                match &*split {
                    // validated when installed by set_traffic_split_spec
                    Some(arms) => self.draw_arm(arms),
                    None => VariantSpec::Fp32 {
                        backend: Backend::Auto,
                    },
                }
            }
        };
        if let Some(t0) = t0 {
            let d = format!("variant={}", leaf.key());
            self.shard.ring.record("route", d, t0, Instant::now());
        }
        self.submit_leaf(image, leaf, opts)
    }

    /// Blocking version of [`ModelHandle::submit_routed`].
    pub fn infer_routed(&self, image: TensorF) -> Result<InferResponse> {
        let rx = self.submit_routed(image)?;
        rx.recv()
            .context("worker dropped the response")?
            .map_err(anyhow::Error::new)
    }

    /// Scale this model's replica fleet to `n` worker threads. Scaling
    /// up spawns enough replicas to bring the *live* count to `n` (so
    /// it also replaces fail-stopped replicas); scaling down retires
    /// the excess as soon as they finish their current batch. Checked
    /// against the PE-area budget when one is set.
    pub fn set_replicas(&self, n: usize) -> Result<()> {
        anyhow::ensure!(n >= 1, "model {:?} needs at least one replica", self.shard.name);
        // area-budget re-check: the fleet's charge scales with n
        let max_area = lock(&self.shard.plans)
            .values()
            .fold(0.0f64, |m, &a| m.max(a));
        {
            let mut ledger = lock(&self.shard.area);
            let need = max_area * n as f64;
            if let Some(budget) = ledger.budget {
                let others = ledger.others(&self.shard.name);
                anyhow::ensure!(
                    others + need <= budget + 1e-9,
                    "cannot scale model {:?} to {n} replicas: needs {:.0} PE-area but \
                     only {:.0} of budget {:.0} is free",
                    self.shard.name,
                    need,
                    (budget - others).max(0.0),
                    budget
                );
            }
            ledger.usage.insert(self.shard.name.clone(), need);
        }
        let before = self.shard.replicas.target.swap(n, Ordering::SeqCst);
        let alive = self.shard.replicas.alive.load(Ordering::SeqCst);
        if alive < n {
            for _ in 0..(n - alive) {
                spawn_replica(self.shard.ctx.clone(), &self.shard.workers)?;
            }
        } else if n < before {
            // excess replicas see the new target on their next wake
            self.shard.queue.kick();
        }
        Ok(())
    }

    /// (target, alive) replica counts for this model's fleet.
    pub fn replica_counts(&self) -> (usize, usize) {
        (
            self.shard.replicas.target.load(Ordering::SeqCst),
            self.shard.replicas.alive.load(Ordering::SeqCst),
        )
    }

    /// Arm a test-only replica fault: the next replica to pick up a
    /// batch trips it (see [`ReplicaFault`]). Used by the
    /// fault-injection tests to prove failure isolation; never called
    /// in production paths.
    pub fn inject_replica_fault(&self, fault: ReplicaFault) {
        *lock(&self.shard.ctx.fault) = Some(fault);
    }

    /// Install (or replace) a deployment plan under its own name;
    /// requests may then target `plan:<plan.name>`. Ordered with respect
    /// to this handle's later `submit`s.
    pub fn register_plan(&self, plan: DeploymentPlan) -> Result<()> {
        let alias = plan.name.clone();
        self.install_plan(alias, plan)
    }

    /// Hot-swap: requests targeting `plan:<alias>` switch to `plan`
    /// without clients changing their variant strings and without
    /// dropping in-flight requests (they run on whichever plan the
    /// shard publishes when their batch executes).
    pub fn swap_plan(&self, alias: &str, plan: DeploymentPlan) -> Result<()> {
        anyhow::ensure!(!alias.is_empty(), "plan alias must be non-empty");
        self.install_plan(alias.to_string(), plan)
    }

    fn install_plan(&self, alias: String, plan: DeploymentPlan) -> Result<()> {
        anyhow::ensure!(
            plan.model == self.shard.name,
            "plan {:?} was tuned for model {:?}, this shard serves {:?}",
            plan.name,
            plan.model,
            self.shard.name
        );
        // static analysis gate: Error-level lint findings make a plan
        // unservable, so refuse before anything is published. Warnings
        // (area drift etc.) serve fine — `overq lint` is where they gate.
        let report = crate::analysis::lint_plan(&plan);
        if let Some(d) = report.first_error() {
            anyhow::bail!("plan {:?} failed lint: {d}", plan.name);
        }
        // second static gate: abstract interpretation over the model
        // graph (`analysis::absint`). A plan whose scales provably
        // saturate the cascade capacity on every input (OQ020) is
        // refused before anything is published; warnings pass, same
        // contract as lint. Covers `register_plan`, `swap_plan` and the
        // `PlanWatch` hot-reload path, which all land here.
        if let Some(gb) = &self.shard.bounds {
            let cert = crate::analysis::absint::verify_plan_with_bounds(
                gb,
                &plan,
                crate::analysis::absint::DEFAULT_INPUT_RANGE,
                &crate::analysis::absint::AbsintConfig::default(),
            );
            if let Some(d) = cert.report.first_error() {
                anyhow::bail!("plan {:?} failed static certification: {d}", plan.name);
            }
        }
        // placement gate: charge this model's fleet (its largest plan ×
        // replica count) against the shared PE-area budget; shrink the
        // fleet to fit, or refuse the plan when even one replica won't
        let area = plan.total_area;
        {
            let max_area = lock(&self.shard.plans)
                .values()
                .fold(area, |m, &a| m.max(a));
            let mut ledger = lock(&self.shard.area);
            let target = self.shard.replicas.target.load(Ordering::SeqCst).max(1);
            if let Some(budget) = ledger.budget {
                let others = ledger.others(&self.shard.name);
                let need = max_area * target as f64;
                if others + need > budget + 1e-9 {
                    let headroom = (budget - others).max(0.0);
                    let fit = if max_area > 0.0 {
                        ((headroom + 1e-9) / max_area) as usize
                    } else {
                        target
                    };
                    anyhow::ensure!(
                        fit >= 1,
                        "plan {:?} refused: needs {:.0} PE-area but only {:.0} of \
                         budget {:.0} is free (co-hosted models hold the rest); \
                         raise the budget or retire a model",
                        alias,
                        max_area,
                        headroom,
                        budget
                    );
                    // relocate: shrink this model's fleet so the
                    // co-hosted set stays under budget
                    self.shard.replicas.target.store(fit, Ordering::SeqCst);
                    self.shard.queue.kick();
                    ledger
                        .usage
                        .insert(self.shard.name.clone(), max_area * fit as f64);
                    self.shard.ring.record_now(
                        "area_relocate",
                        format!("plan={alias} replicas={fit} area={max_area:.0}"),
                    );
                    eprintln!(
                        "[coordinator] area budget {budget:.0}: model {:?} scaled to \
                         {fit} replica(s) to fit plan {:?} ({max_area:.0} PE-area each)",
                        self.shard.name, alias
                    );
                } else {
                    ledger.usage.insert(self.shard.name.clone(), need);
                }
            } else {
                ledger
                    .usage
                    .insert(self.shard.name.clone(), max_area * target as f64);
            }
        }
        // publish the plan's profile-time drift baselines before the
        // install becomes visible, so coverage snapshots can compare
        // live activation stats from the first request onward
        let drift = plan.layers.iter().map(|l| l.drift).collect();
        self.shard.obs.set_baselines(&format!("plan:{alias}"), drift);
        // publication order is the correctness invariant here: the plan
        // body lands in the shared plan map FIRST, the alias becomes
        // submit-visible SECOND. Any submit that passes the fail-fast
        // alias check therefore finds the body when its batch executes
        // (model-checked: rust/tests/model_check.rs).
        lock(&self.shard.plan_map).insert(alias.clone(), Arc::new(plan));
        lock(&self.shard.plans).insert(alias, area);
        Ok(())
    }

    /// Install a weighted A/B split, e.g.
    /// `handle.set_traffic_split(&[("plan:a", 0.9), ("plan:b", 0.1)])`.
    /// Every arm is validated against this shard; requests submitted via
    /// [`ModelHandle::submit_routed`] then draw arms from the seeded
    /// router, so the arm sequence is reproducible run-to-run.
    pub fn set_traffic_split(&self, split: &[(&str, f64)]) -> Result<()> {
        self.set_traffic_split_spec(&VariantSpec::split(split)?)
    }

    /// [`ModelHandle::set_traffic_split`] for an already-parsed
    /// [`VariantSpec::Split`] (e.g. straight from `VariantSpec::parse`).
    pub fn set_traffic_split_spec(&self, spec: &VariantSpec) -> Result<()> {
        let VariantSpec::Split(arms) = spec else {
            anyhow::bail!("set_traffic_split needs a split variant, got {spec}")
        };
        VariantSpec::validate_split(arms)?;
        for (arm, _) in arms {
            self.check_leaf(arm)?;
        }
        *lock(&self.shard.split) = Some(arms.clone());
        Ok(())
    }

    /// The currently installed traffic split, if any.
    pub fn traffic_split(&self) -> Option<Vec<(VariantSpec, f64)>> {
        lock(&self.shard.split).clone()
    }

    /// Install the routing policy behind [`ModelHandle::submit_routed`].
    ///
    /// `Bandit` validates every arm against this shard (same fail-fast
    /// contract as [`ModelHandle::set_traffic_split`]), builds the
    /// seeded [`BanditRouter`], and pins its control arm as the metrics
    /// regret reference. `Fixed` tears the bandit down again; the plain
    /// traffic split (if any) takes back over. In-flight requests are
    /// unaffected either way — the policy only decides future submits.
    pub fn set_routing_policy(&self, policy: RoutingPolicy) -> Result<()> {
        match policy {
            RoutingPolicy::Fixed => {
                *lock(&self.shard.bandit) = None;
                lock(&self.shard.metrics).control_arm = None;
            }
            RoutingPolicy::Bandit(cfg) => {
                for (arm, _) in &cfg.arms {
                    if !arm.is_split() {
                        self.check_leaf(arm)?;
                    }
                }
                // rejects splits, duplicate arms, bad floors/priors
                let router = BanditRouter::new(cfg)?;
                let control = router.control_key().to_string();
                *lock(&self.shard.bandit) = Some(router);
                lock(&self.shard.metrics).control_arm = Some(control);
            }
        }
        Ok(())
    }

    /// Per-arm bandit statistics (pulls, mean reward, control pin), or
    /// `None` under fixed routing.
    pub fn bandit_arms(&self) -> Option<Vec<ArmStats>> {
        lock(&self.shard.bandit).as_ref().map(|b| b.arm_stats())
    }

    /// Watch `dir` for new/changed `*.plan.json` files and hot-swap
    /// matching plans through the admin plane every `interval`
    /// (docs/operations.md has the full lifecycle). Plan files already
    /// on disk are applied synchronously before this returns, so their
    /// `plan:<name>` variants are immediately servable. Rejected files
    /// leave the previously served plan untouched and are surfaced via
    /// [`MetricsSnapshot::watch_errors`]. Dropping the returned
    /// [`watch::PlanWatcher`] stops the background poller.
    pub fn watch_plans(
        &self,
        dir: impl AsRef<Path>,
        interval: Duration,
    ) -> Result<watch::PlanWatcher> {
        let mut w = watch::PlanWatch::new(self.clone(), dir)?;
        let _ = w.poll();
        Ok(watch::spawn(w, interval))
    }

    /// Metrics hook for the plan watcher: one applied swap.
    pub(crate) fn note_plan_swap(&self) {
        lock(&self.shard.metrics).record_plan_swap();
    }

    /// Metrics hook for the plan watcher: one rejected plan file.
    pub(crate) fn note_watch_error(&self, msg: &str) {
        eprintln!("[coordinator] plan watch: {msg}");
        lock(&self.shard.metrics).record_watch_error(msg);
    }

    /// Point-in-time metrics for this shard (global + per-variant),
    /// with the live queue/replica gauges filled in.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = lock(&self.shard.metrics).snapshot();
        snap.queue_depth = self.shard.queue.depth();
        snap.queue_peak_depth = self.shard.queue.peak_depth();
        snap.replicas_target = self.shard.replicas.target.load(Ordering::SeqCst);
        snap.replicas_alive = self.shard.replicas.alive.load(Ordering::SeqCst);
        snap
    }

    /// Zero this shard's metrics and OverQ coverage counters — e.g. to
    /// exclude warmup traffic from a measurement window, or between A/B
    /// experiment epochs. Requests already in the queue still count
    /// when they execute. Configuration and lifecycle state survive:
    /// the control-arm pin, the plan-watcher health counters
    /// (`plan_swaps` / `watch_errors` / `last_watch_error`), the
    /// replica-failure count, and the plans' stored drift baselines.
    pub fn reset_metrics(&self) {
        lock(&self.shard.metrics).reset();
        self.shard.obs.reset();
    }

    /// Turn request tracing for this shard on or off. While off a span
    /// site costs one relaxed atomic load; buffered events survive a
    /// disable and wait for [`ModelHandle::drain_events`].
    pub fn set_tracing(&self, on: bool) {
        self.shard.ring.set_enabled(on);
    }

    /// Drain this shard's buffered trace events, oldest first. `overq
    /// trace` renders them as JSONL
    /// ([`crate::obs::span::events_jsonl`]).
    pub fn drain_events(&self) -> Vec<Event> {
        self.shard.ring.drain()
    }

    /// Trace events dropped to the ring bound so far (process
    /// lifetime; exported as `overq_trace_dropped_total`).
    pub fn trace_dropped(&self) -> u64 {
        self.shard.ring.dropped()
    }

    /// Point-in-time OverQ coverage/drift counters for this shard, one
    /// entry per observed variant, sorted by variant key.
    pub fn obs_snapshot(&self) -> Vec<VariantObsSnapshot> {
        self.shard.obs.snapshot()
    }

    /// Prometheus text exposition of this shard's serving metrics plus
    /// the OverQ coverage counters — the body served by `overq serve
    /// --telemetry-addr` under `/metrics` (docs/observability.md).
    pub fn prometheus(&self) -> String {
        let snap = self.metrics();
        snap.render_prometheus(&self.obs_snapshot(), self.trace_dropped())
    }

    /// One JSON document with serving metrics, per-variant coverage and
    /// trace health — what `overq stats` tabulates and the telemetry
    /// listener serves under `/snapshot.json`.
    pub fn stats_json(&self) -> crate::util::json::Value {
        let snap = self.metrics();
        snap.stats_json(&self.obs_snapshot(), self.trace_dropped())
    }

    /// Warm a variant: trigger compilation of every batch size by
    /// pushing enough dummy requests to hit the largest executable.
    /// Returns the wall time spent (the one-time compile cost).
    pub fn warmup(&self, spec: &VariantSpec, max_batch: usize) -> Result<Duration> {
        let dims = self.shard.input_dims.clone();
        let t0 = Instant::now();
        // single request exercises the b1 executable (if present)
        let _ = self.infer(TensorF::zeros(&dims), spec)?;
        // a burst exercises the batched executable
        let burst: Vec<_> = (0..max_batch)
            .map(|_| self.submit(TensorF::zeros(&dims), spec))
            .collect::<Result<_>>()?;
        for rx in burst {
            rx.recv()
                .context("warmup response lost")?
                .map_err(anyhow::Error::new)?;
        }
        Ok(t0.elapsed())
    }
}

/// Replica-local execution state (engine handle, executable cache).
/// Everything shared lives in [`ReplicaCtx`].
struct WorkerState {
    model_name: String,
    arts: Option<Artifacts>,
    cache: ExecutableCache,
    native: Option<Arc<LoadedModel>>,
    plan_map: SharedPlans,
    scales: TensorF,
    metrics: SharedMetrics,
    bandit: SharedBandit,
    ring: Arc<Ring>,
    obs: Arc<Registry>,
}

impl WorkerState {
    fn new(ctx: &ReplicaCtx) -> Result<WorkerState> {
        let arts = match &ctx.arts_root {
            Some(r) => Some(Artifacts::open(r)?),
            None => None,
        };
        let cache = match &arts {
            Some(a) => ExecutableCache::new(a)?,
            None => ExecutableCache::empty(),
        };
        Ok(WorkerState {
            model_name: ctx.model_name.clone(),
            arts,
            cache,
            native: ctx.local.clone(),
            plan_map: ctx.plan_map.clone(),
            scales: TensorF::from_vec(&[ctx.act_scales.len()], ctx.act_scales.clone()),
            metrics: ctx.metrics.clone(),
            bandit: ctx.bandit.clone(),
            ring: ctx.ring.clone(),
            obs: ctx.obs.clone(),
        })
    }
}

/// One replica worker: pull batches from the shard queue until the
/// queue closes, the replica is retired (scale-down), or it fail-stops
/// on a panic.
fn replica_loop(id: usize, ctx: ReplicaCtx) {
    let mut st = match WorkerState::new(&ctx) {
        Ok(st) => st,
        Err(e) => {
            eprintln!("[coordinator] replica {id} of {:?} failed to start: {e:#}", ctx.model_name);
            ctx.replicas.alive.fetch_sub(1, Ordering::SeqCst);
            return;
        }
    };
    loop {
        if try_retire(&ctx.replicas) {
            return; // scale-down: excess replica exits cleanly
        }
        match ctx.queue.next_batch(&ctx.policy) {
            Drained::Done => {
                ctx.replicas.alive.fetch_sub(1, Ordering::SeqCst);
                return;
            }
            Drained::Idle => continue,
            Drained::Work { batch, expired } => {
                reply_expired(&ctx, expired);
                if batch.is_empty() {
                    continue;
                }
                // test-only fault hook: the armed fault trips on the
                // next batch pickup, whichever replica that is
                let fault = lock(&ctx.fault).take();
                if let Some(ReplicaFault::StallNextBatch(d)) = &fault {
                    std::thread::sleep(*d);
                }
                let panic_now = matches!(fault, Some(ReplicaFault::PanicNextBatch));
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if panic_now {
                        panic!("injected replica fault (test hook)");
                    }
                    run_group(&mut st, &batch)
                }));
                match outcome {
                    Ok(Ok(())) => {
                        lock(&ctx.metrics).record_replica_batch(id);
                    }
                    Ok(Err(e)) => {
                        // per-batch failure (missing artifact, backend
                        // error): reply to every request and keep serving
                        let msg = format!("{e:#}");
                        for req in &batch {
                            let _ = req.resp.try_send(Err(ServeError::Backend(msg.clone())));
                        }
                    }
                    Err(p) => {
                        // fail-stop: error out the in-flight batch, mark
                        // this replica dead and stop pulling work. The
                        // surviving replicas keep draining the queue.
                        let msg = p
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "replica panicked".to_string());
                        for req in &batch {
                            let _ = req.resp.try_send(Err(ServeError::ReplicaFailed(msg.clone())));
                        }
                        lock(&ctx.metrics).record_replica_failure();
                        ctx.ring
                            .record_now("replica_death", format!("replica={id} msg={msg}"));
                        eprintln!(
                            "[coordinator] replica {id} of {:?} fail-stopped: {msg}",
                            ctx.model_name
                        );
                        let left = ctx.replicas.alive.fetch_sub(1, Ordering::SeqCst) - 1;
                        if left == 0 {
                            drain_orphaned(&ctx);
                        }
                        return;
                    }
                }
            }
        }
    }
}

/// CAS-retire this replica if the fleet is over its target (scale-down
/// or area relocation). Returns true when the caller should exit.
fn try_retire(replicas: &ReplicaSet) -> bool {
    loop {
        let alive = replicas.alive.load(Ordering::SeqCst);
        let target = replicas.target.load(Ordering::SeqCst);
        if alive <= target {
            return false;
        }
        if replicas
            .alive
            .compare_exchange(alive, alive - 1, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            return true;
        }
    }
}

/// Reply `DeadlineExceeded` to requests swept from the queue.
fn reply_expired(ctx: &ReplicaCtx, expired: Vec<InferRequest>) {
    if expired.is_empty() {
        return;
    }
    lock(&ctx.metrics).record_deadline_exceeded(expired.len());
    for req in expired {
        ctx.ring.record_now(
            "expire",
            format!("variant={} tenant={}", req.group, req.tenant),
        );
        let queued = req.submitted.elapsed();
        let _ = req.resp.try_send(Err(ServeError::DeadlineExceeded { queued }));
    }
}

/// The last live replica just died: fail the whole queued backlog with
/// `ReplicaFailed` rather than leaving clients blocked on a queue no
/// one drains. Admitted work is never silently dropped.
fn drain_orphaned(ctx: &ReplicaCtx) {
    // non-blocking pulls: a submitter's race-recovery drain can run
    // concurrently with the dying replica's, and whichever loses the
    // last pop must return, not sleep on the condvar
    let eager = BatchPolicy {
        max_batch: usize::MAX,
        max_wait: Duration::ZERO,
    };
    loop {
        match ctx.queue.try_next_batch(&eager) {
            Drained::Work { batch, expired } => {
                reply_expired(ctx, expired);
                for req in batch {
                    let _ = req.resp.try_send(Err(ServeError::ReplicaFailed(
                        "no live replica".to_string(),
                    )));
                }
            }
            Drained::Idle | Drained::Done => return,
        }
    }
}

fn run_group(st: &mut WorkerState, group: &[InferRequest]) -> Result<()> {
    match &group[0].spec {
        VariantSpec::Plan(name) => {
            let plan = lock(&st.plan_map)
                .get(name)
                .cloned()
                .with_context(|| format!("no registered plan {name:?}"))?;
            anyhow::ensure!(
                plan.model == st.model_name,
                "plan {name:?} was tuned for model {:?}, shard serves {:?}",
                plan.model,
                st.model_name
            );
            let qc = plan.to_quant_config();
            run_group_native(st, group, Some(&qc))
        }
        VariantSpec::Fp32 {
            backend: Backend::Native,
        } => run_group_native(st, group, None),
        VariantSpec::Fp32 {
            backend: Backend::Auto,
        } => {
            // fp32 prefers PJRT when it can actually run — an HLO
            // artifact exists and the binary has the `pjrt` feature —
            // and falls back to the native engine otherwise.
            let available = st.cache.batch_sizes(&st.model_name, "fp32");
            if !available.is_empty() && cfg!(feature = "pjrt") {
                run_group_pjrt(st, group, "fp32", &available)
            } else {
                run_group_native(st, group, None)
            }
        }
        VariantSpec::Fp32 {
            backend: Backend::Pjrt,
        } => {
            let available = st.cache.batch_sizes(&st.model_name, "fp32");
            run_group_pjrt(st, group, "fp32", &available)
        }
        VariantSpec::Compiled(name) => {
            let available = st.cache.batch_sizes(&st.model_name, name);
            run_group_pjrt(st, group, name, &available)
        }
        VariantSpec::Split(_) => {
            anyhow::bail!("split variants must be resolved before the worker")
        }
    }
}

/// Account one executed chunk: feed each request's e2e latency to the
/// bandit (when outcome-aware routing is on), then record the batch,
/// per-request latencies, and rewards under one metrics lock — batch
/// and request counters stay mutually consistent for snapshots. The
/// bandit and metrics locks are taken sequentially, never nested.
fn account_chunk(
    metrics: &SharedMetrics,
    bandit: &SharedBandit,
    key: &str,
    reqs: &[InferRequest],
    queue_start: Instant,
    padded: usize,
    exec: Duration,
) {
    let lats: Vec<(Duration, Duration)> = reqs
        .iter()
        .map(|r| (queue_start - r.submitted, r.submitted.elapsed()))
        .collect();
    let rewards: Vec<Option<f64>> = {
        let mut guard = lock(bandit);
        match guard.as_mut() {
            Some(b) => lats
                .iter()
                .map(|(_, e2e)| b.observe(key, e2e.as_micros() as f64))
                .collect(),
            None => vec![None; lats.len()],
        }
    };
    let mut m = lock(metrics);
    m.record_batch(reqs.len(), padded, exec);
    for ((queue, e2e), reward) in lats.iter().zip(&rewards) {
        m.record_request(key, *queue, *e2e);
        if let Some(r) = reward {
            m.record_reward(key, *r);
        }
    }
}

/// Ensure the native model is loaded (in-process handoff or artifacts).
fn native_model(st: &mut WorkerState) -> Result<Arc<LoadedModel>> {
    if st.native.is_none() {
        let arts = st
            .arts
            .as_ref()
            .context("native backend needs an in-process model or artifacts")?;
        st.native = Some(Arc::new(arts.load_model(&st.model_name)?));
    }
    Ok(st.native.as_ref().unwrap().clone())
}

fn run_group_native(
    st: &mut WorkerState,
    group: &[InferRequest],
    qc: Option<&QuantConfig>,
) -> Result<()> {
    let max_batch = group.len().max(1);
    let key = group[0].spec.key();
    let metrics = st.metrics.clone();
    let bandit = st.bandit.clone();
    let ring = st.ring.clone();
    // pin the trace ring and this variant's counter slot to the worker
    // thread, so deep engine code (forward_quant's encode sites) can
    // record spans and coverage without seeing the shard
    let _sink = span::set_sink(ring.clone());
    let _ctx = counters::set_ctx(st.obs.variant(&key));
    let model = native_model(st)?;
    if let Some(qc) = qc {
        anyhow::ensure!(
            qc.num_enc_points() >= model.engine.graph.num_enc_points(),
            "plan covers {} enc points, model {} has {}",
            qc.num_enc_points(),
            model.name,
            model.engine.graph.num_enc_points()
        );
    }
    let dims = group[0].image.dims().to_vec();
    let img_sz: usize = dims.iter().product();
    let mut done = 0;
    for take in chunks(group.len(), max_batch) {
        let mut bdims = vec![take];
        bdims.extend_from_slice(&dims);
        let mut xb = TensorF::zeros(&bdims);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            anyhow::ensure!(
                req.image.numel() == img_sz,
                "request image shape {:?} != group shape {:?}",
                req.image.dims(),
                dims
            );
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        if ring.enabled() {
            let qd = format!("variant={key}");
            for req in &group[done..done + take] {
                ring.record("queue", qd.clone(), req.submitted, queue_start);
            }
        }
        let _batch = ring.span("batch", format!("variant={key} batch={take}"));
        let t0 = Instant::now();
        let logits = {
            let _exec = ring.span("execute", format!("variant={key} batch={take}"));
            match qc {
                Some(qc) => model.engine.forward_quant(&xb, qc)?,
                None => model.engine.forward_f32(&xb, &[])?.0,
            }
        };
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        account_chunk(
            &metrics,
            &bandit,
            &key,
            &group[done..done + take],
            queue_start,
            0,
            exec,
        );
        let _decode = ring.span("decode", format!("variant={key} batch={take}"));
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}

fn run_group_pjrt(
    st: &mut WorkerState,
    group: &[InferRequest],
    variant: &str,
    available: &[usize],
) -> Result<()> {
    let Some(exe_batch) = pick_batch(group.len(), available) else {
        anyhow::bail!("no executable for {}/{}", st.model_name, variant);
    };
    let key = group[0].spec.key();
    let ring = st.ring.clone();
    let dims = group[0].image.dims().to_vec(); // (H, W, C)
    let img_sz: usize = dims.iter().product();
    let needs_scales = variant != "fp32";

    let mut done = 0;
    for take in chunks(group.len(), exe_batch) {
        // build padded batch tensor (shape-generic, like the native path)
        let mut bdims = vec![exe_batch];
        bdims.extend_from_slice(&dims);
        let mut xb = TensorF::zeros(&bdims);
        for (slot, req) in group[done..done + take].iter().enumerate() {
            xb.data[slot * img_sz..(slot + 1) * img_sz].copy_from_slice(&req.image.data);
        }
        let queue_start = Instant::now();
        if ring.enabled() {
            let qd = format!("variant={key}");
            for req in &group[done..done + take] {
                ring.record("queue", qd.clone(), req.submitted, queue_start);
            }
        }
        let exe = st.cache.get(&st.model_name, variant, exe_batch)?;
        let inputs: Vec<Input> = if needs_scales {
            vec![Input::F32(xb), Input::F32(st.scales.clone())]
        } else {
            vec![Input::F32(xb)]
        };
        let t0 = Instant::now();
        let logits = {
            let _exec = ring.span("execute", format!("variant={key} batch={exe_batch}"));
            exe.run_f32(&inputs)?
        };
        let exec = t0.elapsed();
        let classes = logits.dims()[1];
        account_chunk(
            &st.metrics,
            &st.bandit,
            &key,
            &group[done..done + take],
            queue_start,
            exe_batch - take,
            exec,
        );
        for (slot, req) in group[done..done + take].iter().enumerate() {
            let resp = InferResponse {
                logits: logits.data[slot * classes..(slot + 1) * classes].to_vec(),
                batch_size: take,
                queue: queue_start - req.submitted,
                e2e: req.submitted.elapsed(),
            };
            let _ = req.resp.send(Ok(resp)); // client may have gone away
        }
        done += take;
    }
    Ok(())
}
