//! Cross-request dynamic batching: a bounded, deadline-aware submission
//! queue shared by every replica of a model shard.
//!
//! PR 3's batcher collected from a per-worker mpsc channel — one
//! consumer, unbounded, no admission control. This module replaces it
//! with [`SubmitQueue`]: a single queue per shard that any number of
//! replica workers pull batches from ([`SubmitQueue::next_batch`]),
//! with three serving-layer guarantees on top:
//!
//! * **Bounded admission** — [`SubmitQueue::push`] sheds instead of
//!   queueing once `max_depth` requests wait ([`ShedReason::QueueFull`])
//!   or a single tenant exceeds its quota
//!   ([`ShedReason::TenantQuota`]). Shedding is synchronous: the caller
//!   gets the request back and replies immediately, so overload never
//!   grows the queue without bound.
//! * **Deadline awareness** — requests carry an optional deadline. A
//!   batch closes at `max_batch`, at `max_wait` after its oldest
//!   member, or at the earliest deadline of its members, whichever
//!   comes first; requests already past their deadline are never
//!   batched but handed back as `expired` for an explicit
//!   `DeadlineExceeded` reply.
//! * **Tenant fairness** — within a batch's variant group, members are
//!   drawn round-robin across tenants
//!   ([`super::router::round_robin_merge`]), FIFO within each tenant,
//!   so one flooding tenant cannot starve the others even below the
//!   shed threshold.
//!
//! Batches are homogeneous: every member shares the variant-group key
//! of the oldest waiting request, mirroring the old worker-side
//! group-by-spec step. The queue is the shutdown point too:
//! [`SubmitQueue::close`] wakes all workers, later pushes fail, and
//! `next_batch` keeps handing out batches until the admitted backlog is
//! drained — the model-checked shutdown-drain protocol
//! (`rust/tests/model_check.rs`).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::sync::{cv_wait, cv_wait_timeout, lock, Condvar, Mutex};

use super::router::round_robin_merge;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest batch to assemble before executing.
    pub max_batch: usize,
    /// Longest a batch may wait for more requests after its first.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Admission-control knobs for a [`SubmitQueue`].
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    /// Most requests allowed to wait; pushes beyond it shed
    /// ([`ShedReason::QueueFull`]).
    pub max_depth: usize,
    /// Most *waiting* requests one tenant may hold; pushes beyond it
    /// shed ([`ShedReason::TenantQuota`]). `None` = no per-tenant cap.
    pub tenant_quota: Option<usize>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            max_depth: 4096,
            tenant_quota: None,
        }
    }
}

/// Why a push was shed at admission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue held `max_depth` waiting requests.
    QueueFull {
        /// Queue depth observed at the shed.
        depth: usize,
    },
    /// The request's tenant already held its full quota of waiting
    /// requests.
    TenantQuota {
        /// The over-quota tenant.
        tenant: String,
        /// The configured per-tenant quota.
        quota: usize,
    },
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull { depth } => write!(f, "queue full (depth {depth})"),
            ShedReason::TenantQuota { tenant, quota } => {
                write!(f, "tenant {tenant:?} over quota ({quota} waiting)")
            }
        }
    }
}

/// A rejected [`SubmitQueue::push`]; the item comes back so the caller
/// can reply to it.
#[derive(Debug)]
pub enum PushError<T> {
    /// Shed at admission (queue full or tenant over quota).
    Shed {
        /// The rejected request.
        item: T,
        /// Why it was shed.
        reason: ShedReason,
    },
    /// The queue was closed ([`SubmitQueue::close`]).
    Closed {
        /// The rejected request.
        item: T,
    },
}

/// What a worker gets from [`SubmitQueue::next_batch`].
#[derive(Debug)]
pub enum Drained<T> {
    /// Work to do: a single-group batch (possibly empty) plus requests
    /// whose deadline passed while they waited — the caller must reply
    /// `DeadlineExceeded` to those, never drop them.
    Work {
        /// Batch to execute; all members share one group key.
        batch: Vec<T>,
        /// Admitted requests that expired in the queue.
        expired: Vec<T>,
    },
    /// Woken by [`SubmitQueue::kick`] with nothing to hand out; the
    /// caller re-checks its own lifecycle conditions (e.g. replica
    /// retirement) and calls again.
    Idle,
    /// Closed and fully drained: the worker can exit.
    Done,
}

/// What the queue needs to know about a queued request. Implemented by
/// `coordinator::server::InferRequest`; tests use lightweight stand-ins.
pub trait BatchItem {
    /// Batch-compatibility key — only same-group items share a batch
    /// (the resolved variant key in the coordinator).
    fn group(&self) -> &str;
    /// Admission-control tenant.
    fn tenant(&self) -> &str;
    /// Absolute deadline, if the request carries one.
    fn deadline(&self) -> Option<Instant>;
}

struct Pending<T> {
    item: T,
    seq: u64,
    enqueued: Instant,
}

struct QState<T> {
    items: Vec<Pending<T>>,
    /// Waiting-request count per tenant (admission view).
    per_tenant: BTreeMap<String, usize>,
    closed: bool,
    seq: u64,
    /// Bumped by [`SubmitQueue::kick`]; sleepers return `Idle` when it
    /// moves so lifecycle changes (retirement, scale-down) are seen
    /// promptly.
    generation: u64,
    peak: usize,
}

/// The bounded, deadline-aware, tenant-fair submission queue (module
/// docs have the full contract).
pub struct SubmitQueue<T> {
    cfg: QueueConfig,
    state: Mutex<QState<T>>,
    cv: Condvar,
}

impl<T: BatchItem> SubmitQueue<T> {
    /// Empty open queue with the given admission config.
    pub fn new(cfg: QueueConfig) -> SubmitQueue<T> {
        SubmitQueue {
            cfg,
            state: Mutex::new(QState {
                items: Vec::new(),
                per_tenant: BTreeMap::new(),
                closed: false,
                seq: 0,
                generation: 0,
                peak: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit one request or shed it. On success returns the queue depth
    /// *after* the push (for depth metrics); the emptiness check a
    /// worker sleeps on and this insert happen under one lock, so a
    /// wakeup is never lost (model-checked: `bounded_queue_no_lost_wakeup`).
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = lock(&self.state);
        if st.closed {
            return Err(PushError::Closed { item });
        }
        let depth = st.items.len();
        if depth >= self.cfg.max_depth {
            return Err(PushError::Shed {
                item,
                reason: ShedReason::QueueFull { depth },
            });
        }
        if let Some(quota) = self.cfg.tenant_quota {
            let waiting = st.per_tenant.get(item.tenant()).copied().unwrap_or(0);
            if waiting >= quota {
                let tenant = item.tenant().to_string();
                return Err(PushError::Shed {
                    item,
                    reason: ShedReason::TenantQuota { tenant, quota },
                });
            }
        }
        *st.per_tenant.entry(item.tenant().to_string()).or_insert(0) += 1;
        st.seq += 1;
        let seq = st.seq;
        st.items.push(Pending {
            item,
            seq,
            enqueued: Instant::now(),
        });
        let depth = st.items.len();
        st.peak = st.peak.max(depth);
        self.cv.notify_one();
        Ok(depth)
    }

    /// Current number of waiting requests.
    pub fn depth(&self) -> usize {
        lock(&self.state).items.len()
    }

    /// High-water mark of the waiting-request count.
    pub fn peak_depth(&self) -> usize {
        lock(&self.state).peak
    }

    /// Close the queue: later pushes fail, sleeping workers wake, and
    /// `next_batch` drains the admitted backlog before reporting
    /// [`Drained::Done`].
    pub fn close(&self) {
        let mut st = lock(&self.state);
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Wake every worker without handing out work — sleepers return
    /// [`Drained::Idle`], workers mid-assembly close their batch early.
    /// Used when lifecycle state changed (replica retirement targets).
    pub fn kick(&self) {
        let mut st = lock(&self.state);
        st.generation += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Pull the next batch (blocking). See [`Drained`] for the three
    /// outcomes. The batch: all waiting requests sharing the oldest
    /// request's group key, up to `policy.max_batch`, tenant-fair,
    /// closed early at the earliest member deadline; requests already
    /// past their deadline come back in `expired` instead.
    pub fn next_batch(&self, policy: &BatchPolicy) -> Drained<T> {
        self.batch_inner(policy, true)
    }

    /// Non-blocking [`SubmitQueue::next_batch`]: when the queue is open
    /// but empty, returns [`Drained::Idle`] immediately instead of
    /// sleeping. For drains that may race each other (orphaned-backlog
    /// cleanup after total replica death), where a loser blocking on
    /// the condvar would hang its thread.
    pub fn try_next_batch(&self, policy: &BatchPolicy) -> Drained<T> {
        self.batch_inner(policy, false)
    }

    fn batch_inner(&self, policy: &BatchPolicy, block: bool) -> Drained<T> {
        let max_batch = policy.max_batch.max(1);
        let mut st = lock(&self.state);
        let entry_gen = st.generation;
        // Phase 1: wait for work (or close / kick).
        loop {
            if !st.items.is_empty() {
                break;
            }
            if st.closed {
                return Drained::Done;
            }
            if !block || st.generation != entry_gen {
                return Drained::Idle;
            }
            st = cv_wait(&self.cv, st);
        }
        // Phase 2: assemble. Taken items leave the queue (and the
        // admission counts) immediately, so concurrent workers never
        // select the same request twice.
        let mut expired: Vec<T> = Vec::new();
        let mut batch: Vec<Pending<T>> = Vec::new();
        let mut anchor: Option<Instant> = None; // enqueue time of oldest member
        loop {
            let now = Instant::now();
            // sweep expired requests of every group so they get their
            // DeadlineExceeded reply promptly, not at their group's turn
            let mut i = 0;
            while i < st.items.len() {
                match st.items[i].item.deadline() {
                    Some(d) if d <= now => {
                        let p = st.items.remove(i);
                        take_tenant_slot(&mut st.per_tenant, p.item.tenant());
                        expired.push(p.item);
                    }
                    _ => i += 1,
                }
            }
            // fill from the oldest request's group, tenant-fair
            if batch.len() < max_batch {
                if let Some(oldest) = st.items.iter().min_by_key(|p| p.seq) {
                    let group_ok = batch
                        .first()
                        .map(|b| b.item.group() == oldest.item.group())
                        .unwrap_or(true);
                    if group_ok {
                        let key = oldest.item.group().to_string();
                        let room = max_batch - batch.len();
                        batch.extend(take_group(&mut st, &key, room));
                        if anchor.is_none() {
                            // take_group returns seq-sorted, so [0] is oldest
                            anchor = batch.first().map(|p| p.enqueued);
                        }
                    }
                }
            }
            if batch.is_empty() {
                // every waiting request expired: hand them back now
                break;
            }
            if batch.len() >= max_batch || st.closed || st.generation != entry_gen {
                break;
            }
            // close time: max_wait after the oldest member, clamped to
            // the earliest member deadline so nobody expires in-batch
            let mut close_at = match anchor {
                Some(a) => a + policy.max_wait,
                None => Instant::now(),
            };
            for p in &batch {
                if let Some(d) = p.item.deadline() {
                    close_at = close_at.min(d);
                }
            }
            let now = Instant::now();
            if close_at <= now {
                break;
            }
            let (g, timed_out) = cv_wait_timeout(&self.cv, st, close_at - now);
            st = g;
            if timed_out {
                break;
            }
        }
        // tenant-fair order inside the batch: FIFO lanes per tenant,
        // interleaved round-robin starting from the oldest lane
        let mut lanes: Vec<(String, Vec<Pending<T>>)> = Vec::new();
        let mut by_seq = batch;
        by_seq.sort_by_key(|p| p.seq);
        for p in by_seq {
            match lanes.iter_mut().find(|(t, _)| t == p.item.tenant()) {
                Some((_, lane)) => lane.push(p),
                None => lanes.push((p.item.tenant().to_string(), vec![p])),
            }
        }
        let batch = round_robin_merge(lanes).into_iter().map(|p| p.item).collect();
        Drained::Work { batch, expired }
    }
}

/// Decrement (and clean up) one tenant's waiting count.
fn take_tenant_slot(per_tenant: &mut BTreeMap<String, usize>, tenant: &str) {
    if let Some(n) = per_tenant.get_mut(tenant) {
        *n -= 1;
        if *n == 0 {
            per_tenant.remove(tenant);
        }
    }
}

/// Remove up to `room` unexpired items of `group` from the queue, in
/// seq order, keeping the admission counts in step.
fn take_group<T: BatchItem>(st: &mut QState<T>, group: &str, room: usize) -> Vec<Pending<T>> {
    let mut order: Vec<usize> = (0..st.items.len())
        .filter(|&i| st.items[i].item.group() == group)
        .collect();
    order.sort_by_key(|&i| st.items[i].seq);
    order.truncate(room);
    // remove from the back so earlier indices stay valid
    order.sort_unstable_by(|a, b| b.cmp(a));
    let mut taken: Vec<Pending<T>> = order
        .into_iter()
        .map(|i| {
            let p = st.items.remove(i);
            take_tenant_slot(&mut st.per_tenant, p.item.tenant());
            p
        })
        .collect();
    taken.sort_by_key(|p| p.seq);
    taken
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen};
    use std::sync::Arc;

    /// Minimal [`BatchItem`] for queue tests.
    #[derive(Clone, Debug, PartialEq)]
    struct Item {
        id: usize,
        group: String,
        tenant: String,
        deadline: Option<Instant>,
    }

    impl Item {
        fn new(id: usize) -> Item {
            Item {
                id,
                group: "g".into(),
                tenant: "default".into(),
                deadline: None,
            }
        }
        fn tenant(mut self, t: &str) -> Item {
            self.tenant = t.into();
            self
        }
        fn group(mut self, g: &str) -> Item {
            self.group = g.into();
            self
        }
        fn deadline(mut self, d: Instant) -> Item {
            self.deadline = Some(d);
            self
        }
    }

    impl BatchItem for Item {
        fn group(&self) -> &str {
            &self.group
        }
        fn tenant(&self) -> &str {
            &self.tenant
        }
        fn deadline(&self) -> Option<Instant> {
            self.deadline
        }
    }

    /// `max_wait: 0` drains whatever is queued without waiting — the
    /// deterministic setting every non-timing test uses.
    fn eager() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
        }
    }

    fn drain(q: &SubmitQueue<Item>, policy: &BatchPolicy) -> (Vec<Vec<Item>>, Vec<Item>) {
        q.close();
        let (mut batches, mut expired) = (Vec::new(), Vec::new());
        loop {
            match q.next_batch(policy) {
                Drained::Work { batch, expired: e } => {
                    if !batch.is_empty() {
                        batches.push(batch);
                    }
                    expired.extend(e);
                }
                Drained::Idle => continue,
                Drained::Done => return (batches, expired),
            }
        }
    }

    #[test]
    fn batches_up_to_max() {
        let q = SubmitQueue::new(QueueConfig::default());
        for i in 0..20 {
            q.push(Item::new(i)).unwrap();
        }
        let (batches, expired) = drain(&q, &eager());
        assert!(expired.is_empty());
        let sizes: Vec<usize> = batches.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![8, 8, 4]);
        let ids: Vec<usize> = batches.concat().iter().map(|i| i.id).collect();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn sheds_at_max_depth() {
        let q = SubmitQueue::new(QueueConfig {
            max_depth: 3,
            tenant_quota: None,
        });
        for i in 0..3 {
            q.push(Item::new(i)).unwrap();
        }
        match q.push(Item::new(3)) {
            Err(PushError::Shed { item, reason }) => {
                assert_eq!(item.id, 3);
                assert_eq!(reason, ShedReason::QueueFull { depth: 3 });
            }
            other => panic!("expected QueueFull shed, got {other:?}"),
        }
        // draining frees capacity again
        let (batches, _) = drain(&q, &eager());
        assert_eq!(batches[0].len(), 3);
    }

    #[test]
    fn tenant_quota_sheds_only_the_flooder() {
        let q = SubmitQueue::new(QueueConfig {
            max_depth: 100,
            tenant_quota: Some(2),
        });
        q.push(Item::new(0).tenant("a")).unwrap();
        q.push(Item::new(1).tenant("a")).unwrap();
        match q.push(Item::new(2).tenant("a")) {
            Err(PushError::Shed { reason, .. }) => assert_eq!(
                reason,
                ShedReason::TenantQuota {
                    tenant: "a".into(),
                    quota: 2
                }
            ),
            other => panic!("expected TenantQuota shed, got {other:?}"),
        }
        // another tenant is unaffected
        q.push(Item::new(3).tenant("b")).unwrap();
        assert_eq!(q.depth(), 3);
        assert_eq!(q.peak_depth(), 3);
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = SubmitQueue::new(QueueConfig::default());
        q.push(Item::new(0)).unwrap();
        q.close();
        assert!(matches!(q.push(Item::new(1)), Err(PushError::Closed { .. })));
        match q.next_batch(&eager()) {
            Drained::Work { batch, .. } => assert_eq!(batch.len(), 1),
            other => panic!("expected the admitted request, got {other:?}"),
        }
        assert!(matches!(q.next_batch(&eager()), Drained::Done));
    }

    #[test]
    fn batches_are_single_group() {
        let q = SubmitQueue::new(QueueConfig::default());
        q.push(Item::new(0).group("g1")).unwrap();
        q.push(Item::new(1).group("g2")).unwrap();
        q.push(Item::new(2).group("g1")).unwrap();
        let (batches, _) = drain(&q, &eager());
        // oldest request anchors the first batch to g1
        let g: Vec<Vec<usize>> = batches
            .iter()
            .map(|b| b.iter().map(|i| i.id).collect())
            .collect();
        assert_eq!(g, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn expired_requests_never_batch() {
        let q = SubmitQueue::new(QueueConfig::default());
        let past = Instant::now() - Duration::from_millis(50);
        let future = Instant::now() + Duration::from_secs(60);
        q.push(Item::new(0).deadline(past)).unwrap();
        q.push(Item::new(1).deadline(future)).unwrap();
        q.push(Item::new(2)).unwrap();
        let (batches, expired) = drain(&q, &eager());
        assert_eq!(expired.iter().map(|i| i.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(
            batches.concat().iter().map(|i| i.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }

    #[test]
    fn waits_for_stragglers_up_to_max_wait() {
        let q = Arc::new(SubmitQueue::new(QueueConfig::default()));
        q.push(Item::new(0)).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(Item::new(1)).unwrap();
        });
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(300),
        };
        match q.next_batch(&policy) {
            Drained::Work { batch, .. } => {
                assert_eq!(batch.iter().map(|i| i.id).collect::<Vec<_>>(), vec![0, 1]);
            }
            other => panic!("expected a 2-batch, got {other:?}"),
        }
        t.join().unwrap();
    }

    #[test]
    fn kick_wakes_idle_workers() {
        let q: Arc<SubmitQueue<Item>> = Arc::new(SubmitQueue::new(QueueConfig::default()));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.next_batch(&eager()));
        std::thread::sleep(Duration::from_millis(20));
        q.kick();
        assert!(matches!(t.join().unwrap(), Drained::Idle));
    }

    // ---- property tests over random arrival streams (prop::gen) ----

    /// Map one generated [`gen::Arrival`] to a queue item. Deadline
    /// offsets are widened to ±50 ms/+50 s so wall-clock jitter between
    /// generation and batching cannot flip expired/live.
    fn arrival_item(id: usize, a: &gen::Arrival) -> Item {
        let mut it = Item::new(id)
            .tenant(&format!("t{}", a.tenant))
            .group(&format!("g{}", a.group));
        it.deadline = a.deadline_us.map(|d| {
            if d < 0 {
                Instant::now() - Duration::from_millis(50)
            } else {
                Instant::now() + Duration::from_secs(50)
            }
        });
        it
    }

    #[test]
    fn prop_no_expired_in_batch_no_oversize_single_group() {
        check("deadline batcher invariants", 40, |rng| {
            let stream = gen::arrivals(rng, 60);
            let max_batch = 1 + rng.index(9);
            let q = SubmitQueue::new(QueueConfig::default());
            for (i, a) in stream.iter().enumerate() {
                q.push(arrival_item(i, a)).unwrap();
            }
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::ZERO,
            };
            let (batches, expired) = drain(&q, &policy);
            let mut seen = 0;
            for b in &batches {
                assert!(!b.is_empty() && b.len() <= max_batch, "batch size {}", b.len());
                // single group per batch
                assert!(b.iter().all(|i| i.group == b[0].group), "mixed groups: {b:?}");
                // no already-expired member
                for i in b {
                    assert!(
                        stream[i.id].deadline_us.map(|d| d >= 0).unwrap_or(true),
                        "expired request {} reached a batch",
                        i.id
                    );
                }
                seen += b.len();
            }
            // every expired stream entry is handed back, none executed
            for i in &expired {
                assert!(stream[i.id].deadline_us.unwrap() < 0);
            }
            assert_eq!(seen + expired.len(), stream.len(), "requests lost or duplicated");
        });
    }

    #[test]
    fn prop_fifo_holds_within_tenant() {
        check("tenant FIFO under fair dequeue", 40, |rng| {
            let stream: Vec<gen::Arrival> = gen::arrivals(rng, 60)
                .into_iter()
                .map(|mut a| {
                    a.deadline_us = None; // FIFO property wants no expiry holes
                    a
                })
                .collect();
            let q = SubmitQueue::new(QueueConfig::default());
            for (i, a) in stream.iter().enumerate() {
                q.push(arrival_item(i, a)).unwrap();
            }
            let policy = BatchPolicy {
                max_batch: 1 + rng.index(9),
                max_wait: Duration::ZERO,
            };
            let (batches, _) = drain(&q, &policy);
            let mut last: BTreeMap<String, usize> = BTreeMap::new();
            for item in batches.concat() {
                if let Some(&prev) = last.get(&item.tenant) {
                    assert!(
                        item.id > prev,
                        "tenant {} served {} after {}",
                        item.tenant,
                        item.id,
                        prev
                    );
                }
                last.insert(item.tenant.clone(), item.id);
            }
        });
    }

    #[test]
    fn prop_admission_never_exceeds_bounds() {
        check("bounded admission", 40, |rng| {
            let depth = 1 + rng.index(8);
            let quota = 1 + rng.index(4);
            let q = SubmitQueue::new(QueueConfig {
                max_depth: depth,
                tenant_quota: Some(quota),
            });
            let stream = gen::arrivals(rng, 40);
            let mut admitted = 0usize;
            for (i, a) in stream.iter().enumerate() {
                let mut it = arrival_item(i, a);
                it.deadline = None;
                match q.push(it) {
                    Ok(d) => {
                        admitted += 1;
                        assert!(d <= depth, "depth {d} exceeded bound {depth}");
                    }
                    Err(PushError::Shed { .. }) => {}
                    Err(PushError::Closed { .. }) => unreachable!(),
                }
                assert!(q.depth() <= depth);
            }
            let (batches, expired) = drain(&q, &eager());
            assert!(expired.is_empty());
            let drained: usize = batches.iter().map(|b| b.len()).sum();
            assert_eq!(drained, admitted, "admitted requests dropped");
        });
    }
}
