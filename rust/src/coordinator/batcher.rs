//! Dynamic batcher: greedy size/deadline batching over an mpsc queue.
//!
//! Policy: block until the first request arrives, then keep draining
//! until either `max_batch` requests are in hand or `max_wait` has
//! elapsed since the first one. FIFO order is preserved.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest batch to assemble before executing.
    pub max_batch: usize,
    /// Longest a batch may wait for more requests after its first.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Collect one batch. Returns `None` when the channel has disconnected
/// and no requests remain.
pub fn collect<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        };
        let b1 = collect(&rx, &policy).unwrap();
        assert_eq!(b1, (0..8).collect::<Vec<_>>());
        let b2 = collect(&rx, &policy).unwrap();
        assert_eq!(b2, (8..16).collect::<Vec<_>>());
    }

    #[test]
    fn none_after_disconnect() {
        let (tx, rx) = channel::<u32>();
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(collect(&rx, &BatchPolicy::default()), Some(vec![1]));
        assert_eq!(collect(&rx, &BatchPolicy::default()), None);
    }

    #[test]
    fn respects_deadline() {
        let (tx, rx) = channel();
        tx.send(0u32).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            let _ = tx.send(1);
        });
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let b = collect(&rx, &policy).unwrap();
        assert_eq!(b, vec![0]); // did not wait for the late request
        t.join().unwrap();
    }

    #[test]
    fn prop_no_loss_no_dup_fifo() {
        use crate::util::prop::check;
        check("batcher preserves the stream", 30, |rng| {
            let n = 1 + rng.index(100);
            let max_batch = 1 + rng.index(16);
            let (tx, rx) = channel();
            for i in 0..n {
                tx.send(i).unwrap();
            }
            drop(tx);
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            };
            let mut seen = Vec::new();
            while let Some(batch) = collect(&rx, &policy) {
                assert!(batch.len() <= max_batch);
                seen.extend(batch);
            }
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        });
    }
}
