//! Outlier-coverage analysis (paper §3.2, Table 1, Eq. 1).
//!
//! *Outlier coverage* = fraction of outliers (values the quantizer would
//! clip) handled by range overwrite. Eq. (1) models it as
//! `P = 1 - (1 - p0)^c` under iid zeros with probability p0.

use crate::tensor::TensorF;

use super::encode::{encode_tensor, int_codes};
use super::state::{OverQConfig, MSB};

/// Coverage statistics for one activation tensor at one config.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoverageStats {
    /// Total values inspected.
    pub total: usize,
    /// Values exceeding qmax (would be clipped by plain quantization).
    pub outliers: usize,
    /// Outliers covered by range overwrite.
    pub covered: usize,
    /// Exact zeros.
    pub zeros: usize,
    /// Slots claimed for precision overwrite.
    pub pr_slots: usize,
}

impl CoverageStats {
    /// Fraction of outliers covered (1.0 when there are none).
    pub fn coverage(&self) -> f64 {
        if self.outliers == 0 {
            1.0
        } else {
            self.covered as f64 / self.outliers as f64
        }
    }

    pub fn zero_frac(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.zeros as f64 / self.total as f64
        }
    }

    pub fn merge(&mut self, o: &CoverageStats) {
        self.total += o.total;
        self.outliers += o.outliers;
        self.covered += o.covered;
        self.zeros += o.zeros;
        self.pr_slots += o.pr_slots;
    }
}

/// Eq. (1): probability a zero lies within `c` slots, iid zeros at `p0`.
pub fn theory_coverage(p0: f64, cascade: usize) -> f64 {
    1.0 - (1.0 - p0).powi(cascade as i32)
}

/// Measure coverage of an activation tensor at the given scale/config.
///
/// Counts MSB slots (each identifies exactly one covered outlier) against
/// the raw outlier count from the pre-encode integer codes.
pub fn coverage_stats(x: &TensorF, scale: f32, cfg: &OverQConfig) -> CoverageStats {
    let mut s = CoverageStats {
        total: x.numel(),
        ..Default::default()
    };
    let inv = 1.0f32 / scale;
    let bf = cfg.b() as f32;
    let qmax = cfg.qmax();
    for &v in &x.data {
        let (code, _) = int_codes(v, inv, bf);
        if code > qmax {
            s.outliers += 1;
        }
        if code == 0 {
            s.zeros += 1;
        }
    }
    let enc = encode_tensor(x, scale, cfg);
    for (k, &st) in enc.state.data.iter().enumerate() {
        if st == MSB {
            s.covered += 1;
        }
        if st == super::state::LSB {
            s.pr_slots += 1;
        }
        let _ = k;
    }
    s
}

/// [`coverage_stats`] computed from the bit-packed encode — the
/// outlier/zero pre-counts are identical scalar passes, but the
/// MSB/LSB tallies come from [`super::dotprod::slot_histogram_packed`]
/// over the packed words instead of the state lane. Must agree exactly
/// with [`coverage_stats`]; the property suite pins it.
pub fn coverage_stats_packed(x: &TensorF, scale: f32, cfg: &OverQConfig) -> CoverageStats {
    let mut s = CoverageStats {
        total: x.numel(),
        ..Default::default()
    };
    let inv = 1.0f32 / scale;
    let bf = cfg.b() as f32;
    let qmax = cfg.qmax();
    for &v in &x.data {
        let (code, _) = int_codes(v, inv, bf);
        if code > qmax {
            s.outliers += 1;
        }
        if code == 0 {
            s.zeros += 1;
        }
    }
    let enc = encode_tensor(x, scale, cfg);
    let p = super::encode::pack_slots(&enc.codes, &enc.state, cfg.bits);
    let h = super::dotprod::slot_histogram_packed(&p);
    s.covered = h[MSB as usize] as usize;
    s.pr_slots = h[super::state::LSB as usize] as usize;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn synth(rng: &mut Rng, rows: usize, c: usize, p0: f64, pout: f64) -> TensorF {
        let mut x = TensorF::zeros(&[rows, c]);
        for v in x.data.iter_mut() {
            *v = if rng.bool(p0) {
                0.0
            } else if rng.bool(pout) {
                rng.normal().abs() * 4.0 + 5.0
            } else {
                rng.normal().abs() * 0.8 + 0.05
            };
        }
        x
    }

    #[test]
    fn eq1_matches_bernoulli_simulation() {
        // iid zero pattern + sparse outliers: measured coverage tracks
        // Eq. (1) within sampling error (the paper's Table 1 'Theory').
        let mut rng = Rng::new(2024);
        let x = synth(&mut rng, 600, 64, 0.5, 0.012);
        for c in 1..=4 {
            let cfg = OverQConfig::ro(4, c);
            let s = coverage_stats(&x, 0.35, &cfg);
            assert!(s.outliers > 50, "need outliers, got {}", s.outliers);
            let want = theory_coverage(s.zero_frac(), c);
            assert!(
                (s.coverage() - want).abs() < 0.12,
                "c={c}: got {} want {}",
                s.coverage(),
                want
            );
        }
    }

    #[test]
    fn coverage_monotone_in_cascade() {
        check("coverage monotone in c", 60, |rng: &mut Rng| {
            let p0 = 0.4 + rng.f64() * 0.3;
            let x = synth(rng, 40, 32, p0, 0.05);
            let mut prev = -1.0;
            for c in 1..=6 {
                let s = coverage_stats(&x, 0.3, &OverQConfig::ro(4, c));
                assert!(s.coverage() >= prev - 1e-12);
                prev = s.coverage();
            }
        });
    }

    #[test]
    fn theory_limits() {
        assert_eq!(theory_coverage(0.5, 1), 0.5);
        assert_eq!(theory_coverage(0.5, 2), 0.75);
        assert!((theory_coverage(0.5, 6) - 0.984375).abs() < 1e-9);
        assert_eq!(theory_coverage(0.0, 5), 0.0);
        assert_eq!(theory_coverage(1.0, 1), 1.0);
    }

    #[test]
    fn prop_packed_stats_match_unpacked() {
        check("coverage_stats_packed == coverage_stats", 80, |rng: &mut Rng| {
            let cfg = OverQConfig {
                bits: 2 + rng.index(7) as u32,
                cascade: 1 + rng.index(4),
                range_overwrite: rng.bool(0.8),
                precision_overwrite: rng.bool(0.5),
            };
            let x = synth(rng, 1 + rng.index(20), 1 + rng.index(40), 0.45, 0.06);
            let a = coverage_stats(&x, 0.3, &cfg);
            let b = coverage_stats_packed(&x, 0.3, &cfg);
            assert_eq!(
                (a.total, a.outliers, a.covered, a.zeros, a.pr_slots),
                (b.total, b.outliers, b.covered, b.zeros, b.pr_slots),
                "cfg={cfg:?}"
            );
        });
    }

    #[test]
    fn no_outliers_full_coverage() {
        let x = TensorF::from_vec(&[1, 4], vec![0.1, 0.0, 0.2, 0.0]);
        let s = coverage_stats(&x, 0.1, &OverQConfig::ro(4, 2));
        assert_eq!(s.outliers, 0);
        assert_eq!(s.coverage(), 1.0);
    }
}
