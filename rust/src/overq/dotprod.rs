//! The OverQ dot product / GEMM — the hardware-view computation.
//!
//! `sum_k codes[k] * factor[k] * w[sel(k)]` with `sel(k) = k-1` for all
//! non-NORM slots (weight copy from the adjacent PE) and per-slot factor
//! B / B² / 1 (NORM-SHIFT / MSB / LSB). The result is `B * Σ x̂·w` in
//! fixed point; epilogues fold the extra B into the dequant scale.
//!
//! `gemm_overq` is the native analogue of the Pallas kernel
//! (`python/compile/kernels/overq_matmul.py`): the state-muxed weight
//! copy becomes a second GEMM against the 1-rolled weight matrix:
//! `out = A0 @ W + A1 @ Wroll`.

use crate::tensor::{Tensor, TensorI};
use crate::util::threadpool;

use super::encode::PackedSlots;
use super::state::{OverQConfig, SlotState, NORM};

/// Below this many slot×column multiply-adds the packed GEMM stays
/// sequential (thread spawn would dominate).
const PAR_MIN_MACS: usize = 1 << 18;

/// Output rows per unit of parallel work in [`gemm_overq_packed`].
const ROW_CHUNK: usize = 64;

/// Slot-wise dot product against one weight column (reference form).
pub fn dot_fixed_point(
    codes: &[i32],
    state: &[SlotState],
    w: &[i32],
    cfg: &OverQConfig,
) -> i64 {
    let mut acc = 0i64;
    for k in 0..codes.len() {
        let wsel = if state[k] != NORM {
            if k == 0 {
                0
            } else {
                w[k - 1]
            }
        } else {
            w[k]
        };
        acc += codes[k] as i64 * cfg.factor(state[k]) * wsel as i64;
    }
    acc
}

/// OverQ GEMM: (M,K) codes/state × (K,N) int8-range weights → (M,N) i32.
///
/// Identical numerics to the Pallas kernel; accumulates in i32 (bounds
/// proven for b ≤ 5, K ≤ 512 — see python/tests/test_kernel.py).
/// `wroll` must be `w` shifted down one row (row 0 = zeros); pass the
/// output of [`roll_weights`].
pub fn gemm_overq(
    codes: &TensorI,
    state: &Tensor<SlotState>,
    w: &TensorI,
    wroll: &TensorI,
    cfg: &OverQConfig,
    out: &mut TensorI,
) {
    let (m, k) = (codes.dims()[0], codes.dims()[1]);
    let n = w.dims()[1];
    assert_eq!(w.dims()[0], k);
    assert_eq!(out.dims(), &[m, n]);
    let b = cfg.b();
    let bb = b * b;
    out.data.fill(0);
    // Row-major GEMM with the decode fused into the k loop. Each slot
    // reads EITHER w[kk] (NORM) or wroll[kk] (the weight-copy states),
    // so exactly one axpy per non-zero slot; ReLU zeros (~50 % of
    // slots) are skipped entirely — the §Perf optimization that took
    // this kernel from 1.05 to >3 GOPS (EXPERIMENTS.md §Perf).
    // per-state factor table: NORM/SHIFT -> B, MSB -> B*B, LSB -> 1
    let ftab = [b, bb, b, 1i32];
    for i in 0..m {
        let crow = codes.row(i);
        let srow = state.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for kk in 0..k {
            let code = crow[kk];
            if code == 0 {
                continue;
            }
            let st = srow[kk];
            let v = code * ftab[(st & 3) as usize];
            let wrow = if st == NORM {
                &w.data[kk * n..(kk + 1) * n]
            } else {
                &wroll.data[kk * n..(kk + 1) * n]
            };
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += v * wv;
            }
        }
    }
}

/// [`gemm_overq`] over the bit-packed activation plane, parallel over
/// row chunks. Bit-identical to the value-at-a-time kernel (integer
/// accumulation is associative): `tests/kernel_diff.rs` pins the parity.
///
/// The word loop gives two skip levels the struct-of-arrays kernel does
/// not have: a whole u64 of zero slots (common under ReLU sparsity) is
/// one compare, and each live word is loaded once with the (code, state)
/// fields shifted out of a register — no second lane to stream.
/// Non-NORM slots always carry a non-zero code (MSB ≥ 1, SHIFT copies a
/// non-zero, LSB requires lo > 0), so skipping on `code == 0` alone is
/// exact regardless of the state bits; zero padding in the last word of
/// a row is inert for the same reason.
pub fn gemm_overq_packed(
    p: &PackedSlots,
    w: &TensorI,
    wroll: &TensorI,
    cfg: &OverQConfig,
    out: &mut TensorI,
) {
    let macs = p
        .rows
        .saturating_mul(p.cols)
        .saturating_mul(w.dims()[1]);
    let threads = if macs < PAR_MIN_MACS {
        1
    } else {
        threadpool::configured_threads()
    };
    gemm_overq_packed_threads(p, w, wroll, cfg, out, threads);
}

/// [`gemm_overq_packed`] with an explicit worker count (1 = sequential).
pub fn gemm_overq_packed_threads(
    p: &PackedSlots,
    w: &TensorI,
    wroll: &TensorI,
    cfg: &OverQConfig,
    out: &mut TensorI,
    threads: usize,
) {
    let (m, k) = (p.rows, p.cols);
    let n = w.dims()[1];
    assert_eq!(w.dims()[0], k, "inner dims");
    assert_eq!(p.bits, cfg.bits, "packed bits != config bits");
    assert_eq!(out.dims(), &[m, n]);
    out.data.fill(0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let sw = p.slot_width();
    let spw = p.slots_per_word();
    let wpr = p.words_per_row();
    let cmask = (1u64 << p.bits) - 1;
    let b = cfg.b();
    let ftab = [b, b * b, b, 1i32];
    let words = &p.words[..];
    threadpool::parallel_chunks_mut(&mut out.data, ROW_CHUNK * n, threads, |ci, ochunk| {
        let i0 = ci * ROW_CHUNK;
        for (ri, orow) in ochunk.chunks_mut(n).enumerate() {
            let i = i0 + ri;
            for (wi, &w0) in words[i * wpr..(i + 1) * wpr].iter().enumerate() {
                if w0 == 0 {
                    continue; // whole word of (0, NORM) slots
                }
                let mut word = w0;
                let base = wi * spw;
                for s in 0..(k - base).min(spw) {
                    let code = (word & cmask) as i32;
                    let st = ((word >> p.bits) & 3) as usize;
                    word >>= sw;
                    if code == 0 {
                        continue;
                    }
                    let kk = base + s;
                    let v = code * ftab[st];
                    let wrow = if st == NORM as usize {
                        &w.data[kk * n..(kk + 1) * n]
                    } else {
                        &wroll.data[kk * n..(kk + 1) * n]
                    };
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += v * wv;
                    }
                }
            }
        }
    });
}

/// MAC-lane slot occupancy of a state tensor: counts indexed by state
/// value, i.e. `[NORM, MSB, SHIFT, LSB]`. Telemetry only — the engine
/// feeds the im2col'd state lane through this so the serving counters
/// can export what fraction of MAC slots ran in each overwrite mode
/// ([`crate::obs::counters::EncObs::mac_slots`]).
pub fn slot_histogram(state: &Tensor<SlotState>) -> [u64; 4] {
    let mut h = [0u64; 4];
    for &s in &state.data {
        h[(s & 3) as usize] += 1;
    }
    h
}

/// [`slot_histogram`] over a packed plane. The padding slots in the
/// last word of each row are *excluded* (they would otherwise inflate
/// the NORM bucket), so the counts match the unpacked histogram exactly
/// — the serving counters must not change meaning when the engine swaps
/// in the packed kernels.
pub fn slot_histogram_packed(p: &PackedSlots) -> [u64; 4] {
    let mut h = [0u64; 4];
    if p.rows == 0 || p.cols == 0 {
        return h;
    }
    let sw = p.slot_width();
    let spw = p.slots_per_word();
    let wpr = p.words_per_row();
    for r in 0..p.rows {
        for (wi, &w0) in p.words[r * wpr..(r + 1) * wpr].iter().enumerate() {
            let mut word = w0;
            let base = wi * spw;
            for _ in 0..(p.cols - base).min(spw) {
                h[((word >> p.bits) & 3) as usize] += 1;
                word >>= sw;
            }
        }
    }
    h
}

/// Build the 1-rolled weight matrix (row 0 zeroed) used by [`gemm_overq`].
pub fn roll_weights(w: &TensorI) -> TensorI {
    let (k, n) = (w.dims()[0], w.dims()[1]);
    let mut out = TensorI::zeros(&[k, n]);
    out.data[n..].copy_from_slice(&w.data[..(k - 1) * n]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overq::decode::decode_rows;
    use crate::overq::encode::encode_tensor;
    use crate::tensor::TensorF;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rand_acts(rng: &mut Rng, m: usize, k: usize) -> TensorF {
        let mut x = TensorF::zeros(&[m, k]);
        for v in x.data.iter_mut() {
            *v = if rng.bool(0.5) {
                0.0
            } else {
                rng.normal().abs() * (if rng.bool(0.08) { 10.0 } else { 1.0 })
            };
        }
        x
    }

    #[test]
    fn prop_gemm_equals_decode_identity() {
        // hardware GEMM == B * (decoded activations @ W), exactly.
        check("overq gemm identity", 120, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.index(12), 1 + rng.index(40), 1 + rng.index(12));
            let cfg = OverQConfig {
                bits: 4,
                cascade: 1 + rng.index(5),
                range_overwrite: rng.bool(0.8),
                precision_overwrite: rng.bool(0.5),
            };
            let scale = 0.2f32;
            let x = rand_acts(rng, m, k);
            let enc = encode_tensor(&x, scale, &cfg);
            let mut w = TensorI::zeros(&[k, n]);
            for v in w.data.iter_mut() {
                *v = rng.range(-127, 128) as i32;
            }
            let wroll = roll_weights(&w);
            let mut out = TensorI::zeros(&[m, n]);
            gemm_overq(&enc.codes, &enc.state, &w, &wroll, &cfg, &mut out);
            // reference: decode (scale 1 → integer-valued * 1/B) then matmul
            let dec = decode_rows(&enc.codes, &enc.state, 1.0, &cfg);
            let b = cfg.b() as f64;
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0f64;
                    for kk in 0..k {
                        want += dec.data[i * k + kk] as f64 * w.data[kk * n + j] as f64;
                    }
                    want *= b;
                    assert!(
                        (out.data[i * n + j] as f64 - want).abs() < 0.5,
                        "mismatch at ({i},{j}): {} vs {}",
                        out.data[i * n + j],
                        want
                    );
                }
            }
        });
    }

    #[test]
    fn prop_gemm_matches_slotwise_dot() {
        check("gemm == dot_fixed_point per column", 80, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.index(6), 1 + rng.index(30), 1 + rng.index(6));
            let cfg = OverQConfig::full(4, 3);
            let x = rand_acts(rng, m, k);
            let enc = encode_tensor(&x, 0.25, &cfg);
            let mut w = TensorI::zeros(&[k, n]);
            for v in w.data.iter_mut() {
                *v = rng.range(-127, 128) as i32;
            }
            let wroll = roll_weights(&w);
            let mut out = TensorI::zeros(&[m, n]);
            gemm_overq(&enc.codes, &enc.state, &w, &wroll, &cfg, &mut out);
            let mut wcol = vec![0i32; k];
            for j in 0..n {
                for kk in 0..k {
                    wcol[kk] = w.data[kk * n + j];
                }
                for i in 0..m {
                    let want = dot_fixed_point(enc.codes.row(i), enc.state.row(i), &wcol, &cfg);
                    assert_eq!(out.data[i * n + j] as i64, want);
                }
            }
        });
    }

    #[test]
    fn prop_packed_gemm_matches_value_at_a_time() {
        use crate::overq::encode::pack_slots;
        check("packed gemm == gemm_overq, all bit widths", 120, |rng: &mut Rng| {
            let cfg = OverQConfig {
                bits: 2 + rng.index(7) as u32, // 2..=8
                cascade: 1 + rng.index(4),
                range_overwrite: rng.bool(0.7),
                precision_overwrite: rng.bool(0.5),
            };
            let (m, k, n) = (1 + rng.index(8), 1 + rng.index(70), 1 + rng.index(9));
            let x = rand_acts(rng, m, k);
            let enc = encode_tensor(&x, 0.2, &cfg);
            let mut w = TensorI::zeros(&[k, n]);
            for v in w.data.iter_mut() {
                *v = rng.range(-127, 128) as i32;
            }
            let wroll = roll_weights(&w);
            let mut want = TensorI::zeros(&[m, n]);
            gemm_overq(&enc.codes, &enc.state, &w, &wroll, &cfg, &mut want);
            let p = pack_slots(&enc.codes, &enc.state, cfg.bits);
            for threads in [1usize, 3] {
                let mut got = TensorI::zeros(&[m, n]);
                gemm_overq_packed_threads(&p, &w, &wroll, &cfg, &mut got, threads);
                assert_eq!(got.data, want.data, "threads={threads} cfg={cfg:?}");
            }
            // histogram over the packed plane matches the unpacked lane
            // (padding excluded)
            assert_eq!(
                slot_histogram_packed(&p),
                slot_histogram(&enc.state),
                "histogram parity cfg={cfg:?}"
            );
        });
    }

    #[test]
    fn packed_gemm_empty_plane() {
        // pack_slots collapses any empty tensor to a (0, 0) plane; the
        // packed GEMM must treat it as a no-op against 0-row weights
        let cfg = OverQConfig::full(4, 2);
        let codes = TensorI::zeros(&[0, 8]);
        let state = Tensor::<SlotState>::zeros(&[0, 8]);
        let p = crate::overq::encode::pack_slots(&codes, &state, cfg.bits);
        assert_eq!((p.rows, p.cols), (0, 0));
        let w = TensorI::zeros(&[0, 3]);
        let wroll = TensorI::zeros(&[0, 3]);
        let mut out = TensorI::zeros(&[0, 3]);
        gemm_overq_packed(&p, &w, &wroll, &cfg, &mut out);
        assert!(out.data.is_empty());
    }

    #[test]
    fn roll_shifts_rows() {
        let w = TensorI::from_vec(&[3, 2], vec![1, 2, 3, 4, 5, 6]);
        let r = roll_weights(&w);
        assert_eq!(r.data, vec![0, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn slot_histogram_counts_states() {
        // same stream as encode::known_chain: v = [20, 3, 5, 0, 2] at
        // 4 bits → states NORM, MSB, SHIFT, SHIFT, NORM
        let cfg = OverQConfig::ro(4, 3);
        let x = TensorF::from_vec(&[1, 5], vec![4.0, 0.6, 1.0, 0.0, 0.4]);
        let enc = encode_tensor(&x, 0.2, &cfg);
        assert_eq!(slot_histogram(&enc.state), [2, 1, 2, 0]);
        // baseline encodes never leave NORM
        let enc = encode_tensor(&x, 0.2, &OverQConfig::baseline(4));
        assert_eq!(slot_histogram(&enc.state), [5, 0, 0, 0]);
    }
}
