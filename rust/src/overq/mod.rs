//! OverQ — overwrite quantization (the paper's contribution).
//!
//! Activations are uniformly quantized to `bits` bits; values the
//! quantizer would clip are **outliers**. OverQ opportunistically widens
//! outliers by letting them overwrite nearby ReLU zeros:
//!
//! * **Range overwrite (RO)** — an outlier's out-of-range MSBs are stored
//!   in an adjacent zero's slot; the adjacent PE copies the outlier's
//!   weight and left-shifts its product (Fig. 1/3/4a of the paper).
//! * **Precision overwrite (PR)** — a non-outlier next to an unclaimed
//!   zero stores `bits` extra LSBs there; the PE right-shifts (Fig. 4b).
//! * **Cascading** — with cascade factor `c`, an outlier may claim the
//!   nearest zero up to `c` slots away; intermediate values shift over by
//!   one slot and reuse their predecessor's weight (Fig. 4c).
//!
//! This module is bit-exact with `python/compile/overq.py` (the
//! `lax.scan` encoder lowered into the AOT model) and with the numpy
//! normative reference — verified by `tests/integration_crosslang.rs`
//! against dumped test vectors.

pub mod coverage;
pub mod decode;
pub mod dotprod;
pub mod encode;
pub mod state;

pub use coverage::{coverage_stats, coverage_stats_packed, theory_coverage, CoverageStats};
pub use decode::{decode_packed, decode_rows, fakequant_from_codes, unpack_slots};
pub use dotprod::{
    dot_fixed_point, gemm_overq, gemm_overq_packed, gemm_overq_packed_threads, slot_histogram,
    slot_histogram_packed,
};
pub use encode::{
    encode_rows, encode_tensor, int_codes, pack_slots, pack_slots_into, packed_len, Encoded,
    PackedSlots,
};
pub use state::{OverQConfig, SlotState, LSB, MSB, NORM, SHIFT};
