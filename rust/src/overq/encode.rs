//! The OverQ encoder — the paper's rescale-unit state computation.
//!
//! Greedy left-to-right scan along the channel axis (DESIGN.md §7),
//! linear time: each slot is visited once because chains jump past their
//! claimed window (the paper's O(nc) → O(n) argument in §3.2).
//!
//! Must stay bit-exact with `python/compile/overq.py::encode_rows_ref`.

use crate::tensor::{Tensor, TensorF, TensorI};

use super::state::{OverQConfig, SlotState, LSB, MSB, NORM, SHIFT};

/// Encoded activation plane: b-bit slot codes + 2-bit state lane.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub codes: TensorI,
    pub state: Tensor<SlotState>,
    /// The activation scale used (clip / qmax).
    pub scale: f32,
}

/// Integerization shared with python (`overq.int_codes_np`):
/// `v = floor(x * (1/scale) + 0.5)`, `vfine = floor(x * (1/scale) * B + 0.5)`.
/// The reciprocal is computed once in f32 to match JAX bit-for-bit.
#[inline]
pub fn int_codes(x: f32, inv_scale: f32, b: f32) -> (i32, i32) {
    let v = (x * inv_scale + 0.5).floor() as i32;
    let vfine = (x * inv_scale * b + 0.5).floor() as i32;
    (v, vfine)
}

/// Encode one channel vector in place. `v`/`vfine` are the unclamped
/// integer codes; outputs go to `codes`/`state` (same length).
pub fn encode_channels(
    v: &[i32],
    vfine: &[i32],
    cfg: &OverQConfig,
    codes: &mut [i32],
    state: &mut [SlotState],
) {
    let c = v.len();
    let b = cfg.bits;
    let bb = 1i32 << b;
    let qmax = bb - 1;
    codes[..c].fill(0);
    state[..c].fill(NORM);
    let mut i = 0;
    while i < c {
        let vi = v[i];
        if vi > qmax {
            // --- outlier: try range overwrite via nearest zero in (i, i+c]
            let mut j = 0;
            if cfg.range_overwrite {
                for d in 1..=cfg.cascade {
                    if i + d < c && v[i + d] == 0 {
                        j = i + d;
                        break;
                    }
                }
            }
            if j > 0 {
                let full = vi.min(bb * bb - 1);
                codes[i] = full & qmax;
                state[i] = NORM;
                codes[i + 1] = full >> b;
                state[i + 1] = MSB;
                for k in (i + 2)..=j {
                    codes[k] = v[k - 1].min(qmax);
                    state[k] = SHIFT;
                }
                i = j + 1;
            } else {
                codes[i] = qmax; // uncovered outlier: clamp
                i += 1;
            }
        } else if vi > 0 {
            codes[i] = vi;
            if cfg.precision_overwrite && i + 1 < c && v[i + 1] == 0 {
                // PR re-derives (hi, lo) from the 2b-bit fine code so
                // hi + lo/B is the best representation (v may round up).
                let vf = vfine[i];
                let hi = (vf >> b).min(qmax);
                let lo = vf & qmax;
                if lo > 0 {
                    codes[i] = hi;
                    codes[i + 1] = lo;
                    state[i + 1] = LSB;
                    i += 2;
                    continue;
                }
            }
            i += 1;
        } else {
            i += 1; // zero — may be claimed by jumps above
        }
    }
}

/// Encode an (R, C) matrix of raw integer codes row by row.
pub fn encode_rows(v: &TensorI, vfine: &TensorI, cfg: &OverQConfig) -> (TensorI, Tensor<SlotState>) {
    assert_eq!(v.dims(), vfine.dims());
    let mut codes = TensorI::zeros(v.dims());
    let mut state = Tensor::<SlotState>::zeros(v.dims());
    for r in 0..v.num_rows() {
        encode_channels(
            v.row(r),
            vfine.row(r),
            cfg,
            codes.row_mut(r),
            state.row_mut(r),
        );
    }
    (codes, state)
}

/// Encode an activation tensor (..., C) along its channel axis with a
/// per-tensor scale. This is the runtime entry point used by the native
/// engine, the systolic simulator and the harnesses.
pub fn encode_tensor(x: &TensorF, scale: f32, cfg: &OverQConfig) -> Encoded {
    let inv = 1.0f32 / scale;
    let bf = (1u32 << cfg.bits) as f32;
    let c = *x.dims().last().expect("rank >= 1");
    let rows = x.numel() / c;
    let mut codes = TensorI::zeros(x.dims());
    let mut state = Tensor::<SlotState>::zeros(x.dims());
    // scratch per row; the fine codes are only needed when precision
    // overwrite is enabled (halves the float work for baseline/RO runs)
    let mut v = vec![0i32; c];
    let mut vfine = vec![0i32; c];
    for r in 0..rows {
        let xr = &x.data[r * c..(r + 1) * c];
        if cfg.precision_overwrite {
            for (k, &xv) in xr.iter().enumerate() {
                let t = xv * inv;
                v[k] = (t + 0.5).floor() as i32;
                vfine[k] = (t * bf + 0.5).floor() as i32;
            }
        } else {
            for (k, &xv) in xr.iter().enumerate() {
                v[k] = (xv * inv + 0.5).floor() as i32;
            }
        }
        encode_channels(&v, &vfine, cfg, codes.row_mut(r), state.row_mut(r));
    }
    Encoded {
        codes,
        state,
        scale,
    }
}

/// Bit-packed (codes, state) plane: the wire format the packed kernels
/// consume in place of the per-value `(i32 code, u8 state)` struct-of-
/// arrays pair.
///
/// Layout (see `docs/runtime.md` for the diagram): each slot occupies
/// `bits + 2` bits of a little-endian u64 word — the b-bit code in the
/// low bits, the 2-bit [`SlotState`] above it:
///
/// ```text
/// word: | slotN | ... | slot2 | slot1 | slot0 |   (slot0 = lowest bits)
/// slot: | state (2 bits) | code (b bits) |
/// ```
///
/// Rows are word-aligned: every row starts on a fresh word and the final
/// word's unused high slots are zero (code 0, state NORM), so a
/// whole-word zero test skips `slots_per_word` slots at once and padding
/// slots are inert in the dot product. Zero padding must however be
/// *excluded* from slot-occupancy telemetry — [`super::dotprod::slot_histogram_packed`]
/// masks it off.
#[derive(Clone, Debug)]
pub struct PackedSlots {
    /// `rows * words_per_row` little-endian words.
    pub words: Vec<u64>,
    /// Number of (im2col) rows.
    pub rows: usize,
    /// Slots per row (the GEMM K dimension).
    pub cols: usize,
    /// Code width b; slot width is `bits + 2`.
    pub bits: u32,
}

impl PackedSlots {
    /// Bits per slot (`bits` code + 2 state).
    #[inline]
    pub fn slot_width(&self) -> u32 {
        self.bits + 2
    }

    /// Slots stored per u64 word.
    #[inline]
    pub fn slots_per_word(&self) -> usize {
        (64 / self.slot_width()) as usize
    }

    /// Words per (word-aligned) row.
    #[inline]
    pub fn words_per_row(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.cols.div_ceil(self.slots_per_word())
        }
    }
}

/// Number of u64 words needed to pack an (rows, cols) plane at `bits`.
pub fn packed_len(rows: usize, cols: usize, bits: u32) -> usize {
    let spw = (64 / (bits + 2)) as usize;
    if cols == 0 {
        0
    } else {
        rows * cols.div_ceil(spw)
    }
}

/// Pack flat row-major (codes, state) lanes into `words`, which must
/// hold exactly [`packed_len`] words. Word-at-a-time: each output word
/// is assembled in a register and stored once.
pub fn pack_slots_into(
    codes: &[i32],
    state: &[SlotState],
    rows: usize,
    cols: usize,
    bits: u32,
    words: &mut [u64],
) {
    assert_eq!(codes.len(), rows * cols, "codes len");
    assert_eq!(state.len(), rows * cols, "state len");
    assert_eq!(words.len(), packed_len(rows, cols, bits), "words len");
    let sw = bits + 2;
    let spw = (64 / sw) as usize;
    let mut wi = 0;
    for r in 0..rows {
        let crow = &codes[r * cols..(r + 1) * cols];
        let srow = &state[r * cols..(r + 1) * cols];
        let mut c0 = 0;
        while c0 < cols {
            let nslots = (cols - c0).min(spw);
            let mut word = 0u64;
            for s in (0..nslots).rev() {
                let code = crow[c0 + s];
                let st = srow[c0 + s];
                debug_assert!(code >= 0 && (code as u64) < (1u64 << bits), "code fits b bits");
                debug_assert!(st < 4, "state fits 2 bits");
                word = (word << sw) | ((st as u64) << bits) | code as u64;
            }
            words[wi] = word;
            wi += 1;
            c0 += nslots;
        }
    }
}

/// Pack an encoded (codes, state) tensor pair into a [`PackedSlots`]
/// plane. The tensors are flattened to (num_rows, last-dim) rows — for
/// the engine these are already the im2col'd (M, K) matrices.
pub fn pack_slots(codes: &TensorI, state: &Tensor<SlotState>, bits: u32) -> PackedSlots {
    assert_eq!(codes.dims(), state.dims(), "codes/state dims");
    let (rows, cols) = if codes.numel() == 0 {
        (0, 0)
    } else {
        (codes.num_rows(), *codes.dims().last().unwrap())
    };
    let mut words = vec![0u64; packed_len(rows, cols, bits)];
    pack_slots_into(&codes.data, &state.data, rows, cols, bits, &mut words);
    PackedSlots {
        words,
        rows,
        cols,
        bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn enc(v: &[i32], cfg: &OverQConfig) -> (Vec<i32>, Vec<SlotState>) {
        let vf: Vec<i32> = v.iter().map(|&x| x * cfg.b()).collect();
        let mut codes = vec![0; v.len()];
        let mut state = vec![0; v.len()];
        encode_channels(v, &vf, cfg, &mut codes, &mut state);
        (codes, state)
    }

    #[test]
    fn known_chain() {
        // Worked example from the paper's Fig. 4(c) style: outlier
        // cascades over two non-zeros to a zero three slots away.
        let cfg = OverQConfig::ro(4, 3);
        let (codes, state) = enc(&[20, 3, 5, 0, 2], &cfg);
        assert_eq!(state, vec![NORM, MSB, SHIFT, SHIFT, NORM]);
        assert_eq!(codes, vec![20 & 15, 20 >> 4, 3, 5, 2]);
    }

    #[test]
    fn adjacent_overwrite() {
        let cfg = OverQConfig::ro(4, 1);
        let (codes, state) = enc(&[200, 0, 1], &cfg);
        assert_eq!(state, vec![NORM, MSB, NORM]);
        // 200 fits in the doubled range (< B²-1 = 255): lo=8, hi=12
        assert_eq!(codes, vec![200 & 15, 200 >> 4, 1]);
    }

    #[test]
    fn huge_outlier_clamps_to_double_range() {
        let cfg = OverQConfig::ro(4, 1);
        let (codes, state) = enc(&[999, 0], &cfg);
        assert_eq!(state, vec![NORM, MSB]);
        assert_eq!(codes, vec![255 & 15, 255 >> 4]);
    }

    #[test]
    fn uncovered_outlier_clamps() {
        let cfg = OverQConfig::ro(4, 2);
        let (codes, state) = enc(&[20, 1, 1, 0], &cfg);
        assert_eq!(state, vec![NORM; 4]);
        assert_eq!(codes, vec![15, 1, 1, 0]);
    }

    #[test]
    fn baseline_never_sets_state() {
        let cfg = OverQConfig::baseline(4);
        let (codes, state) = enc(&[20, 0, 3, 0], &cfg);
        assert_eq!(state, vec![NORM; 4]);
        assert_eq!(codes, vec![15, 0, 3, 0]);
    }

    #[test]
    fn pr_uses_fine_code() {
        let cfg = OverQConfig::full(4, 1);
        // x = 0.37, scale 0.1: v = 4 (rounds up), vfine = 59 → hi 3, lo 11
        let v = vec![4, 0];
        let vfine = vec![59, 0];
        let mut codes = vec![0; 2];
        let mut state = vec![0; 2];
        encode_channels(&v, &vfine, &cfg, &mut codes, &mut state);
        assert_eq!(state, vec![NORM, LSB]);
        assert_eq!(codes, vec![3, 11]);
    }

    #[test]
    fn ro_beats_pr_for_same_zero() {
        // outlier at 0 claims the zero at 1; the non-outlier at 2 then
        // has no zero to its right and stays plain.
        let cfg = OverQConfig::full(4, 1);
        let (_, state) = enc(&[30, 0, 3], &cfg);
        assert_eq!(state, vec![NORM, MSB, NORM]);
    }

    #[test]
    fn prop_invariants() {
        check("encoder invariants", 300, |rng: &mut Rng| {
            let c = 1 + rng.index(48);
            let cfg = OverQConfig {
                bits: 3 + rng.index(3) as u32,
                cascade: 1 + rng.index(6),
                range_overwrite: rng.bool(0.7),
                precision_overwrite: rng.bool(0.5),
            };
            let qmax = cfg.qmax();
            let mut v = vec![0i32; c];
            for x in v.iter_mut() {
                *x = if rng.bool(0.5) {
                    0
                } else if rng.bool(0.1) {
                    qmax + 1 + rng.range(0, 40) as i32
                } else {
                    rng.range(1, qmax as i64 + 1) as i32
                };
            }
            let vf: Vec<i32> = v
                .iter()
                .map(|&x| x * cfg.b() + rng.range(0, cfg.b() as i64) as i32)
                .collect();
            let mut codes = vec![0; c];
            let mut state = vec![0; c];
            encode_channels(&v, &vf, &cfg, &mut codes, &mut state);
            // 1. codes fit in b bits
            assert!(codes.iter().all(|&x| x >= 0 && x <= qmax));
            // 2. slot 0 is never a continuation
            assert_eq!(state[0], NORM);
            // 3. LSB/MSB-as-terminator slots only ever overwrite zeros:
            //    an LSB slot's original value is always zero.
            for k in 0..c {
                if state[k] == LSB {
                    assert_eq!(v[k], 0, "PR overwrote non-zero at {k}");
                }
            }
            // 4. every chain is NORM,MSB,(SHIFT)*: check transitions
            for k in 1..c {
                if state[k] == MSB {
                    assert_eq!(state[k - 1], NORM);
                }
                if state[k] == SHIFT {
                    assert!(state[k - 1] == MSB || state[k - 1] == SHIFT);
                }
            }
            // 5. chains end on an original zero (the claimed slot)
            for k in 0..c {
                let is_chain = state[k] == MSB || state[k] == SHIFT;
                let next_in_chain = k + 1 < c && state[k + 1] == SHIFT;
                if is_chain && !next_in_chain {
                    assert_eq!(v[k], 0, "chain did not end on a zero at {k}");
                }
            }
            // 6. OverQ disabled => all NORM
            if !cfg.range_overwrite && !cfg.precision_overwrite {
                assert!(state.iter().all(|&s| s == NORM));
            }
        });
    }

    #[test]
    fn pack_layout_known_values() {
        // bits=4 → slot width 6, 10 slots per word; row of 3 slots packs
        // into one word with the padding slots zero
        let codes = TensorI::from_vec(&[1, 3], vec![0x5, 0x3, 0xF]);
        let state = Tensor::<SlotState>::from_vec(&[1, 3], vec![NORM, MSB, SHIFT]);
        let p = pack_slots(&codes, &state, 4);
        assert_eq!((p.slot_width(), p.slots_per_word(), p.words_per_row()), (6, 10, 1));
        let want = 0x5u64 | ((0x3 | (MSB as u64) << 4) << 6) | ((0xF | (SHIFT as u64) << 4) << 12);
        assert_eq!(p.words, vec![want]);
    }

    #[test]
    fn pack_rows_are_word_aligned() {
        // bits=6 → slot width 8 → 8 slots/word; 9 cols → 2 words per row
        let codes = TensorI::full(&[3, 9], 1);
        let state = Tensor::<SlotState>::zeros(&[3, 9]);
        let p = pack_slots(&codes, &state, 6);
        assert_eq!(p.words_per_row(), 2);
        assert_eq!(p.words.len(), 6);
        // second word of each row holds exactly one live slot
        for r in 0..3 {
            assert_eq!(p.words[r * 2 + 1], 1);
        }
        assert_eq!(packed_len(3, 9, 6), 6);
    }

    #[test]
    fn pack_empty_plane() {
        let codes = TensorI::zeros(&[0, 5]);
        let state = Tensor::<SlotState>::zeros(&[0, 5]);
        let p = pack_slots(&codes, &state, 4);
        assert!(p.words.is_empty());
        assert_eq!(packed_len(0, 5, 4), 0);
        assert_eq!(packed_len(4, 0, 4), 0);
    }
}
