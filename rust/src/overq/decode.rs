//! Decoding: reconstruct effective dequantized activations from slot
//! codes + states (the "fake-quant view" used by accuracy experiments).
//!
//! Identity (DESIGN.md §7): the hardware dot product over slots equals
//! the plain dot product over this decoded tensor — tested in dotprod.rs.

use crate::tensor::{Tensor, TensorF, TensorI};

use super::encode::PackedSlots;
use super::state::{OverQConfig, SlotState, LSB, MSB, NORM, SHIFT};

/// Decode one row of slot codes to effective values at ORIGINAL indices.
///
/// x̂_k = codes[k+1]                 if state[k+1] == SHIFT (value moved)
///     = 0                          if state[k]  != NORM (consumed zero)
///     = codes[k] + codes[k+1]·B    if state[k+1] == MSB (chain start)
///     = codes[k] + codes[k+1]/B    if state[k+1] == LSB (PR)
///     = codes[k]                   otherwise
/// all times `scale`.
pub fn decode_channels(
    codes: &[i32],
    state: &[SlotState],
    scale: f32,
    cfg: &OverQConfig,
    out: &mut [f32],
) {
    let c = codes.len();
    let b = cfg.b() as f32;
    for k in 0..c {
        let nxt_state = if k + 1 < c { state[k + 1] } else { NORM };
        let nxt_code = if k + 1 < c { codes[k + 1] } else { 0 };
        let v = if nxt_state == SHIFT {
            nxt_code as f32
        } else if state[k] != NORM {
            0.0
        } else {
            match nxt_state {
                MSB => codes[k] as f32 + nxt_code as f32 * b,
                LSB => codes[k] as f32 + nxt_code as f32 / b,
                _ => codes[k] as f32,
            }
        };
        out[k] = v * scale;
    }
}

/// Decode an (R, C) code matrix (row-wise [`decode_channels`]).
pub fn decode_rows(
    codes: &TensorI,
    state: &Tensor<SlotState>,
    scale: f32,
    cfg: &OverQConfig,
) -> TensorF {
    let mut out = TensorF::zeros(codes.dims());
    let c = *codes.dims().last().unwrap();
    for r in 0..codes.num_rows() {
        decode_channels(
            codes.row(r),
            state.row(r),
            scale,
            cfg,
            &mut out.data[r * c..(r + 1) * c],
        );
    }
    out
}

/// Unpack a [`PackedSlots`] plane back into (codes, state) tensors of
/// shape `(rows, cols)` — the exact inverse of
/// [`super::encode::pack_slots`] (pack→unpack is lossless; the property
/// suite pins it).
pub fn unpack_slots(p: &PackedSlots) -> (TensorI, Tensor<SlotState>) {
    let mut codes = TensorI::zeros(&[p.rows, p.cols]);
    let mut state = Tensor::<SlotState>::zeros(&[p.rows, p.cols]);
    if p.cols == 0 || p.rows == 0 {
        return (codes, state);
    }
    let sw = p.slot_width();
    let spw = p.slots_per_word();
    let wpr = p.words_per_row();
    let cmask = (1u64 << p.bits) - 1;
    for r in 0..p.rows {
        let crow = &mut codes.data[r * p.cols..(r + 1) * p.cols];
        let srow = &mut state.data[r * p.cols..(r + 1) * p.cols];
        for (wi, &w0) in p.words[r * wpr..(r + 1) * wpr].iter().enumerate() {
            let mut word = w0;
            let base = wi * spw;
            for s in 0..(p.cols - base).min(spw) {
                crow[base + s] = (word & cmask) as i32;
                srow[base + s] = ((word >> p.bits) & 3) as SlotState;
                word >>= sw;
            }
        }
    }
    (codes, state)
}

/// Effective value of the slot `cur` given its successor `nxt` — the
/// scalar decode rule of [`decode_channels`], shared by the streaming
/// packed decoder.
#[inline]
fn decode_slot(cur: (i32, SlotState), nxt: (i32, SlotState), b: f32) -> f32 {
    if nxt.1 == SHIFT {
        nxt.0 as f32
    } else if cur.1 != NORM {
        0.0
    } else {
        match nxt.1 {
            MSB => cur.0 as f32 + nxt.0 as f32 * b,
            LSB => cur.0 as f32 + nxt.0 as f32 / b,
            _ => cur.0 as f32,
        }
    }
}

/// Word-at-a-time decode of a packed plane to the fake-quant view —
/// numerically identical to unpacking and calling [`decode_rows`], but
/// each u64 is loaded once and slots stream out of a register (the slot
/// at `k` is emitted as soon as its successor `k+1` is extracted).
pub fn decode_packed(p: &PackedSlots, scale: f32, cfg: &OverQConfig) -> TensorF {
    assert_eq!(p.bits, cfg.bits, "packed bits != config bits");
    let (rows, cols) = (p.rows, p.cols);
    let mut out = TensorF::zeros(&[rows, cols]);
    if cols == 0 || rows == 0 {
        return out;
    }
    let sw = p.slot_width();
    let spw = p.slots_per_word();
    let wpr = p.words_per_row();
    let cmask = (1u64 << p.bits) - 1;
    let b = cfg.b() as f32;
    for r in 0..rows {
        let orow = &mut out.data[r * cols..(r + 1) * cols];
        let mut prev: (i32, SlotState) = (0, NORM);
        let mut k = 0usize;
        for (wi, &w0) in p.words[r * wpr..(r + 1) * wpr].iter().enumerate() {
            let mut word = w0;
            let base = wi * spw;
            for _ in 0..(cols - base).min(spw) {
                let cur = ((word & cmask) as i32, ((word >> p.bits) & 3) as SlotState);
                word >>= sw;
                if k > 0 {
                    orow[k - 1] = decode_slot(prev, cur, b) * scale;
                }
                prev = cur;
                k += 1;
            }
        }
        // last slot of the row: no successor (treated as a NORM zero)
        orow[cols - 1] = decode_slot(prev, (0, NORM), b) * scale;
    }
    out
}

/// Alias matching the python API name.
pub fn fakequant_from_codes(
    codes: &TensorI,
    state: &Tensor<SlotState>,
    scale: f32,
    cfg: &OverQConfig,
) -> TensorF {
    decode_rows(codes, state, scale, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overq::encode::{encode_channels, encode_tensor};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn decode_chain() {
        let cfg = OverQConfig::ro(4, 3);
        let v = [20, 3, 5, 0, 2];
        let vf: Vec<i32> = v.iter().map(|&x| x * 16).collect();
        let (mut codes, mut state) = (vec![0; 5], vec![0u8; 5]);
        encode_channels(&v, &vf, &cfg, &mut codes, &mut state);
        let mut out = vec![0.0; 5];
        decode_channels(&codes, &state, 1.0, &cfg, &mut out);
        // original values: 20 (covered outlier), 3, 5, 0 (consumed), 2
        assert_eq!(out, vec![20.0, 3.0, 5.0, 0.0, 2.0]);
    }

    #[test]
    fn prop_error_never_worse_than_clip() {
        check("decode error <= clip error pointwise", 200, |rng: &mut Rng| {
            let cfg = OverQConfig {
                bits: 4,
                cascade: 1 + rng.index(5),
                range_overwrite: true,
                precision_overwrite: rng.bool(0.5),
            };
            let c = 1 + rng.index(40);
            let scale = 0.25f32;
            let mut x = TensorF::zeros(&[1, c]);
            for v in x.data.iter_mut() {
                *v = if rng.bool(0.5) {
                    0.0
                } else {
                    rng.normal().abs() * (if rng.bool(0.1) { 8.0 } else { 1.0 })
                };
            }
            let enc = encode_tensor(&x, scale, &cfg);
            let dec = decode_rows(&enc.codes, &enc.state, scale, &cfg);
            let qmax = cfg.qmax() as f32;
            for k in 0..c {
                let xv = x.data[k];
                let base = ((xv / scale + 0.5).floor().clamp(0.0, qmax)) * scale;
                let e_base = (xv - base).abs();
                let e_ovq = (xv - dec.data[k]).abs();
                assert!(
                    e_ovq <= e_base + 1e-5,
                    "worse at {k}: x={xv} base={base} ovq={}",
                    dec.data[k]
                );
            }
        });
    }

    /// Independent value-level reference of DESIGN.md §7: walk the raw
    /// integer codes and produce the *effective dequantized values*
    /// directly, without going through the (codes, state) bit
    /// representation. encode→decode must reproduce this exactly.
    fn normative_fakequant(x: &[f32], scale: f32, cfg: &OverQConfig) -> Vec<f32> {
        use crate::overq::encode::int_codes;
        let c = x.len();
        let inv = 1.0f32 / scale;
        let bf = cfg.b() as f32;
        let (bb, qmax) = (cfg.b(), cfg.qmax());
        let (mut v, mut vf) = (vec![0i32; c], vec![0i32; c]);
        for (k, &xv) in x.iter().enumerate() {
            let (a, b) = int_codes(xv, inv, bf);
            v[k] = a;
            vf[k] = b;
        }
        let mut out = vec![0.0f32; c];
        let mut i = 0;
        while i < c {
            let vi = v[i];
            if vi > qmax {
                let mut j = 0;
                if cfg.range_overwrite {
                    for d in 1..=cfg.cascade {
                        if i + d < c && v[i + d] == 0 {
                            j = i + d;
                            break;
                        }
                    }
                }
                if j > 0 {
                    // covered outlier: full value in the widened range;
                    // intermediates shift over (clamped); the claimed
                    // zero stays zero
                    out[i] = vi.min(bb * bb - 1) as f32 * scale;
                    for k in (i + 1)..j {
                        out[k] = v[k].min(qmax) as f32 * scale;
                    }
                    out[j] = 0.0;
                    i = j + 1;
                } else {
                    out[i] = qmax as f32 * scale; // uncovered: clamp
                    i += 1;
                }
            } else if vi > 0 {
                if cfg.precision_overwrite && i + 1 < c && v[i + 1] == 0 {
                    let hi = (vf[i] >> cfg.bits).min(qmax);
                    let lo = vf[i] & qmax;
                    if lo > 0 {
                        out[i] = (hi as f32 + lo as f32 / bf) * scale;
                        out[i + 1] = 0.0;
                        i += 2;
                        continue;
                    }
                }
                out[i] = vi as f32 * scale;
                i += 1;
            } else {
                i += 1;
            }
        }
        out
    }

    #[test]
    fn prop_roundtrip_matches_normative_path() {
        check("encode→decode == normative fake-quant", 250, |rng: &mut Rng| {
            let cfg = OverQConfig {
                bits: 3 + rng.index(3) as u32, // 3..5
                cascade: 1 + rng.index(4),
                range_overwrite: rng.bool(0.75),
                precision_overwrite: rng.bool(0.5),
            };
            let rows = 1 + rng.index(4);
            let c = 1 + rng.index(48);
            let scale = 0.1 + rng.f32() * 0.4;
            let mut x = TensorF::zeros(&[rows, c]);
            for v in x.data.iter_mut() {
                *v = if rng.bool(0.45) {
                    0.0
                } else {
                    rng.normal().abs() * (if rng.bool(0.15) { 10.0 } else { 1.0 })
                };
            }
            let enc = encode_tensor(&x, scale, &cfg);
            let dec = fakequant_from_codes(&enc.codes, &enc.state, scale, &cfg);
            for r in 0..rows {
                let want = normative_fakequant(x.row(r), scale, &cfg);
                let got = &dec.data[r * c..(r + 1) * c];
                for k in 0..c {
                    assert!(
                        got[k] == want[k],
                        "row {r} slot {k}: decoded {} != normative {} \
                         (x={}, cfg={cfg:?})",
                        got[k],
                        want[k],
                        x.row(r)[k]
                    );
                }
            }
        });
    }

    #[test]
    fn prop_roundtrip_is_plain_quant_without_zeros_or_outliers() {
        // with nothing to overwrite, every mode degenerates to the plain
        // uniform quantizer
        check("roundtrip degenerates to uniform quant", 100, |rng: &mut Rng| {
            let bits = 3 + rng.index(3) as u32;
            for cfg in [
                OverQConfig::baseline(bits),
                OverQConfig::ro(bits, 1 + rng.index(4)),
                OverQConfig::full(bits, 1 + rng.index(4)),
            ] {
                let c = 1 + rng.index(32);
                let scale = 0.25f32;
                let qmax = cfg.qmax() as f32;
                let mut x = TensorF::zeros(&[1, c]);
                for v in x.data.iter_mut() {
                    // strictly in-range, never rounding to zero
                    *v = scale * (1.0 + rng.f32() * (qmax - 1.0));
                }
                let enc = encode_tensor(&x, scale, &cfg);
                let dec = fakequant_from_codes(&enc.codes, &enc.state, scale, &cfg);
                for (k, &xv) in x.data.iter().enumerate() {
                    let plain = (xv / scale + 0.5).floor().min(qmax) * scale;
                    assert_eq!(dec.data[k], plain, "slot {k} x={xv} cfg={cfg:?}");
                }
            }
        });
    }

    #[test]
    fn prop_packed_decode_matches_value_at_a_time() {
        use crate::overq::encode::pack_slots;
        check("decode_packed == decode_rows; unpack roundtrips", 200, |rng: &mut Rng| {
            let cfg = OverQConfig {
                bits: 2 + rng.index(7) as u32, // 2..=8
                cascade: 1 + rng.index(4),
                range_overwrite: rng.bool(0.7),
                precision_overwrite: rng.bool(0.5),
            };
            let rows = 1 + rng.index(5);
            let c = 1 + rng.index(70);
            let scale = 0.1 + rng.f32() * 0.4;
            let mut x = TensorF::zeros(&[rows, c]);
            for v in x.data.iter_mut() {
                *v = if rng.bool(0.45) {
                    0.0
                } else {
                    rng.normal().abs() * (if rng.bool(0.15) { 10.0 } else { 1.0 })
                };
            }
            let enc = encode_tensor(&x, scale, &cfg);
            let p = pack_slots(&enc.codes, &enc.state, cfg.bits);
            // lossless pack → unpack round-trip
            let (codes2, state2) = unpack_slots(&p);
            assert_eq!(codes2.data, enc.codes.data, "codes roundtrip cfg={cfg:?}");
            assert_eq!(state2.data, enc.state.data, "state roundtrip cfg={cfg:?}");
            // streaming packed decode is bit-identical to the value-at-a-
            // time path
            let want = decode_rows(&enc.codes, &enc.state, scale, &cfg);
            let got = decode_packed(&p, scale, &cfg);
            assert_eq!(got.data, want.data, "decode parity cfg={cfg:?}");
        });
    }

    #[test]
    fn packed_decode_empty_plane() {
        use crate::overq::encode::pack_slots;
        let cfg = OverQConfig::full(4, 2);
        let codes = TensorI::zeros(&[0, 7]);
        let state = Tensor::<SlotState>::zeros(&[0, 7]);
        let p = pack_slots(&codes, &state, cfg.bits);
        let dec = decode_packed(&p, 0.1, &cfg);
        assert_eq!(dec.numel(), 0);
        let (c2, s2) = unpack_slots(&p);
        assert_eq!(c2.numel(), 0);
        assert_eq!(s2.numel(), 0);
    }

    #[test]
    fn zeros_stay_zero() {
        let cfg = OverQConfig::full(4, 4);
        let x = TensorF::zeros(&[2, 8]);
        let enc = encode_tensor(&x, 0.1, &cfg);
        let dec = decode_rows(&enc.codes, &enc.state, 0.1, &cfg);
        assert!(dec.data.iter().all(|&v| v == 0.0));
    }
}
