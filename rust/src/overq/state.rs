//! OverQ slot states and mode configuration.

/// Slot state lane values (2 bits in hardware, matching the paper's
/// "one or two bits depending on which OverQ features are supported").
pub type SlotState = u8;

/// Slot holds its own value's low bits (weight `w_k`, factor `B`).
pub const NORM: SlotState = 0;
/// Slot holds the previous outlier's MSBs (weight `w_{k-1}`, factor `B²`).
pub const MSB: SlotState = 1;
/// Cascade: slot holds the previous original value (weight `w_{k-1}`, factor `B`).
pub const SHIFT: SlotState = 2;
/// Precision overwrite LSBs (weight `w_{k-1}`, factor `1`).
pub const LSB: SlotState = 3;

/// OverQ operating mode — a hardware configuration strap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OverQConfig {
    /// Activation bitwidth b (the paper evaluates 4 and 5).
    pub bits: u32,
    /// Cascade factor c; 1 = adjacent-only (no cascading).
    pub cascade: usize,
    /// Range overwrite enabled.
    pub range_overwrite: bool,
    /// Precision overwrite enabled.
    pub precision_overwrite: bool,
}

impl OverQConfig {
    /// Plain uniform quantization (no OverQ).
    pub fn baseline(bits: u32) -> Self {
        OverQConfig {
            bits,
            cascade: 1,
            range_overwrite: false,
            precision_overwrite: false,
        }
    }

    /// Range overwrite only, given cascade factor.
    pub fn ro(bits: u32, cascade: usize) -> Self {
        OverQConfig {
            bits,
            cascade,
            range_overwrite: true,
            precision_overwrite: false,
        }
    }

    /// Full OverQ: range + precision overwrite with cascading.
    pub fn full(bits: u32, cascade: usize) -> Self {
        OverQConfig {
            bits,
            cascade,
            range_overwrite: true,
            precision_overwrite: true,
        }
    }

    /// B = 2^bits.
    #[inline]
    pub fn b(&self) -> i32 {
        1 << self.bits
    }

    /// qmax = B - 1, the largest plain code.
    #[inline]
    pub fn qmax(&self) -> i32 {
        (1 << self.bits) - 1
    }

    /// Per-slot fixed-point factor (B for NORM/SHIFT, B² for MSB, 1 for LSB).
    #[inline]
    pub fn factor(&self, state: SlotState) -> i64 {
        let b = 1i64 << self.bits;
        match state {
            MSB => b * b,
            LSB => 1,
            _ => b,
        }
    }

    /// Bits of OverQ state per slot: 1 if only RO, 2 if PR supported
    /// (paper §3.1), 0 when OverQ is disabled entirely.
    pub fn state_bits(&self) -> u32 {
        match (self.range_overwrite, self.precision_overwrite) {
            (false, false) => 0,
            (true, false) => 1,
            _ => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors() {
        let c = OverQConfig::full(4, 4);
        assert_eq!(c.b(), 16);
        assert_eq!(c.qmax(), 15);
        assert_eq!(c.factor(NORM), 16);
        assert_eq!(c.factor(SHIFT), 16);
        assert_eq!(c.factor(MSB), 256);
        assert_eq!(c.factor(LSB), 1);
    }

    #[test]
    fn state_bits() {
        assert_eq!(OverQConfig::baseline(4).state_bits(), 0);
        assert_eq!(OverQConfig::ro(4, 4).state_bits(), 1);
        assert_eq!(OverQConfig::full(4, 4).state_bits(), 2);
    }
}
