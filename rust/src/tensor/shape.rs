//! Shape: dimension vector with row-major offset computation.

/// Row-major shape descriptor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape {
            dims: dims.to_vec(),
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major flat offset of a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0;
        for (i, &d) in self.dims.iter().enumerate() {
            debug_assert!(idx[i] < d, "index {} out of bound {} at axis {}", idx[i], d, i);
            off = off * d + idx[i];
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.offset(&[0, 0, 0]), 0);
        assert_eq!(s.offset(&[0, 0, 1]), 1);
        assert_eq!(s.offset(&[0, 1, 0]), 4);
        assert_eq!(s.offset(&[1, 0, 0]), 12);
        assert_eq!(s.offset(&[1, 2, 3]), 23);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.rank(), 3);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.offset(&[]), 0);
    }
}
