//! Dense row-major tensors (f32 / i32 / u8 / i8) — the crate's array type.
//!
//! Deliberately minimal: shape + contiguous Vec, with just the indexing
//! and reshaping the inference engine and simulators need. All heavy math
//! lives in specialized kernels (`nn::gemm`, `overq::dotprod`).

mod shape;
pub use shape::Shape;

/// A dense row-major tensor over element type `T`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub shape: Shape,
    pub data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;
pub type TensorU8 = Tensor<u8>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        Tensor {
            data: vec![T::default(); shape.numel()],
            shape,
        }
    }

    pub fn from_vec(dims: &[usize], data: Vec<T>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {:?} != data len {}",
            dims,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn full(dims: &[usize], v: T) -> Self {
        let shape = Shape::new(dims);
        Tensor {
            data: vec![v; shape.numel()],
            shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        let ns = Shape::new(dims);
        assert_eq!(ns.numel(), self.numel(), "reshape numel mismatch");
        self.shape = ns;
        self
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.shape.offset(idx)]
    }

    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        let o = self.shape.offset(idx);
        &mut self.data[o]
    }

    /// Borrow the last-axis row at the given outer index.
    pub fn row(&self, outer: usize) -> &[T] {
        let c = *self.dims().last().expect("rank >= 1");
        &self.data[outer * c..(outer + 1) * c]
    }

    pub fn row_mut(&mut self, outer: usize) -> &mut [T] {
        let c = *self.dims().last().expect("rank >= 1");
        &mut self.data[outer * c..(outer + 1) * c]
    }

    /// Number of last-axis rows (numel / last dim).
    pub fn num_rows(&self) -> usize {
        let c = *self.dims().last().expect("rank >= 1");
        self.numel() / c
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl Tensor<f32> {
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().map(|&x| x as f64).sum::<f64>() as f32 / self.numel() as f32
        }
    }

    pub fn std(&self) -> f32 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let m = self.mean() as f64;
        let v = self
            .data
            .iter()
            .map(|&x| (x as f64 - m).powi(2))
            .sum::<f64>()
            / self.data.len() as f64;
        v.sqrt() as f32
    }

    /// Fraction of exact zeros (the paper's `p0`).
    pub fn zero_frac(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.numel() as f64
    }

    pub fn allclose(&self, other: &Self, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::<f32>::zeros(&[2, 3, 4]);
        *t.at_mut(&[1, 2, 3]) = 5.0;
        assert_eq!(t.at(&[1, 2, 3]), 5.0);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.data[23], 5.0); // row-major last element
    }

    #[test]
    fn rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.row(0), &[1, 2, 3]);
        assert_eq!(t.row(1), &[4, 5, 6]);
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::<i32>::zeros(&[4, 6]).reshape(&[2, 12]);
        assert_eq!(t.dims(), &[2, 12]);
    }

    #[test]
    #[should_panic]
    fn reshape_mismatch_panics() {
        let _ = Tensor::<i32>::zeros(&[4, 6]).reshape(&[5, 5]);
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![0.0f32, 0.0, 2.0, -2.0]);
        assert_eq!(t.zero_frac(), 0.5);
        assert_eq!(t.mean(), 0.0);
        assert_eq!(t.max_abs(), 2.0);
        assert!((t.std() - 2.0f32.powi(2).sqrt() / 2f32.sqrt()).abs() < 1.0); // sanity
    }

    #[test]
    fn allclose_works() {
        let a = Tensor::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0f32, 2.0 + 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        let c = Tensor::from_vec(&[2], vec![1.0f32, 3.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-6));
    }
}
