//! Artifact manifest + model loading.
//!
//! `make artifacts` produces `artifacts/manifest.json` (see
//! `python/compile/aot.py`); this module resolves it into [`Engine`]s,
//! datasets and HLO paths.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::io::tensorfile::{self, TensorMap};
use crate::nn::{Engine, Graph};
use crate::quant::clip::ActStats;
use crate::tensor::TensorF;
use crate::util::json::{parse_file, Value};

/// Parsed artifact manifest.
pub struct Artifacts {
    pub root: PathBuf,
    pub manifest: Value,
}

/// A model loaded into the native engine.
pub struct LoadedModel {
    pub name: String,
    pub engine: Engine,
    /// Per-enc-point (mean, std, max) profiled at export time.
    pub enc_stats: Vec<ActStats>,
    /// fp32 eval accuracy recorded at export time.
    pub fp32_acc: f64,
}

/// Labeled image set.
pub struct Dataset {
    pub images: TensorF,
    pub labels: Vec<i32>,
}

impl Artifacts {
    /// Locate the artifacts directory: `$OVERQ_ARTIFACTS`, ./artifacts,
    /// or the crate-root artifacts dir.
    pub fn locate() -> Result<Artifacts> {
        let candidates = [
            std::env::var("OVERQ_ARTIFACTS").unwrap_or_default(),
            "artifacts".to_string(),
            format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ];
        for c in candidates.iter().filter(|c| !c.is_empty()) {
            let root = PathBuf::from(c);
            if root.join("manifest.json").exists() {
                return Artifacts::open(&root);
            }
        }
        anyhow::bail!("artifacts not found — run `make artifacts` first")
    }

    pub fn open(root: &Path) -> Result<Artifacts> {
        let manifest = parse_file(&root.join("manifest.json"))?;
        Ok(Artifacts {
            root: root.to_path_buf(),
            manifest,
        })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.manifest
            .at(&["models"])
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Load one model into the native engine.
    pub fn load_model(&self, name: &str) -> Result<LoadedModel> {
        let meta = self.manifest.at(&["models", name]);
        let graph_rel = meta.at(&["graph"]).as_str().context("model graph path")?;
        let weights_rel = meta
            .at(&["weights"])
            .as_str()
            .context("model weights path")?;
        let graph = Graph::load(&self.root.join(graph_rel))?;
        let weights = tensorfile::read(&self.root.join(weights_rel))?;
        let enc_stats = parse_enc_stats(&weights)?;
        let engine = Engine::new(graph, &weights)?;
        Ok(LoadedModel {
            name: name.to_string(),
            engine,
            enc_stats,
            fp32_acc: meta.at(&["fp32_acc"]).as_f64().unwrap_or(0.0),
        })
    }

    /// Load the eval or profile dataset.
    pub fn load_dataset(&self, which: &str) -> Result<Dataset> {
        let rel = self
            .manifest
            .at(&["data", which])
            .as_str()
            .with_context(|| format!("dataset {which}"))?;
        let t = tensorfile::read(&self.root.join(rel))?;
        Ok(Dataset {
            images: t["images"].as_f32()?.clone(),
            labels: t["labels"].as_i32()?.data.clone(),
        })
    }

    /// HLO artifact entries: (model, variant, batch, path).
    pub fn hlo_entries(&self) -> Vec<(String, String, usize, PathBuf)> {
        self.manifest
            .at(&["hlo"])
            .as_arr()
            .map(|arr| {
                arr.iter()
                    .map(|h| {
                        (
                            h.at(&["model"]).as_str().unwrap_or("").to_string(),
                            h.at(&["variant"]).as_str().unwrap_or("").to_string(),
                            h.at(&["batch"]).as_usize().unwrap_or(0),
                            self.root.join(h.at(&["path"]).as_str().unwrap_or("")),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Full manifest entry for an HLO artifact.
    pub fn hlo_meta(&self, model: &str, variant: &str, batch: usize) -> Option<&Value> {
        self.manifest.at(&["hlo"]).as_arr()?.iter().find(|h| {
            h.at(&["model"]).as_str() == Some(model)
                && h.at(&["variant"]).as_str() == Some(variant)
                && h.at(&["batch"]).as_usize() == Some(batch)
        })
    }

    pub fn testvectors(&self) -> Result<TensorMap> {
        let rel = self
            .manifest
            .at(&["testvectors"])
            .as_str()
            .context("testvectors path")?;
        tensorfile::read(&self.root.join(rel))
    }
}

fn parse_enc_stats(weights: &TensorMap) -> Result<Vec<ActStats>> {
    let t = weights
        .get("enc.stats")
        .context("weights missing enc.stats")?
        .as_f32()?;
    let e = t.dims()[0];
    Ok((0..e)
        .map(|i| ActStats {
            mean: t.data[i * 3],
            std: t.data[i * 3 + 1],
            max: t.data[i * 3 + 2],
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> Option<Artifacts> {
        Artifacts::locate().ok()
    }

    #[test]
    fn loads_all_models() {
        let Some(a) = arts() else { return };
        let names = a.model_names();
        assert_eq!(names.len(), 4);
        for name in names {
            let m = a.load_model(&name).unwrap();
            assert!(m.fp32_acc > 0.7, "{name}: {}", m.fp32_acc);
            assert_eq!(m.enc_stats.len(), m.engine.graph.num_enc_points());
            for s in &m.enc_stats {
                assert!(s.max > 0.0 && s.std > 0.0);
            }
        }
    }

    #[test]
    fn loads_datasets() {
        let Some(a) = arts() else { return };
        let ev = a.load_dataset("evalset").unwrap();
        assert_eq!(ev.images.dims()[0], ev.labels.len());
        assert_eq!(ev.images.dims()[3], 3);
        let pf = a.load_dataset("profileset").unwrap();
        assert!(pf.images.dims()[0] >= 256);
    }

    #[test]
    fn hlo_entries_exist() {
        let Some(a) = arts() else { return };
        let entries = a.hlo_entries();
        assert!(entries.len() >= 8);
        for (_, _, _, p) in entries {
            assert!(p.exists(), "{}", p.display());
        }
    }
}
