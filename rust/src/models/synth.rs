//! Synthetic model zoo — deterministic, artifact-free [`LoadedModel`]s.
//!
//! The real zoo loads graphs + trained weights exported by `make
//! artifacts`; that directory is not always present (CI, fresh clones).
//! These generators build the same graph IR in memory with seeded random
//! weights, then profile enc stats on the native synthetic image
//! distribution (`data::shapes`). Random weights make no accuracy
//! claims, but ReLU zeros and activation outliers — everything the
//! policy engine, coverage analysis and serving path exercise — behave
//! like the real thing, so tests and benches run anywhere.

use anyhow::Result;

use crate::data::shapes;
use crate::io::tensorfile::{AnyTensor, TensorMap};
use crate::nn::{Engine, Graph};
use crate::quant::clip::ActStats;
use crate::tensor::TensorF;
use crate::util::json::parse;
use crate::util::rng::Rng;

use super::zoo::LoadedModel;

/// Names [`synth_model`] accepts.
pub fn names() -> &'static [&'static str] {
    &["synth-tiny", "synth-cnn"]
}

/// Build a synthetic model by name. Deterministic in (name, seed).
pub fn synth_model(name: &str, seed: u64) -> Result<LoadedModel> {
    let graph_json = match name {
        // two quantized convs — the smallest multi-enc-point model
        "synth-tiny" => r#"{
          "name": "synth-tiny",
          "nodes": [
            {"id": 0, "op": "input", "in": []},
            {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
             "cin": 3, "cout": 8, "relu": true, "quant": false},
            {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 2,
             "cin": 8, "cout": 12, "relu": true, "quant": true, "enc": 0},
            {"id": 3, "op": "conv", "in": [2], "kh": 3, "kw": 3, "stride": 2,
             "cin": 12, "cout": 16, "relu": true, "quant": true, "enc": 1},
            {"id": 4, "op": "gap", "in": [3]},
            {"id": 5, "op": "dense", "in": [4], "cin": 16, "cout": 10}
          ]
        }"#,
        // four enc points over a conv stack with a pool — a "zoo model"
        // shaped like the artifact minis, sized for benches
        "synth-cnn" => r#"{
          "name": "synth-cnn",
          "nodes": [
            {"id": 0, "op": "input", "in": []},
            {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
             "cin": 3, "cout": 12, "relu": true, "quant": false},
            {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 1,
             "cin": 12, "cout": 16, "relu": true, "quant": true, "enc": 0},
            {"id": 3, "op": "maxpool", "in": [2]},
            {"id": 4, "op": "conv", "in": [3], "kh": 3, "kw": 3, "stride": 1,
             "cin": 16, "cout": 24, "relu": true, "quant": true, "enc": 1},
            {"id": 5, "op": "conv", "in": [4], "kh": 3, "kw": 3, "stride": 2,
             "cin": 24, "cout": 32, "relu": true, "quant": true, "enc": 2},
            {"id": 6, "op": "conv", "in": [5], "kh": 3, "kw": 3, "stride": 1,
             "cin": 32, "cout": 32, "relu": true, "quant": true, "enc": 3},
            {"id": 7, "op": "gap", "in": [6]},
            {"id": 8, "op": "dense", "in": [7], "cin": 32, "cout": 10}
          ]
        }"#,
        other => anyhow::bail!(
            "unknown synthetic model {other:?} (available: {:?})",
            names()
        ),
    };
    let graph = Graph::from_json(&parse(graph_json).map_err(|e| anyhow::anyhow!("{e}"))?)?;

    // seeded random weights, scaled to keep activations O(1)
    let mut rng = Rng::new(seed ^ 0x5F37_59DF);
    let mut weights = TensorMap::new();
    for node in &graph.nodes {
        use crate::nn::graph::Op;
        let (wdims, bdim): (Vec<usize>, usize) = match &node.op {
            Op::Conv {
                kh, kw, cin, cout, ..
            } => (vec![*kh, *kw, *cin, *cout], *cout),
            Op::Dense { cin, cout } => (vec![*cin, *cout], *cout),
            _ => continue,
        };
        let fan_in: usize = wdims[..wdims.len() - 1].iter().product();
        let std = (2.0 / fan_in as f32).sqrt(); // He init
        let mut w = TensorF::zeros(&wdims);
        for v in w.data.iter_mut() {
            *v = rng.normal() * std;
        }
        let mut b = TensorF::zeros(&[bdim]);
        for v in b.data.iter_mut() {
            *v = rng.normal() * 0.05;
        }
        weights.insert(format!("n{}.w", node.id), AnyTensor::F32(w));
        weights.insert(format!("n{}.b", node.id), AnyTensor::F32(b));
    }
    let engine = Engine::new(graph, &weights)?;

    // profile enc stats on the native synthetic image distribution
    let (images, labels) = shapes::gen_batch(seed, 0, 32);
    let srcs = engine.graph.enc_point_sources();
    let (_, taps) = engine.forward_f32(&images, &srcs)?;
    let enc_stats: Vec<ActStats> = taps.iter().map(ActStats::from_tensor).collect();
    let fp32_acc = engine.accuracy_f32(&images, &labels, 16)?;

    Ok(LoadedModel {
        name: name.to_string(),
        engine,
        enc_stats,
        fp32_acc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_well_formed() {
        let a = synth_model("synth-tiny", 7).unwrap();
        let b = synth_model("synth-tiny", 7).unwrap();
        assert_eq!(a.engine.graph.num_enc_points(), 2);
        assert_eq!(a.enc_stats.len(), 2);
        for (sa, sb) in a.enc_stats.iter().zip(&b.enc_stats) {
            assert_eq!(sa.mean, sb.mean);
            assert_eq!(sa.std, sb.std);
            assert_eq!(sa.max, sb.max);
        }
        // ReLU taps: nonnegative with real mass and real zeros
        for s in &a.enc_stats {
            assert!(s.max > 0.0 && s.std > 0.0);
        }
    }

    #[test]
    fn cnn_has_four_enc_points_and_runs() {
        let m = synth_model("synth-cnn", 1).unwrap();
        assert_eq!(m.engine.graph.num_enc_points(), 4);
        let (x, _) = shapes::gen_batch(2, 0, 2);
        let (logits, _) = m.engine.forward_f32(&x, &[]).unwrap();
        assert_eq!(logits.dims(), &[2, 10]);
    }

    #[test]
    fn unknown_name_errors() {
        assert!(synth_model("nope", 0).is_err());
    }
}
