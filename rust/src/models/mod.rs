//! Model zoo: load graphs + weights from the artifact directory, or
//! build synthetic artifact-free models for tests/benches.

pub mod synth;
pub mod zoo;

pub use synth::synth_model;
pub use zoo::{Artifacts, LoadedModel};
