//! Model zoo: load graphs + weights from the artifact directory.

pub mod zoo;

pub use zoo::{Artifacts, LoadedModel};
