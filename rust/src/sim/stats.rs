//! Simulation statistics.

/// Counters collected during a systolic simulation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Total cycles, including weight (re)loads.
    pub cycles: u64,
    /// Cycles spent loading weights.
    pub load_cycles: u64,
    /// MAC operations with a non-zero activation operand.
    pub useful_macs: u64,
    /// MAC slots occupied by zero activations (wasted work the paper's
    /// overwrite mechanism reclaims).
    pub zero_macs: u64,
    /// Products routed through the OverQ path (state != NORM).
    pub overq_macs: u64,
    /// Array size used.
    pub rows: usize,
    pub cols: usize,
}

impl SimStats {
    /// Useful-MAC utilization of the whole array-time volume.
    pub fn utilization(&self) -> f64 {
        let volume = self.cycles.saturating_sub(self.load_cycles) as f64
            * (self.rows * self.cols) as f64;
        if volume == 0.0 {
            0.0
        } else {
            self.useful_macs as f64 / volume
        }
    }

    /// Fraction of occupied slots that were zero-operand (reclaimable).
    pub fn zero_frac(&self) -> f64 {
        let tot = self.useful_macs + self.zero_macs;
        if tot == 0 {
            0.0
        } else {
            self.zero_macs as f64 / tot as f64
        }
    }

    pub fn merge(&mut self, o: &SimStats) {
        self.cycles += o.cycles;
        self.load_cycles += o.load_cycles;
        self.useful_macs += o.useful_macs;
        self.zero_macs += o.zero_macs;
        self.overq_macs += o.overq_macs;
        self.rows = o.rows;
        self.cols = o.cols;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_math() {
        let s = SimStats {
            cycles: 110,
            load_cycles: 10,
            useful_macs: 500,
            zero_macs: 500,
            rows: 4,
            cols: 4,
            ..Default::default()
        };
        assert!((s.utilization() - 500.0 / (100.0 * 16.0)).abs() < 1e-12);
        assert_eq!(s.zero_frac(), 0.5);
    }
}
