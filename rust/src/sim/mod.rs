//! Cycle-level weight-stationary systolic-array simulator (paper §4).
//!
//! The array spatially unrolls input channels along rows and output
//! channels along columns (Fig. 5a): activations stream left→right,
//! partial sums top→bottom, weights stay resident in the PEs. The OverQ
//! PE (Fig. 5c) extends the baseline PE with a 2-bit state register, a
//! weight mux reading the *row above* (the paper's weight copy between
//! physically adjacent PEs) and a shifter for the MSB/LSB product
//! alignment.
//!
//! The simulator is bit-exact against [`crate::overq::dotprod::gemm_overq`]
//! (and therefore against the Pallas kernel) and reports cycle counts and
//! PE utilization for the hardware-comparison benches.

pub mod array;
pub mod pe;
pub mod stats;

pub use array::{simulate_matmul, SystolicArray};
pub use stats::SimStats;
