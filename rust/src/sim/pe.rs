//! Processing elements: baseline MAC PE and the OverQ-extended PE.

use crate::overq::{OverQConfig, SlotState, LSB, MSB, NORM};

/// Activation lane travelling through a row: code + OverQ state.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ActLane {
    pub code: i32,
    pub state: SlotState,
    /// True when this lane carries a real (scheduled) value.
    pub valid: bool,
}

/// One processing element (weight-stationary).
#[derive(Clone, Copy, Debug, Default)]
pub struct Pe {
    /// Resident weight.
    pub weight: i32,
    /// Activation register (flows to the right neighbour next cycle).
    pub act: ActLane,
}

impl Pe {
    /// Compute this PE's product given the weight of the PE in the row
    /// above (`weight_up`, the paper's weight-copy wire). The product is
    /// in B-fixed-point: NORM/SHIFT ×B, MSB ×B² (left shift), LSB ×1
    /// (right shift) — shifts are the OverQ PE's shifter.
    #[inline]
    pub fn product(&self, weight_up: i32, cfg: &OverQConfig) -> i64 {
        if !self.act.valid || self.act.code == 0 {
            return 0;
        }
        let w = if self.act.state != NORM {
            weight_up
        } else {
            self.weight
        } as i64;
        let f = match self.act.state {
            MSB => (1i64 << cfg.bits) << cfg.bits,
            LSB => 1,
            _ => 1i64 << cfg.bits,
        };
        self.act.code as i64 * f * w
    }

    /// Baseline PE: ignores the state lane entirely (plain MAC, ×B for
    /// scale compatibility with the OverQ datapath).
    #[inline]
    pub fn product_baseline(&self, cfg: &OverQConfig) -> i64 {
        if !self.act.valid {
            return 0;
        }
        self.act.code as i64 * (1i64 << cfg.bits) * self.weight as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn products() {
        let cfg = OverQConfig::full(4, 4);
        let mut pe = Pe {
            weight: 3,
            act: ActLane {
                code: 5,
                state: NORM,
                valid: true,
            },
        };
        assert_eq!(pe.product(7, &cfg), 5 * 16 * 3);
        pe.act.state = MSB;
        assert_eq!(pe.product(7, &cfg), 5 * 256 * 7);
        pe.act.state = LSB;
        assert_eq!(pe.product(7, &cfg), 5 * 7);
        pe.act.valid = false;
        assert_eq!(pe.product(7, &cfg), 0);
    }

    #[test]
    fn zero_code_skips() {
        let cfg = OverQConfig::full(4, 4);
        let pe = Pe {
            weight: 3,
            act: ActLane {
                code: 0,
                state: NORM,
                valid: true,
            },
        };
        assert_eq!(pe.product(9, &cfg), 0);
    }
}
