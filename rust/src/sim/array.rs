//! The systolic array: cycle-by-cycle simulation with tiling.

use anyhow::{ensure, Result};

use crate::overq::{OverQConfig, SlotState, NORM};
use crate::tensor::{Tensor, TensorI};

use super::pe::{ActLane, Pe};
use super::stats::SimStats;

/// A weight-stationary R×C array.
pub struct SystolicArray {
    pub rows: usize,
    pub cols: usize,
    /// OverQ PEs when true; baseline PEs ignore the state lane.
    pub overq_pes: bool,
}

impl SystolicArray {
    pub fn new(rows: usize, cols: usize, overq_pes: bool) -> Self {
        SystolicArray {
            rows,
            cols,
            overq_pes,
        }
    }

    /// Simulate one (M,K)×(K,N) OverQ matmul, tiling K over rows and N
    /// over columns. `chan_block` is the channel-block size of the
    /// encoding (chains never cross block boundaries); K-tile edges are
    /// aligned to it so the weight-copy wire never needs to reach across
    /// a tile reload — the same constraint real hardware has.
    ///
    /// Returns the (M,N) fixed-point accumulator plus cycle statistics.
    pub fn run(
        &self,
        codes: &TensorI,
        state: &Tensor<SlotState>,
        w: &TensorI,
        cfg: &OverQConfig,
        chan_block: usize,
    ) -> Result<(TensorI, SimStats)> {
        let (m, k) = (codes.dims()[0], codes.dims()[1]);
        let n = w.dims()[1];
        ensure!(w.dims()[0] == k, "K mismatch");
        ensure!(chan_block > 0 && k % chan_block == 0, "K not block-aligned");
        // K-tile size: largest multiple of chan_block that fits the rows
        // (or the full block if a single block exceeds the array height).
        let ktile = if chan_block >= self.rows {
            chan_block
        } else {
            (self.rows / chan_block) * chan_block
        };
        let mut out = TensorI::zeros(&[m, n]);
        let mut acc64 = vec![0i64; m * n];
        let mut stats = SimStats {
            rows: self.rows,
            cols: self.cols,
            ..Default::default()
        };

        let mut k0 = 0;
        while k0 < k {
            let kt = ktile.min(k - k0);
            let mut n0 = 0;
            while n0 < n {
                let nt = self.cols.min(n - n0);
                self.run_tile(
                    codes, state, w, cfg, k0, kt, n0, nt, &mut acc64, n, m, &mut stats,
                )?;
                n0 += nt;
            }
            k0 += kt;
        }
        for (o, &a) in out.data.iter_mut().zip(&acc64) {
            *o = i32::try_from(a).map_err(|_| anyhow::anyhow!("accumulator overflow"))?;
        }
        Ok((out, stats))
    }

    /// Cycle-accurate simulation of one tile.
    #[allow(clippy::too_many_arguments)]
    fn run_tile(
        &self,
        codes: &TensorI,
        state: &Tensor<SlotState>,
        w: &TensorI,
        cfg: &OverQConfig,
        k0: usize,
        kt: usize,
        n0: usize,
        nt: usize,
        acc: &mut [i64],
        n_stride: usize,
        m: usize,
        stats: &mut SimStats,
    ) -> Result<()> {
        let k_full = codes.dims()[1];
        // Weight load: one column broadcast per cycle (kt cycles).
        stats.load_cycles += kt as u64;
        stats.cycles += kt as u64;
        let mut pes: Vec<Pe> = vec![Pe::default(); kt * nt];
        for kk in 0..kt {
            for nn in 0..nt {
                pes[kk * nt + nn].weight = w.data[(k0 + kk) * w.dims()[1] + (n0 + nn)];
            }
        }
        // Streaming phase: input vector m enters row kk at cycle m + kk;
        // it reaches column nn at cycle m + kk + nn. Partial sums flow
        // down; the value for (m, nn) passes PE(kk, nn) at exactly that
        // cycle, so we can accumulate during the PE's compute without
        // modelling the psum registers explicitly (their timing is what
        // the cycle count formula below captures).
        let total = m + kt + nt - 1;
        stats.cycles += total as u64;
        // psum wavefront: psum[(mv, nn)] accumulated as its wave passes rows
        for cycle in 0..total {
            // shift activations right (process columns right-to-left)
            for kk in 0..kt {
                for nn in (1..nt).rev() {
                    pes[kk * nt + nn].act = pes[kk * nt + nn - 1].act;
                }
                // feed column 0 of row kk with vector mv = cycle - kk
                let mv = cycle as i64 - kk as i64;
                pes[kk * nt].act = if mv >= 0 && (mv as usize) < m {
                    ActLane {
                        code: codes.data[mv as usize * k_full + k0 + kk],
                        state: state.data[mv as usize * k_full + k0 + kk],
                        valid: true,
                    }
                } else {
                    ActLane::default()
                };
            }
            // compute: each PE contributes to the psum wave passing it
            for kk in 0..kt {
                for nn in 0..nt {
                    let pe = &pes[kk * nt + nn];
                    if !pe.act.valid {
                        continue;
                    }
                    let mv = cycle as i64 - kk as i64 - nn as i64;
                    if mv < 0 || mv as usize >= m {
                        continue;
                    }
                    // the paper's weight-copy wire: row above in the SAME
                    // k-tile (tile edges are block-aligned so chains
                    // never need a weight from the previous tile)
                    let weight_up = if kk > 0 {
                        pes[(kk - 1) * nt + nn].weight
                    } else {
                        0
                    };
                    debug_assert!(
                        !(self.overq_pes && kk == 0 && pe.act.state != NORM),
                        "chain crossed a tile boundary"
                    );
                    let p = if self.overq_pes {
                        pe.product(weight_up, cfg)
                    } else {
                        pe.product_baseline(cfg)
                    };
                    if pe.act.code != 0 {
                        stats.useful_macs += 1;
                        if pe.act.state != NORM {
                            stats.overq_macs += 1;
                        }
                    } else {
                        stats.zero_macs += 1;
                    }
                    acc[mv as usize * n_stride + (n0 + nn)] += p;
                }
            }
        }
        Ok(())
    }
}

/// Convenience wrapper: simulate with a default-sized array.
pub fn simulate_matmul(
    codes: &TensorI,
    state: &Tensor<SlotState>,
    w: &TensorI,
    cfg: &OverQConfig,
    chan_block: usize,
    rows: usize,
    cols: usize,
) -> Result<(TensorI, SimStats)> {
    SystolicArray::new(rows, cols, true).run(codes, state, w, cfg, chan_block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overq::dotprod::{gemm_overq, roll_weights};
    use crate::overq::encode_tensor;
    use crate::tensor::TensorF;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rand_case(rng: &mut Rng, m: usize, blocks: usize, c: usize, n: usize) -> (TensorF, TensorI) {
        let k = blocks * c;
        let mut x = TensorF::zeros(&[m, k]);
        for v in x.data.iter_mut() {
            *v = if rng.bool(0.5) {
                0.0
            } else {
                rng.normal().abs() * (if rng.bool(0.1) { 8.0 } else { 1.0 })
            };
        }
        let mut w = TensorI::zeros(&[k, n]);
        for v in w.data.iter_mut() {
            *v = rng.range(-127, 128) as i32;
        }
        (x, w)
    }

    #[test]
    fn prop_sim_bit_exact_with_gemm() {
        check("systolic == gemm_overq", 40, |rng: &mut Rng| {
            let (m, blocks, c, n) = (
                1 + rng.index(6),
                1 + rng.index(3),
                4 + rng.index(8),
                1 + rng.index(10),
            );
            let cfg = OverQConfig::full(4, 3);
            let (x, w) = rand_case(rng, m, blocks, c, n);
            // encode per channel block (mirrors conv im2col structure):
            // encode_tensor works on the last axis, so encode a reshaped
            // (m*blocks, c) view.
            let k = blocks * c;
            let xb = x.clone().reshape(&[m * blocks, c]);
            let enc = encode_tensor(&xb, 0.3, &cfg);
            let codes = enc.codes.reshape(&[m, k]);
            let state = enc.state.reshape(&[m, k]);
            let wroll = roll_weights(&w);
            let mut want = TensorI::zeros(&[m, n]);
            gemm_overq(&codes, &state, &w, &wroll, &cfg, &mut want);
            // array smaller than the problem → multiple tiles
            let arr = SystolicArray::new(c * (1 + rng.index(2)), 1 + rng.index(6), true);
            let (got, stats) = arr.run(&codes, &state, &w, &cfg, c).unwrap();
            assert_eq!(got.data, want.data);
            assert!(stats.cycles > 0);
            assert!(stats.useful_macs + stats.zero_macs > 0);
        });
    }

    #[test]
    fn baseline_pe_matches_plain_quant() {
        // baseline PEs on baseline-encoded codes == clamped int matmul
        let mut rng = Rng::new(7);
        let (x, w) = rand_case(&mut rng, 5, 2, 8, 6);
        let cfg = OverQConfig::baseline(4);
        let xb = x.clone().reshape(&[10, 8]);
        let enc = encode_tensor(&xb, 0.3, &cfg);
        let codes = enc.codes.reshape(&[5, 16]);
        let state = enc.state.reshape(&[5, 16]);
        let arr = SystolicArray::new(8, 4, false);
        let (got, _) = arr.run(&codes, &state, &w, &cfg, 8).unwrap();
        for i in 0..5 {
            for j in 0..6 {
                let want: i64 = (0..16)
                    .map(|kk| codes.data[i * 16 + kk] as i64 * 16 * w.data[kk * 6 + j] as i64)
                    .sum();
                assert_eq!(got.data[i * 6 + j] as i64, want);
            }
        }
    }

    #[test]
    fn cycle_count_formula() {
        // single tile: load kt + (m + kt + nt - 1) streaming cycles
        let cfg = OverQConfig::baseline(4);
        let codes = TensorI::zeros(&[10, 8]);
        let state = Tensor::<SlotState>::zeros(&[10, 8]);
        let w = TensorI::zeros(&[8, 4]);
        let arr = SystolicArray::new(8, 4, true);
        let (_, stats) = arr.run(&codes, &state, &w, &cfg, 8).unwrap();
        assert_eq!(stats.load_cycles, 8);
        assert_eq!(stats.cycles, 8 + (10 + 8 + 4 - 1) as u64);
    }

    #[test]
    fn utilization_improves_with_longer_m() {
        let cfg = OverQConfig::baseline(4);
        let w = TensorI::full(&[8, 4], 1);
        let arr = SystolicArray::new(8, 4, true);
        let mk = |m: usize| {
            let codes = TensorI::full(&[m, 8], 1);
            let state = Tensor::<SlotState>::zeros(&[m, 8]);
            arr.run(&codes, &state, &w, &cfg, 8).unwrap().1.utilization()
        };
        assert!(mk(64) > mk(4));
    }
}
