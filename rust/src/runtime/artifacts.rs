//! Executable cache keyed by (model, variant, batch).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::models::Artifacts;

use super::pjrt::{Executable, Runtime};

/// Lazily-compiled executable cache over the artifact manifest.
///
/// Construction only indexes the manifest; the PJRT client is created on
/// the first [`ExecutableCache::get`], so a server built without the
/// `pjrt` feature (or without HLO artifacts) can still run native-engine
/// variants through the same worker.
pub struct ExecutableCache {
    runtime: Option<Runtime>,
    paths: HashMap<(String, String, usize), PathBuf>,
    cache: HashMap<(String, String, usize), Executable>,
}

impl ExecutableCache {
    pub fn new(arts: &Artifacts) -> Result<ExecutableCache> {
        let mut paths = HashMap::new();
        for (model, variant, batch, path) in arts.hlo_entries() {
            paths.insert((model, variant, batch), path);
        }
        Ok(ExecutableCache {
            runtime: None,
            paths,
            cache: HashMap::new(),
        })
    }

    /// An empty cache (no artifacts at all): every lookup misses.
    pub fn empty() -> ExecutableCache {
        ExecutableCache {
            runtime: None,
            paths: HashMap::new(),
            cache: HashMap::new(),
        }
    }

    /// Batch sizes available for (model, variant), ascending.
    pub fn batch_sizes(&self, model: &str, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .paths
            .keys()
            .filter(|(m, va, _)| m == model && va == variant)
            .map(|&(_, _, b)| b)
            .collect();
        v.sort();
        v
    }

    /// Get (compiling on first use) the executable for a key.
    pub fn get(&mut self, model: &str, variant: &str, batch: usize) -> Result<&Executable> {
        let key = (model.to_string(), variant.to_string(), batch);
        if !self.cache.contains_key(&key) {
            let path = self
                .paths
                .get(&key)
                .with_context(|| format!("no HLO artifact for {model}/{variant}/b{batch}"))?;
            if self.runtime.is_none() {
                self.runtime = Some(Runtime::cpu()?);
            }
            let exe = self.runtime.as_ref().unwrap().load_hlo_text(path)?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }
}
