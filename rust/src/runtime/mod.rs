//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). The interchange format
//! is HLO *text*, not serialized protos — jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot.py).

pub mod artifacts;
pub mod pjrt;

pub use pjrt::{Executable, Runtime};
