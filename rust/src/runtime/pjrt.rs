//! PJRT client + executable wrappers.
//!
//! The real implementation needs the external `xla` crate and is gated
//! behind the `pjrt` cargo feature. Without it this module compiles to a
//! stub with the same API whose constructors return errors — callers
//! (the executable cache, the serving worker) treat that exactly like
//! "artifacts not built" and fall back to the native engine backend.

use std::path::Path;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::tensor::{TensorF, TensorI};

/// A PJRT client (CPU).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

/// A compiled executable with its expected input arity.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with f32/i32 tensor inputs; returns the first output of
    /// the 1-tuple (aot.py lowers with `return_tuple=True`) as f32.
    pub fn run_f32(&self, inputs: &[Input]) -> Result<TensorF> {
        let lits: Vec<xla::Literal> = inputs.iter().map(Input::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let out = result.to_tuple1().context("unwrap 1-tuple")?;
        let shape = out.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<f32>().context("read f32 output")?;
        Ok(TensorF::from_vec(&dims, data))
    }

    /// Execute and return an i32 output (kernel artifacts).
    pub fn run_i32(&self, inputs: &[Input]) -> Result<TensorI> {
        let lits: Vec<xla::Literal> = inputs.iter().map(Input::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let out = result.to_tuple1().context("unwrap 1-tuple")?;
        let shape = out.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<i32>().context("read i32 output")?;
        Ok(TensorI::from_vec(&dims, data))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        anyhow::bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (rebuild with `--features pjrt` and the xla crate installed)"
        )
    }

    pub fn platform(&self) -> String {
        "disabled".into()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run_f32(&self, _inputs: &[Input]) -> Result<TensorF> {
        anyhow::bail!("PJRT executable unavailable: built without the `pjrt` feature")
    }

    pub fn run_i32(&self, _inputs: &[Input]) -> Result<TensorI> {
        anyhow::bail!("PJRT executable unavailable: built without the `pjrt` feature")
    }
}

/// Typed input tensor for [`Executable::run_f32`].
pub enum Input {
    F32(TensorF),
    I32(TensorI),
}

#[cfg(feature = "pjrt")]
impl Input {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(t) => {
                let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data).reshape(&dims)?
            }
            Input::I32(t) => {
                let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data).reshape(&dims)?
            }
        })
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`,
    // the `pjrt` feature and a working libxla_extension).
}
