//! im2col patch extraction (XLA "SAME" convention) — generic over dtype
//! so fp32 activations and OverQ (codes, state) planes share the path.
//!
//! Padding follows XLA/TF SAME: `pad_lo = pad_total / 2`, which differs
//! from naive symmetric padding for stride 2 on even sizes. Mirrors
//! `python/compile/model.py::_im2col`; columns are ordered (dy, dx) outer
//! with channels innermost per tap, matching the flattened weight layout
//! (kh, kw, cin, cout) → (K, cout).

use crate::tensor::Tensor;

/// Output spatial size for SAME padding.
pub fn same_out(h: usize, stride: usize) -> usize {
    h.div_ceil(stride)
}

/// Extract patches from (N, H, W, C) into (N*OH*OW, kh*kw*C).
/// Out-of-bounds taps read `T::default()` (zero — a real zero in OverQ
/// terms, claimable like any ReLU zero in the padded stream).
pub fn im2col<T: Copy + Default>(
    x: &Tensor<T>,
    kh: usize,
    kw: usize,
    stride: usize,
) -> (Tensor<T>, usize, usize) {
    let (n, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2]);
    let oh = same_out(h, stride);
    let ow = same_out(w, stride);
    let k = kh * kw * x.dims()[3];
    let mut out = Tensor::<T>::zeros(&[n * oh * ow, k]);
    im2col_into(x, kh, kw, stride, &mut out);
    (out, oh, ow)
}

/// [`im2col`] into a caller-provided `(N*OH*OW, kh*kw*C)` tensor — the
/// arena-backed engine path. Padding taps are written explicitly, so
/// `out` does not need to be pre-zeroed (it may be a recycled buffer).
pub fn im2col_into<T: Copy + Default>(
    x: &Tensor<T>,
    kh: usize,
    kw: usize,
    stride: usize,
    out: &mut Tensor<T>,
) -> (usize, usize) {
    let (n, h, w, c) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let oh = same_out(h, stride);
    let ow = same_out(w, stride);
    let pth = ((oh - 1) * stride + kh).saturating_sub(h);
    let ptw = ((ow - 1) * stride + kw).saturating_sub(w);
    let (ph, pw) = (pth / 2, ptw / 2);
    let k = kh * kw * c;
    assert_eq!(out.dims(), &[n * oh * ow, k], "im2col_into out dims");
    let zero = T::default();
    for img in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((img * oh) + oy) * ow + ox;
                let base = row * k;
                for dy in 0..kh {
                    let iy = (oy * stride + dy) as i64 - ph as i64;
                    for dx in 0..kw {
                        let ix = (ox * stride + dx) as i64 - pw as i64;
                        let off = base + (dy * kw + dx) * c;
                        if iy >= 0 && iy < h as i64 && ix >= 0 && ix < w as i64 {
                            let src = ((img * h + iy as usize) * w + ix as usize) * c;
                            out.data[off..off + c].copy_from_slice(&x.data[src..src + c]);
                        } else {
                            out.data[off..off + c].fill(zero);
                        }
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Direct (non-im2col) convolution oracles for the differential harness.
pub mod reference {
    use super::same_out;
    use crate::tensor::TensorF;

    /// Naive direct SAME convolution over (N, H, W, Cin) with a
    /// (kh·kw·cin, cout)-flattened weight — the test oracle for the
    /// im2col + blocked-GEMM lowering.
    pub fn conv2d(
        x: &TensorF,
        w: &[f32],
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        stride: usize,
    ) -> TensorF {
        let (n, h, wd, _) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        let oh = same_out(h, stride);
        let ow = same_out(wd, stride);
        let pth = ((oh - 1) * stride + kh).saturating_sub(h);
        let ptw = ((ow - 1) * stride + kw).saturating_sub(wd);
        let (ph, pw) = (pth / 2, ptw / 2);
        let mut out = TensorF::zeros(&[n, oh, ow, cout]);
        for img in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for oc in 0..cout {
                        let mut acc = 0f32;
                        for dy in 0..kh {
                            for dx in 0..kw {
                                let iy = (oy * stride + dy) as i64 - ph as i64;
                                let ix = (ox * stride + dx) as i64 - pw as i64;
                                if iy < 0 || ix < 0 || iy >= h as i64 || ix >= wd as i64 {
                                    continue;
                                }
                                for ic in 0..cin {
                                    acc += x.at(&[img, iy as usize, ix as usize, ic])
                                        * w[(((dy * kw) + dx) * cin + ic) * cout + oc];
                                }
                            }
                        }
                        *out.at_mut(&[img, oy, ox, oc]) = acc;
                    }
                }
            }
        }
        out
    }
}

/// Gather columns of an im2col matrix by a per-channel index (OCS):
/// expands the channel dimension inside every (dy, dx) tap.
pub fn gather_channels<T: Copy + Default>(
    cols: &Tensor<T>,
    c: usize,
    taps: usize,
    gather: &[usize],
) -> Tensor<T> {
    let m = cols.dims()[0];
    let cg = gather.len();
    let mut out = Tensor::<T>::zeros(&[m, taps * cg]);
    for r in 0..m {
        let src = cols.row(r);
        let dst = out.row_mut(r);
        for t in 0..taps {
            for (gi, &g) in gather.iter().enumerate() {
                dst[t * cg + gi] = src[t * c + g];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    #[test]
    fn im2col_matmul_matches_naive_conv() {
        let mut rng = Rng::new(1);
        for &(h, stride, kh) in &[(8usize, 1usize, 3usize), (8, 2, 3), (7, 2, 3), (8, 1, 1), (8, 2, 1)] {
            let (cin, cout, n) = (5, 4, 2);
            let mut x = TensorF::zeros(&[n, h, h, cin]);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let mut w = vec![0f32; kh * kh * cin * cout];
            for v in w.iter_mut() {
                *v = rng.normal();
            }
            let want = reference::conv2d(&x, &w, kh, kh, cin, cout, stride);
            let (cols, oh, ow) = im2col(&x, kh, kh, stride);
            let k = kh * kh * cin;
            let mut got = TensorF::zeros(&[n, oh, ow, cout]);
            for r in 0..cols.dims()[0] {
                for oc in 0..cout {
                    let mut acc = 0f32;
                    for kk in 0..k {
                        acc += cols.data[r * k + kk] * w[kk * cout + oc];
                    }
                    got.data[r * cout + oc] = acc;
                }
            }
            assert!(
                got.allclose(&want, 1e-5, 1e-5),
                "mismatch h={h} stride={stride} kh={kh}"
            );
        }
    }

    #[test]
    fn im2col_into_overwrites_dirty_buffer() {
        // a recycled arena buffer full of garbage must come out identical
        // to the fresh-allocation path, padding included
        let mut rng = Rng::new(9);
        for &(h, stride, kh) in &[(7usize, 2usize, 3usize), (8, 1, 3)] {
            let mut x = TensorF::zeros(&[2, h, h, 3]);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let (want, oh, ow) = im2col(&x, kh, kh, stride);
            let mut dirty = TensorF::full(want.dims(), f32::NAN);
            let (oh2, ow2) = im2col_into(&x, kh, kh, stride, &mut dirty);
            assert_eq!((oh, ow), (oh2, ow2));
            assert_eq!(dirty.data, want.data, "h={h} stride={stride} kh={kh}");
        }
    }

    #[test]
    fn gather_expands_channels() {
        // 1x1 kernel, 3 channels, gather duplicates channel 1
        let x = Tensor::from_vec(&[1, 1, 1, 3], vec![10, 20, 30]);
        let (cols, _, _) = im2col(&x, 1, 1, 1);
        let g = gather_channels(&cols, 3, 1, &[0, 1, 1, 2]);
        assert_eq!(g.row(0), &[10, 20, 20, 30]);
    }

    #[test]
    fn padding_is_zero() {
        let x = TensorF::full(&[1, 2, 2, 1], 1.0);
        let (cols, oh, ow) = im2col(&x, 3, 3, 1);
        assert_eq!((oh, ow), (2, 2));
        // top-left patch has 5 in-bounds ones, 4 padded zeros
        let s: f32 = cols.row(0).iter().sum();
        assert_eq!(s, 4.0); // (2x2 visible at kernel positions) — row 0 covers indices (-1..1)^2
    }
}
