//! Precomputed execution plan + reusable buffer arena for the native
//! engine — the tract-style "plan once, run many" split.
//!
//! [`ExecPlan::build`] walks a graph once per (model, input-shape) pair
//! and records everything the per-request loop would otherwise recompute:
//! the topological step order, every node's output shape, and per-step
//! *flush lists* — the nodes whose buffers die after that step (their
//! last reader just ran) and can go back to the [`Arena`].
//!
//! The [`Arena`] is a per-request pool of typed buffers (f32 / i32 / u8
//! slot-state / u64 packed-word). Buffers are recycled best-fit by
//! capacity and zero-filled on take, so kernels keep their "caller
//! zeroes the output" contract; `peak_bytes` tracks the high-water mark,
//! which the plan tests bound by [`ExecPlan::naive_bytes`] (what
//! per-layer allocation would have touched). Engines keep a pool of
//! arenas, so steady-state serving does no tensor allocation at all.
//! See `docs/runtime.md` for the lifecycle diagram.

use anyhow::{ensure, Result};

use crate::tensor::{Tensor, TensorF, TensorI};

use super::conv::same_out;
use super::graph::{Graph, Op};

/// One model × input-shape execution schedule.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// Input dims (N, H, W, C) this plan was built for.
    pub in_dims: Vec<usize>,
    /// Node ids in execution order. Graphs are dense SSA, so Kahn's
    /// algorithm with a min-id tie-break yields the identity order —
    /// preserving the span/counter emission order of the unplanned path.
    pub order: Vec<usize>,
    /// `flush[step]` = node ids whose output buffer is dead once the
    /// node at `step` has run (its last reader). The logits node is
    /// never flushed; readerless interior nodes flush at their own step.
    pub flush: Vec<Vec<usize>>,
    /// Inferred output dims per node id.
    pub dims: Vec<Vec<usize>>,
    /// f32 bytes the unplanned per-layer-allocation path touches for one
    /// request: every node output plus every conv's im2col matrix. The
    /// arena's `peak_bytes` must stay at or below this.
    pub naive_bytes: usize,
}

impl ExecPlan {
    /// Build the schedule for `graph` at input shape `in_dims` (N,H,W,C).
    pub fn build(graph: &Graph, in_dims: &[usize]) -> Result<ExecPlan> {
        let nn = graph.nodes.len();
        ensure!(nn > 0, "empty graph");
        ensure!(in_dims.len() == 4, "input must be (N, H, W, C)");
        let mut indeg = vec![0usize; nn];
        let mut readers: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for node in &graph.nodes {
            indeg[node.id] = node.inputs.len();
            for &s in &node.inputs {
                readers[s].push(node.id); // multiplicity kept (Add x+x)
            }
        }
        let mut ready: Vec<usize> = (0..nn).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(nn);
        while !ready.is_empty() {
            let nid = ready.remove(0); // smallest ready id
            order.push(nid);
            for &r in &readers[nid] {
                indeg[r] -= 1;
                if indeg[r] == 0 {
                    let pos = ready.partition_point(|&x| x < r);
                    ready.insert(pos, r);
                }
            }
        }
        ensure!(order.len() == nn, "graph has a cycle");

        // shape inference along the order
        let mut dims: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for &nid in &order {
            let node = &graph.nodes[nid];
            let d = match &node.op {
                Op::Input => in_dims.to_vec(),
                Op::Conv { stride, cout, .. } => {
                    let s = &dims[node.inputs[0]];
                    ensure!(s.len() == 4, "conv input rank");
                    vec![s[0], same_out(s[1], *stride), same_out(s[2], *stride), *cout]
                }
                Op::Add { .. } => {
                    let (a, b) = (&dims[node.inputs[0]], &dims[node.inputs[1]]);
                    ensure!(a == b, "add operand dims");
                    a.clone()
                }
                Op::Concat => {
                    let s0 = &dims[node.inputs[0]];
                    ensure!(s0.len() == 4, "concat input rank");
                    let c = node.inputs.iter().map(|&i| dims[i][3]).sum();
                    vec![s0[0], s0[1], s0[2], c]
                }
                Op::MaxPool | Op::AvgPool => {
                    let s = &dims[node.inputs[0]];
                    ensure!(s.len() == 4, "pool input rank");
                    vec![s[0], s[1] / 2, s[2] / 2, s[3]]
                }
                Op::Gap => {
                    let s = &dims[node.inputs[0]];
                    vec![s[0], *s.last().unwrap()]
                }
                Op::Dense { cout, .. } => vec![dims[node.inputs[0]][0], *cout],
            };
            dims[nid] = d;
        }

        // flush lists: each buffer dies at its last reader's step
        let mut step_of = vec![0usize; nn];
        for (s, &nid) in order.iter().enumerate() {
            step_of[nid] = s;
        }
        let logits = *order.last().unwrap();
        let mut flush: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for v in 0..nn {
            if v == logits {
                continue; // the result must outlive the plan run
            }
            let fs = readers[v]
                .iter()
                .map(|&r| step_of[r])
                .max()
                .unwrap_or(step_of[v]);
            flush[fs].push(v);
        }

        // what the per-layer-allocation path would touch (f32 path)
        let mut naive = 0usize;
        for node in &graph.nodes {
            naive += dims[node.id].iter().product::<usize>();
            if let Op::Conv { kh, kw, cin, .. } = &node.op {
                let d = &dims[node.id];
                naive += d[0] * d[1] * d[2] * kh * kw * cin;
            }
        }
        let naive_bytes = naive * std::mem::size_of::<f32>();

        Ok(ExecPlan {
            in_dims: in_dims.to_vec(),
            order,
            flush,
            dims,
            naive_bytes,
        })
    }
}

/// Recycle a free-listed buffer: best fit by capacity (the smallest one
/// that already holds `len`), else grow the largest, else allocate.
/// Always returns a zero-filled (`T::default()`) buffer of exactly `len`.
fn take_vec<T: Copy + Default>(free: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for i in 0..free.len() {
        let cap = free[i].capacity();
        let better = match best {
            None => true,
            Some(b) => free[b].capacity() > cap,
        };
        if cap >= len && better {
            best = Some(i);
        }
    }
    let mut v = match best {
        Some(i) => free.swap_remove(i),
        None if free.is_empty() => Vec::with_capacity(len),
        None => {
            let mut bi = 0;
            for i in 1..free.len() {
                if free[i].capacity() > free[bi].capacity() {
                    bi = i;
                }
            }
            free.swap_remove(bi)
        }
    };
    v.clear();
    v.resize(len, T::default());
    v
}

/// Typed buffer pool for one in-flight request.
#[derive(Default)]
pub struct Arena {
    f32_free: Vec<Vec<f32>>,
    i32_free: Vec<Vec<i32>>,
    u8_free: Vec<Vec<u8>>,
    u64_free: Vec<Vec<u64>>,
    live_bytes: usize,
    peak_bytes: usize,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    fn note_take(&mut self, bytes: usize) {
        self.live_bytes += bytes;
        if self.live_bytes > self.peak_bytes {
            self.peak_bytes = self.live_bytes;
        }
    }

    fn note_put(&mut self, bytes: usize) {
        self.live_bytes = self.live_bytes.saturating_sub(bytes);
    }

    /// Bytes currently checked out.
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    /// High-water mark of [`Arena::live_bytes`] over the arena's life.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Zero-filled f32 tensor of the given dims (recycled storage).
    pub fn take_f32(&mut self, dims: &[usize]) -> TensorF {
        let len = dims.iter().product::<usize>();
        self.note_take(len * std::mem::size_of::<f32>());
        TensorF::from_vec(dims, take_vec(&mut self.f32_free, len))
    }

    pub fn put_f32(&mut self, t: TensorF) {
        self.note_put(t.data.len() * std::mem::size_of::<f32>());
        self.f32_free.push(t.data);
    }

    /// Zero-filled i32 tensor (codes, integer accumulators).
    pub fn take_i32(&mut self, dims: &[usize]) -> TensorI {
        let len = dims.iter().product::<usize>();
        self.note_take(len * std::mem::size_of::<i32>());
        TensorI::from_vec(dims, take_vec(&mut self.i32_free, len))
    }

    pub fn put_i32(&mut self, t: TensorI) {
        self.note_put(t.data.len() * std::mem::size_of::<i32>());
        self.i32_free.push(t.data);
    }

    /// Zero-filled u8 tensor (slot-state lanes).
    pub fn take_u8(&mut self, dims: &[usize]) -> Tensor<u8> {
        let len = dims.iter().product::<usize>();
        self.note_take(len);
        Tensor::from_vec(dims, take_vec(&mut self.u8_free, len))
    }

    pub fn put_u8(&mut self, t: Tensor<u8>) {
        self.note_put(t.data.len());
        self.u8_free.push(t.data);
    }

    /// Zero-filled u64 word buffer (bit-packed OverQ planes).
    pub fn take_u64(&mut self, len: usize) -> Vec<u64> {
        self.note_take(len * std::mem::size_of::<u64>());
        take_vec(&mut self.u64_free, len)
    }

    pub fn put_u64(&mut self, v: Vec<u64>) {
        self.note_put(v.len() * std::mem::size_of::<u64>());
        self.u64_free.push(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn graph(src: &str) -> Graph {
        Graph::from_json(&parse(src).unwrap()).unwrap()
    }

    fn diamond() -> Graph {
        // input → two convs → add → gap → dense (node 1 read twice)
        graph(
            r#"{
          "name": "diamond",
          "nodes": [
            {"id": 0, "op": "input", "in": []},
            {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
             "cin": 3, "cout": 8, "relu": true, "quant": false},
            {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 1,
             "cin": 8, "cout": 8, "relu": false, "quant": false},
            {"id": 3, "op": "add", "in": [1, 2], "relu": true},
            {"id": 4, "op": "gap", "in": [3]},
            {"id": 5, "op": "dense", "in": [4], "cin": 8, "cout": 10}
          ]
        }"#,
        )
    }

    #[test]
    fn order_is_identity_on_ssa_graphs() {
        let g = diamond();
        let p = ExecPlan::build(&g, &[2, 8, 8, 3]).unwrap();
        assert_eq!(p.order, (0..g.nodes.len()).collect::<Vec<_>>());
    }

    #[test]
    fn shapes_and_flush_points() {
        let g = diamond();
        let p = ExecPlan::build(&g, &[2, 8, 8, 3]).unwrap();
        assert_eq!(p.dims[1], vec![2, 8, 8, 8]);
        assert_eq!(p.dims[3], vec![2, 8, 8, 8]);
        assert_eq!(p.dims[4], vec![2, 8]);
        assert_eq!(p.dims[5], vec![2, 10]);
        // node 1 is read by 2 AND 3 → flushes at step 3, not step 2
        assert!(p.flush[3].contains(&1));
        assert!(!p.flush[2].contains(&1));
        // logits never flush
        assert!(p.flush.iter().all(|f| !f.contains(&5)));
        // everything except the logits flushes exactly once
        let total: usize = p.flush.iter().map(|f| f.len()).sum();
        assert_eq!(total, g.nodes.len() - 1);
        assert!(p.naive_bytes > 0);
    }

    #[test]
    fn arena_recycles_and_tracks_peak() {
        let mut a = Arena::new();
        let t1 = a.take_f32(&[4, 8]);
        assert_eq!(a.live_bytes(), 4 * 8 * 4);
        let ptr = t1.data.as_ptr();
        a.put_f32(t1);
        assert_eq!(a.live_bytes(), 0);
        // same-or-smaller request reuses the same storage
        let t2 = a.take_f32(&[2, 8]);
        assert_eq!(t2.data.as_ptr(), ptr);
        assert!(t2.data.iter().all(|&v| v == 0.0));
        a.put_f32(t2);
        assert_eq!(a.peak_bytes(), 4 * 8 * 4);
        // peak is a high-water mark across concurrent holds
        let x = a.take_i32(&[16]);
        let y = a.take_i32(&[16]);
        assert_eq!(a.live_bytes(), 2 * 16 * 4);
        a.put_i32(x);
        a.put_i32(y);
        let w = a.take_u64(7);
        assert_eq!(w.len(), 7);
        a.put_u64(w);
        assert_eq!(a.live_bytes(), 0);
    }

    #[test]
    fn dirty_buffers_come_back_zeroed() {
        let mut a = Arena::new();
        let mut t = a.take_i32(&[8]);
        t.data.fill(-7);
        a.put_i32(t);
        let t2 = a.take_i32(&[8]);
        assert!(t2.data.iter().all(|&v| v == 0));
    }
}
