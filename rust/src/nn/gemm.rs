//! f32 GEMM for the fp32 path (convs via im2col, the final dense layer).
//!
//! Row-major `out(M,N) = A(M,K) · W(K,N)`, i-k-j loop order so the inner
//! loop is a contiguous axpy over W rows (auto-vectorizes well), with a
//! zero-skip on A that exploits ReLU sparsity.

use crate::tensor::TensorF;

/// out += A @ W. `out` must be zeroed by the caller if accumulation
/// isn't wanted.
pub fn gemm_f32(a: &TensorF, w: &TensorF, out: &mut TensorF) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let n = w.dims()[1];
    assert_eq!(w.dims()[0], k, "inner dims");
    assert_eq!(out.dims(), &[m, n]);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // ReLU sparsity
            }
            let wrow = &w.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * wrow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn prop_matches_naive() {
        check("gemm matches naive", 60, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.index(12), 1 + rng.index(20), 1 + rng.index(12));
            let mut a = TensorF::zeros(&[m, k]);
            let mut w = TensorF::zeros(&[k, n]);
            for v in a.data.iter_mut() {
                *v = if rng.bool(0.3) { 0.0 } else { rng.normal() };
            }
            for v in w.data.iter_mut() {
                *v = rng.normal();
            }
            let mut out = TensorF::zeros(&[m, n]);
            gemm_f32(&a, &w, &mut out);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|x| a.data[i * k + x] * w.data[x * n + j]).sum();
                    assert!((out.data[i * n + j] - want).abs() < 1e-4 * (1.0 + want.abs()));
                }
            }
        });
    }
}
