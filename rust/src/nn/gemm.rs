//! f32 GEMM for the fp32 path (convs via im2col, the final dense layer).
//!
//! `out += A(M,K) · W(K,N)` (row-major) as a cache-blocked, packed-panel
//! kernel parallelized over row blocks with [`crate::util::threadpool`]:
//!
//! * `W` is packed once per call into column panels of [`NR`] columns,
//!   laid out `[panel][k][NR]` and zero-padded, so the microkernel reads
//!   one contiguous `NR`-wide stripe per k step.
//! * Each `MC`-row block packs `A` into [`MR`]-row micro-panels laid out
//!   `[panel][k][MR]` at full k depth, so the microkernel reads one
//!   contiguous `MR`-wide stripe per k step.
//! * The [`microkernel`] holds an `MR × NR` accumulator tile in registers
//!   across the **entire** k dimension — the classic GotoBLAS/BLIS shape
//!   — and the fixed tile bounds let the compiler fully unroll and
//!   vectorize it. Summing all of k in one register tile (no partial
//!   writebacks) is what makes the result **bit-identical to
//!   [`reference::gemm_f32`]** on zero-initialized outputs: both are the
//!   same ascending-k running sum, so every intermediate rounding step
//!   matches.
//! * Row blocks write disjoint `out` ranges, so threads never share a
//!   cache line, and the k order never depends on the thread count —
//!   results are **bit-identical across 1..N threads**. The differential
//!   harness in `rust/tests/kernel_diff.rs` pins both properties.
//!
//! The old scalar i-k-j kernel is kept verbatim in [`reference`] as the
//! test oracle; see `docs/runtime.md` for the blocking scheme and the
//! measured speedups (BENCH_runtime.json).

use crate::tensor::TensorF;
use crate::util::threadpool;

/// Microkernel tile rows (micro-panel height of packed A).
pub const MR: usize = 6;
/// Microkernel tile columns (panel width of packed W).
pub const NR: usize = 8;
/// Rows per parallel block (one unit of thread work; multiple of MR).
pub const MC: usize = 96;

/// Below this many multiply-adds the scoped-thread spawn cost dominates
/// and [`gemm_f32`] stays sequential.
const PAR_MIN_MACS: usize = 1 << 18;

/// out += A @ W. `out` must be zeroed by the caller if accumulation
/// isn't wanted. Parallelizes over row blocks when the problem is large
/// enough to amortize thread spawn ([`crate::util::threadpool::configured_threads`]
/// workers); numerics do not depend on the thread count.
pub fn gemm_f32(a: &TensorF, w: &TensorF, out: &mut TensorF) {
    let macs = a.numel().saturating_mul(w.dims()[1]);
    let threads = if macs < PAR_MIN_MACS {
        1
    } else {
        threadpool::configured_threads()
    };
    gemm_f32_threads(a, w, out, threads);
}

/// [`gemm_f32`] with an explicit worker count (1 = sequential). The
/// result is bit-identical for every `threads` value.
pub fn gemm_f32_threads(a: &TensorF, w: &TensorF, out: &mut TensorF, threads: usize) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let n = w.dims()[1];
    assert_eq!(w.dims()[0], k, "inner dims");
    assert_eq!(out.dims(), &[m, n]);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // pack W once: [jp][kk][NR], zero-padded to a full NR columns
    let npan = n.div_ceil(NR);
    let mut bpack = vec![0f32; npan * k * NR];
    for jp in 0..npan {
        let jn = (n - jp * NR).min(NR);
        for kk in 0..k {
            let dst = &mut bpack[(jp * k + kk) * NR..(jp * k + kk) * NR + jn];
            dst.copy_from_slice(&w.data[kk * n + jp * NR..kk * n + jp * NR + jn]);
        }
    }
    let a_data = &a.data[..];
    let bpack = &bpack[..];
    threadpool::parallel_chunks_mut(&mut out.data, MC * n, threads, |bi, ochunk| {
        let i0 = bi * MC;
        let mc = (m - i0).min(MC);
        let mpan = mc.div_ceil(MR);
        // pack the whole MC × K block once: [ip][kk][MR], edge rows
        // zero-padded (the vec init covers them)
        let mut apack = vec![0f32; mpan * k * MR];
        for ip in 0..mpan {
            let rows = (mc - ip * MR).min(MR);
            let panel = &mut apack[ip * k * MR..(ip + 1) * k * MR];
            for kk in 0..k {
                for r in 0..rows {
                    panel[kk * MR + r] = a_data[(i0 + ip * MR + r) * k + kk];
                }
            }
        }
        for jp in 0..npan {
            let jn = (n - jp * NR).min(NR);
            let bp = &bpack[jp * k * NR..(jp + 1) * k * NR];
            for ip in 0..mpan {
                let ap = &apack[ip * k * MR..(ip + 1) * k * MR];
                let acc = microkernel(ap, bp);
                // masked writeback of the valid MR × NR corner
                let rows = (mc - ip * MR).min(MR);
                for (r, arow) in acc.iter().enumerate().take(rows) {
                    let base = (ip * MR + r) * n + jp * NR;
                    for (o, &v) in ochunk[base..base + jn].iter_mut().zip(arow) {
                        *o += v;
                    }
                }
            }
        }
    });
}

/// The MR×NR register tile: `acc[r][q] += ap[kk][r] * bp[kk][q]` over the
/// packed micro-panels. Fixed bounds so the two inner loops unroll.
#[inline]
fn microkernel(ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0f32; NR]; MR];
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let ar = a[r];
            for q in 0..NR {
                acc[r][q] += ar * b[q];
            }
        }
    }
    acc
}

/// The original scalar kernel, kept as the differential-test oracle.
pub mod reference {
    use crate::tensor::TensorF;

    /// out += A @ W, i-k-j loop order: the inner loop is a contiguous
    /// axpy over W rows, with a zero-skip on A that exploits ReLU
    /// sparsity. Single-threaded by construction.
    pub fn gemm_f32(a: &TensorF, w: &TensorF, out: &mut TensorF) {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = w.dims()[1];
        assert_eq!(w.dims()[0], k, "inner dims");
        assert_eq!(out.dims(), &[m, n]);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // ReLU sparsity
                }
                let wrow = &w.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * wrow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn prop_matches_naive() {
        check("gemm matches naive", 60, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.index(12), 1 + rng.index(20), 1 + rng.index(12));
            let mut a = TensorF::zeros(&[m, k]);
            let mut w = TensorF::zeros(&[k, n]);
            for v in a.data.iter_mut() {
                *v = if rng.bool(0.3) { 0.0 } else { rng.normal() };
            }
            for v in w.data.iter_mut() {
                *v = rng.normal();
            }
            let mut out = TensorF::zeros(&[m, n]);
            gemm_f32(&a, &w, &mut out);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|x| a.data[i * k + x] * w.data[x * n + j]).sum();
                    assert!((out.data[i * n + j] - want).abs() < 1e-4 * (1.0 + want.abs()));
                }
            }
        });
    }

    #[test]
    fn prop_blocked_matches_reference_bitexact() {
        // same k summation order => identical rounding; the fuller shape
        // matrix (block-edge shapes, 1..8 threads) lives in
        // tests/kernel_diff.rs
        check("blocked == scalar reference", 40, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.index(40), 1 + rng.index(70), 1 + rng.index(20));
            let mut a = TensorF::zeros(&[m, k]);
            let mut w = TensorF::zeros(&[k, n]);
            for v in a.data.iter_mut() {
                *v = if rng.bool(0.4) { 0.0 } else { rng.normal() };
            }
            for v in w.data.iter_mut() {
                *v = rng.normal();
            }
            let mut want = TensorF::zeros(&[m, n]);
            reference::gemm_f32(&a, &w, &mut want);
            for threads in [1usize, 3] {
                let mut got = TensorF::zeros(&[m, n]);
                gemm_f32_threads(&a, &w, &mut got, threads);
                assert_eq!(got.data, want.data, "threads={threads} m={m} k={k} n={n}");
            }
        });
    }

    #[test]
    fn accumulates_into_out() {
        let a = TensorF::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = TensorF::from_vec(&[2, 1], vec![3.0, 4.0]);
        let mut out = TensorF::from_vec(&[1, 1], vec![100.0]);
        gemm_f32(&a, &w, &mut out);
        assert_eq!(out.data, vec![111.0]);
    }

    #[test]
    fn empty_shapes_are_noops() {
        for (m, k, n) in [(0usize, 4usize, 4usize), (4, 0, 4), (4, 4, 0)] {
            let a = TensorF::zeros(&[m, k]);
            let w = TensorF::zeros(&[k, n]);
            let mut out = TensorF::zeros(&[m, n]);
            gemm_f32_threads(&a, &w, &mut out, 4);
            assert!(out.data.iter().all(|&v| v == 0.0));
        }
    }
}
