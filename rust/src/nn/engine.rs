//! The graph executor: fp32 reference path + OverQ hardware path.
//!
//! Both paths run through a per-(model, input-shape) [`ExecPlan`] with a
//! pooled [`Arena`] of recycled buffers; the `_unplanned`
//! allocate-per-layer variants are kept as differential oracles. The
//! quant path bit-packs the im2col'd (codes, state) lanes and runs
//! `overq::dotprod::gemm_overq_packed`; see `docs/runtime.md`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::io::tensorfile::TensorMap;
use crate::obs::counters::{self, EncSample, CASCADE_BUCKETS};
use crate::obs::span;
use crate::overq::{self, encode_tensor, Encoded, OverQConfig, LSB, MSB, SHIFT};
use crate::quant::uniform::{quantize_weights_mmse, QuantWeights};
use crate::tensor::{TensorF, TensorI};

use super::conv::{im2col, im2col_into, same_out};
use super::gemm::gemm_f32;
use super::graph::{Graph, Node, Op};
use super::plan::{Arena, ExecPlan};

/// Weight bitwidth sentinel: use the engine's prepared weights (the
/// artifact-exported 8-bit codes, or whatever a prior global
/// [`Engine::requantize_weights`] installed). This is the pre-plan-v2
/// behavior and the default everywhere.
pub const WBITS_DEFAULT: u32 = 0;

/// Quantization of one enc point: the OverQ hardware mode plus the
/// activation scale (clip / qmax at that layer's bitwidth).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerQuant {
    /// OverQ mode (bits, cascade, RO/PR switches) for this enc point.
    pub overq: OverQConfig,
    /// Activation scale (clip / qmax) for this enc point.
    pub scale: f32,
    /// Weight bitwidth for the convs reading this enc point.
    /// [`WBITS_DEFAULT`] (0) keeps the engine's prepared weights; any
    /// other value re-quantizes natively (MMSE) at that width, cached
    /// per (conv, width), OCS-expanded weights included.
    pub wbits: u32,
}

/// Per-run quantization configuration: one [`LayerQuant`] per enc point,
/// so mixed-precision deployment plans can vary bits/cascade/mode layer
/// by layer. [`QuantConfig::uniform`] reproduces the old single-global
/// behavior.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// Per-enc-point configuration, indexed by enc-point id.
    pub layers: Vec<LayerQuant>,
}

impl QuantConfig {
    /// The same OverQ mode at every enc point (the paper's setting),
    /// with the engine's prepared (default) weights.
    pub fn uniform(overq: OverQConfig, act_scales: Vec<f32>) -> QuantConfig {
        QuantConfig {
            layers: act_scales
                .into_iter()
                .map(|scale| LayerQuant {
                    overq,
                    scale,
                    wbits: WBITS_DEFAULT,
                })
                .collect(),
        }
    }

    /// Number of enc points configured.
    pub fn num_enc_points(&self) -> usize {
        self.layers.len()
    }
}

/// Prepared conv layer.
#[derive(Clone, Debug)]
struct PConv {
    kh: usize,
    kw: usize,
    stride: usize,
    cin: usize,
    cout: usize,
    quant: bool,
    /// Flattened fp32 weights (K, cout), K ordered (kh, kw, cin).
    wf: TensorF,
    bias: Vec<f32>,
    /// Artifact-exported int8 codes/scales (bit-exact with JAX path).
    qw: Option<QuantWeights>,
    /// 1-rolled quantized weights for the OverQ GEMM.
    wroll: Option<TensorI>,
    /// OCS channel gather (replaces cin when present).
    gather: Option<Vec<usize>>,
    /// OCS-expanded fp32 weights (duplicated channels halved) — the
    /// source for per-layer weight re-quantization when OCS is active.
    wf_ocs: Option<TensorF>,
}

/// One conv's weights quantized at an explicit bitwidth (the
/// [`LayerQuant::wbits`] path), cached per (conv node, width).
struct PreparedW {
    qw: QuantWeights,
    wroll: TensorI,
}

#[derive(Clone, Debug)]
struct PDense {
    w: TensorF,
    bias: Vec<f32>,
}

/// Per-output-channel interval-arithmetic summary of one affine
/// (conv/dense) layer, consumed by the static analyzer
/// (`crate::analysis::absint`). For output channel `j`, `pos[j]` and
/// `neg[j]` sum the positive and negative weight entries of column `j`,
/// so an input with every element in `[lo, hi]` yields channel-`j`
/// outputs inside `[pos[j]*lo + neg[j]*hi + bias[j],
/// pos[j]*hi + neg[j]*lo + bias[j]]`.
#[derive(Clone, Debug)]
pub struct AffineBounds {
    /// Sum of positive weights per output channel (`>= 0`).
    pub pos: Vec<f64>,
    /// Sum of negative weights per output channel (`<= 0`).
    pub neg: Vec<f64>,
    /// Bias per output channel.
    pub bias: Vec<f64>,
}

impl AffineBounds {
    /// Summarize a flattened `(K, cout)` weight matrix + bias.
    fn from_matrix(w: &TensorF, bias: &[f32]) -> AffineBounds {
        let (k, n) = (w.dims()[0], w.dims()[1]);
        let mut pos = vec![0.0f64; n];
        let mut neg = vec![0.0f64; n];
        for i in 0..k {
            for (j, (p, q)) in pos.iter_mut().zip(neg.iter_mut()).enumerate() {
                let v = w.data[i * n + j] as f64;
                if v >= 0.0 {
                    *p += v;
                } else {
                    *q += v;
                }
            }
        }
        AffineBounds {
            pos,
            neg,
            bias: bias.iter().map(|&b| b as f64).collect(),
        }
    }
}

/// The inference engine for one loaded model.
pub struct Engine {
    pub graph: Graph,
    convs: HashMap<usize, PConv>,
    denses: HashMap<usize, PDense>,
    /// Per-(conv, wbits) quantized-weight cache for plans that pin
    /// explicit weight bitwidths; cleared when OCS rewrites the weights.
    wq_cache: Mutex<HashMap<(usize, u32), Arc<PreparedW>>>,
    /// Per-input-shape execution plans, computed once and shared.
    plan_cache: Mutex<HashMap<Vec<usize>, Arc<ExecPlan>>>,
    /// Idle request arenas — steady-state forwards recycle these instead
    /// of allocating tensors.
    arena_pool: Mutex<Vec<Arena>>,
}

impl Engine {
    /// Build from a parsed graph + the artifact weight map
    /// (`weights/<model>.tensors`).
    pub fn new(graph: Graph, weights: &TensorMap) -> Result<Engine> {
        let mut convs = HashMap::new();
        let mut denses = HashMap::new();
        for node in &graph.nodes {
            match &node.op {
                Op::Conv {
                    kh,
                    kw,
                    stride,
                    cin,
                    cout,
                    relu: _,
                    quant,
                    enc: _,
                } => {
                    let w4 = weights
                        .get(&format!("n{}.w", node.id))
                        .with_context(|| format!("missing n{}.w", node.id))?
                        .as_f32()?
                        .clone();
                    let k = kh * kw * cin;
                    anyhow::ensure!(w4.numel() == k * cout, "n{} weight shape", node.id);
                    let wf = w4.reshape(&[k, *cout]);
                    let bias = weights
                        .get(&format!("n{}.b", node.id))
                        .with_context(|| format!("missing n{}.b", node.id))?
                        .as_f32()?
                        .data
                        .clone();
                    let (qw, wroll) = if *quant {
                        // prefer exported codes (bit-exact with python)
                        let qw = match (
                            weights.get(&format!("n{}.wq", node.id)),
                            weights.get(&format!("n{}.ws", node.id)),
                        ) {
                            (Some(c), Some(s)) => QuantWeights {
                                codes: c.as_i32()?.clone(),
                                scales: s.as_f32()?.data.clone(),
                            },
                            _ => quantize_weights_mmse(&wf, 8),
                        };
                        let wroll = overq::dotprod::roll_weights(&qw.codes);
                        (Some(qw), Some(wroll))
                    } else {
                        (None, None)
                    };
                    convs.insert(
                        node.id,
                        PConv {
                            kh: *kh,
                            kw: *kw,
                            stride: *stride,
                            cin: *cin,
                            cout: *cout,
                            quant: *quant,
                            wf,
                            bias,
                            qw,
                            wroll,
                            gather: None,
                            wf_ocs: None,
                        },
                    );
                }
                Op::Dense { cin, cout } => {
                    let w = weights
                        .get(&format!("n{}.w", node.id))
                        .context("dense w")?
                        .as_f32()?
                        .clone()
                        .reshape(&[*cin, *cout]);
                    let bias = weights
                        .get(&format!("n{}.b", node.id))
                        .context("dense b")?
                        .as_f32()?
                        .data
                        .clone();
                    denses.insert(node.id, PDense { w, bias });
                }
                _ => {}
            }
        }
        Ok(Engine {
            graph,
            convs,
            denses,
            wq_cache: Mutex::new(HashMap::new()),
            plan_cache: Mutex::new(HashMap::new()),
            arena_pool: Mutex::new(Vec::new()),
        })
    }

    /// The cached [`ExecPlan`] for this graph at input shape `in_dims`
    /// (built on first use).
    pub fn plan_for(&self, in_dims: &[usize]) -> Result<Arc<ExecPlan>> {
        let mut cache = crate::util::sync::lock(&self.plan_cache);
        if let Some(p) = cache.get(in_dims) {
            return Ok(p.clone());
        }
        let p = Arc::new(ExecPlan::build(&self.graph, in_dims)?);
        cache.insert(in_dims.to_vec(), p.clone());
        Ok(p)
    }

    fn arena_take(&self) -> Arena {
        crate::util::sync::lock(&self.arena_pool).pop().unwrap_or_default()
    }

    fn arena_put(&self, arena: Arena) {
        crate::util::sync::lock(&self.arena_pool).push(arena);
    }

    /// Apply OCS channel splitting to every quantized conv: duplicate the
    /// `ratio` fraction of input channels with the largest |w|, halve the
    /// copies, and re-quantize the expanded weights (MMSE, 8-bit).
    pub fn apply_ocs(&mut self, ratio: f64) {
        for pc in self.convs.values_mut() {
            if !pc.quant || ratio <= 0.0 {
                continue;
            }
            let (kh, kw, cin, cout) = (pc.kh, pc.kw, pc.cin, pc.cout);
            let taps = kh * kw;
            // rank input channels by max |w| over taps and outputs
            let mut mags: Vec<(f32, usize)> = (0..cin)
                .map(|c| {
                    let mut m = 0f32;
                    for t in 0..taps {
                        for j in 0..cout {
                            m = m.max(pc.wf.data[(t * cin + c) * cout + j].abs());
                        }
                    }
                    (m, c)
                })
                .collect();
            mags.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            let nsplit = ((cin as f64 * ratio).ceil() as usize).min(cin);
            let mut is_split = vec![false; cin];
            for &(_, c) in &mags[..nsplit] {
                is_split[c] = true;
            }
            let mut gather = Vec::with_capacity(cin + nsplit);
            for c in 0..cin {
                gather.push(c);
                if is_split[c] {
                    gather.push(c);
                }
            }
            let cg = gather.len();
            // expanded fp32 weights: duplicated channels halved
            let mut wexp = TensorF::zeros(&[taps * cg, cout]);
            for t in 0..taps {
                for (gi, &src) in gather.iter().enumerate() {
                    let f = if is_split[src] { 0.5 } else { 1.0 };
                    for j in 0..cout {
                        wexp.data[(t * cg + gi) * cout + j] =
                            pc.wf.data[(t * cin + src) * cout + j] * f;
                    }
                }
            }
            let qw = quantize_weights_mmse(&wexp, 8);
            pc.wroll = Some(overq::dotprod::roll_weights(&qw.codes));
            pc.qw = Some(qw);
            pc.gather = Some(gather);
            pc.wf_ocs = Some(wexp);
        }
        // the fp32 source of every quantized weight changed shape
        crate::util::sync::lock(&self.wq_cache).clear();
    }

    /// Re-quantize every conv's *prepared* weights natively at `wbits`
    /// (the default path uses the artifact-exported 8-bit codes). With
    /// OCS active, the expanded weights are re-quantized. Per-enc-point
    /// widths are expressed through [`LayerQuant::wbits`] instead, which
    /// leaves the prepared weights untouched.
    pub fn requantize_weights(&mut self, wbits: u32) {
        for pc in self.convs.values_mut() {
            if pc.quant {
                let wf = pc.wf_ocs.as_ref().unwrap_or(&pc.wf);
                let qw = quantize_weights_mmse(wf, wbits);
                pc.wroll = Some(overq::dotprod::roll_weights(&qw.codes));
                pc.qw = Some(qw);
            }
        }
    }

    /// Weights for one quantized conv at an explicit bitwidth, quantized
    /// from the fp32 (OCS-expanded, when active) weights and cached.
    fn prepared_weights(&self, id: usize, pc: &PConv, wbits: u32) -> Result<Arc<PreparedW>> {
        anyhow::ensure!(
            (2..=8).contains(&wbits),
            "weight bitwidth {wbits} outside the supported 2..=8 range"
        );
        let mut cache = crate::util::sync::lock(&self.wq_cache);
        if let Some(p) = cache.get(&(id, wbits)) {
            return Ok(p.clone());
        }
        let wf = pc.wf_ocs.as_ref().unwrap_or(&pc.wf);
        let qw = quantize_weights_mmse(wf, wbits);
        let wroll = overq::dotprod::roll_weights(&qw.codes);
        let p = Arc::new(PreparedW { qw, wroll });
        cache.insert((id, wbits), p.clone());
        Ok(p)
    }

    /// Effective input-channel count of a conv node after OCS expansion
    /// (`None` for non-conv nodes). Lets the policy profiler account
    /// MACs — and hence the area-time budget — on the channels the
    /// hardware actually sees.
    pub fn conv_in_channels(&self, node_id: usize) -> Option<usize> {
        let pc = self.convs.get(&node_id)?;
        Some(pc.gather.as_ref().map(|g| g.len()).unwrap_or(pc.cin))
    }

    /// Crude relative MSE of quantizing the convs that read enc point
    /// `enc` at `wbits` (per-column uniform step, MAC-weighted across
    /// consuming convs): the weight-side term of the policy engine's
    /// error proxy. Returns 0 when nothing consumes the point.
    pub fn weight_quant_rel_mse(&self, enc: usize, wbits: u32) -> f64 {
        let qmax = ((1i64 << (wbits.max(2) - 1)) - 1) as f64;
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for node in &self.graph.nodes {
            let Op::Conv { quant: true, enc: Some(e), .. } = &node.op else {
                continue;
            };
            if *e != enc {
                continue;
            }
            let pc = &self.convs[&node.id];
            let wf = pc.wf_ocs.as_ref().unwrap_or(&pc.wf);
            let (k, n) = (wf.dims()[0], wf.dims()[1]);
            let (mut mse, mut msq) = (0.0f64, 0.0f64);
            for j in 0..n {
                let mut amax = 0f32;
                let mut col_sq = 0.0f64;
                for i in 0..k {
                    let w = wf.data[i * n + j];
                    amax = amax.max(w.abs());
                    col_sq += (w as f64) * (w as f64);
                }
                let step = amax as f64 / qmax;
                mse += step * step / 12.0 * k as f64;
                msq += col_sq;
            }
            let weight = (k * n) as f64; // MAC share ∝ weight count
            num += weight * (mse / msq.max(1e-30));
            den += weight;
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    /// Output width of the classifier head (the last dense layer's
    /// `cout`), so serving-side consumers don't hardcode class counts.
    pub fn num_classes(&self) -> Option<usize> {
        self.graph
            .nodes
            .iter()
            .rev()
            .find_map(|n| match &n.op {
                Op::Dense { cout, .. } => Some(*cout),
                _ => None,
            })
    }

    /// Interval-arithmetic weight summary of a conv/dense node (`None`
    /// for other ops). Built from the same fp32 weights the reference
    /// [`Engine::forward_f32`] path multiplies by, so bounds derived
    /// from it are sound for that path.
    pub fn affine_bounds(&self, node_id: usize) -> Option<AffineBounds> {
        if let Some(pc) = self.convs.get(&node_id) {
            return Some(AffineBounds::from_matrix(&pc.wf, &pc.bias));
        }
        self.denses
            .get(&node_id)
            .map(|pd| AffineBounds::from_matrix(&pd.w, &pd.bias))
    }

    /// fp32 forward. Returns logits (N, classes); if `taps` is non-empty,
    /// also collects those node outputs (for profiling / Fig. 6b).
    ///
    /// Runs through the cached [`ExecPlan`] with a pooled [`Arena`];
    /// numerically identical to [`Engine::forward_f32_unplanned`] (same
    /// kernels, same evaluation order — the plan only schedules buffer
    /// reuse), which `tests/kernel_diff.rs` pins exactly.
    pub fn forward_f32(&self, x: &TensorF, taps: &[usize]) -> Result<(TensorF, Vec<TensorF>)> {
        let plan = self.plan_for(x.dims())?;
        let mut arena = self.arena_take();
        let r = self.forward_f32_planned(x, taps, &plan, &mut arena);
        self.arena_put(arena);
        r
    }

    /// [`Engine::forward_f32`] against an explicit plan + arena (the
    /// serving path holds its own arena across requests).
    pub fn forward_f32_planned(
        &self,
        x: &TensorF,
        taps: &[usize],
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<(TensorF, Vec<TensorF>)> {
        anyhow::ensure!(plan.in_dims == x.dims(), "plan input shape mismatch");
        let mut vals: Vec<Option<TensorF>> = vec![None; self.graph.nodes.len()];
        let mut tap_out: Vec<Option<TensorF>> = vec![None; taps.len()];
        for (step, &nid) in plan.order.iter().enumerate() {
            let node = &self.graph.nodes[nid];
            let out = self.eval_f32_arena(node, &vals, x, arena)?;
            vals[nid] = Some(out);
            // snapshot tapped outputs before their buffers can flush
            for (ti, &t) in taps.iter().enumerate() {
                if t == nid {
                    tap_out[ti] = Some(vals[nid].as_ref().unwrap().clone());
                }
            }
            for &dead in &plan.flush[step] {
                if let Some(t) = vals[dead].take() {
                    arena.put_f32(t);
                }
            }
        }
        let logits_id = *plan.order.last().context("empty graph")?;
        let logits = vals[logits_id].as_ref().context("missing logits")?.clone();
        for v in vals.iter_mut() {
            if let Some(t) = v.take() {
                arena.put_f32(t);
            }
        }
        Ok((logits, tap_out.into_iter().map(|t| t.unwrap()).collect()))
    }

    /// The original allocate-per-layer fp32 forward, kept as the
    /// differential oracle for the planned path.
    pub fn forward_f32_unplanned(
        &self,
        x: &TensorF,
        taps: &[usize],
    ) -> Result<(TensorF, Vec<TensorF>)> {
        let mut vals: Vec<Option<TensorF>> = vec![None; self.graph.nodes.len()];
        for node in &self.graph.nodes {
            let out = self.eval_f32(node, &vals, x)?;
            vals[node.id] = Some(out);
        }
        let logits = vals
            .last()
            .and_then(|v| v.clone())
            .context("empty graph")?;
        let tap_out = taps
            .iter()
            .map(|&t| vals[t].clone().unwrap())
            .collect();
        Ok((logits, tap_out))
    }

    /// One node on the per-layer-allocation path (fresh `TensorF::zeros`
    /// outputs). Must stay numerically identical to
    /// [`Engine::eval_f32_arena`] — both call the same `_into` kernels.
    fn eval_f32(&self, node: &Node, vals: &[Option<TensorF>], x: &TensorF) -> Result<TensorF> {
        let input = |i: usize| -> &TensorF { vals[node.inputs[i]].as_ref().unwrap() };
        Ok(match &node.op {
            Op::Input => x.clone(),
            Op::Conv { relu, .. } => {
                let pc = &self.convs[&node.id];
                let src = input(0);
                let (cols, oh, ow) = im2col(src, pc.kh, pc.kw, pc.stride);
                let n = src.dims()[0];
                let m = n * oh * ow;
                let mut out = TensorF::zeros(&[m, pc.cout]);
                gemm_f32(&cols, &pc.wf, &mut out);
                add_bias_relu(&mut out, &pc.bias, *relu);
                out.reshape(&[n, oh, ow, pc.cout])
            }
            Op::Add { relu } => {
                let (a, b) = (input(0), input(1));
                anyhow::ensure!(a.dims() == b.dims(), "add dims");
                let mut out = TensorF::zeros(a.dims());
                add_into(a, b, *relu, &mut out);
                out
            }
            Op::Concat => {
                let inputs: Vec<&TensorF> =
                    node.inputs.iter().map(|&i| vals[i].as_ref().unwrap()).collect();
                let mut out = TensorF::zeros(&concat_dims(&inputs));
                concat_into(&inputs, &mut out);
                out
            }
            Op::MaxPool | Op::AvgPool => {
                let src = input(0);
                let mut out = TensorF::zeros(&pool2_dims(src));
                pool2_into(src, matches!(node.op, Op::MaxPool), &mut out);
                out
            }
            Op::Gap => {
                let src = input(0);
                let mut out = TensorF::zeros(&[src.dims()[0], src.dims()[3]]);
                gap_into(src, &mut out);
                out
            }
            Op::Dense { .. } => {
                let pd = &self.denses[&node.id];
                let src = input(0);
                let m = src.dims()[0];
                let mut out = TensorF::zeros(&[m, pd.w.dims()[1]]);
                gemm_f32(src, &pd.w, &mut out);
                add_bias_relu(&mut out, &pd.bias, false);
                out
            }
        })
    }

    /// One node on the arena path: identical kernels and evaluation
    /// order to [`Engine::eval_f32`], only the output storage comes from
    /// (and the im2col scratch returns to) the arena.
    fn eval_f32_arena(
        &self,
        node: &Node,
        vals: &[Option<TensorF>],
        x: &TensorF,
        arena: &mut Arena,
    ) -> Result<TensorF> {
        Ok(match &node.op {
            Op::Input => {
                let mut out = arena.take_f32(x.dims());
                out.data.copy_from_slice(&x.data);
                out
            }
            Op::Conv { relu, .. } => {
                let pc = &self.convs[&node.id];
                let src = vals[node.inputs[0]].as_ref().unwrap();
                let (n, h, w, c) = (src.dims()[0], src.dims()[1], src.dims()[2], src.dims()[3]);
                let (oh, ow) = (same_out(h, pc.stride), same_out(w, pc.stride));
                let m = n * oh * ow;
                let mut cols = arena.take_f32(&[m, pc.kh * pc.kw * c]);
                im2col_into(src, pc.kh, pc.kw, pc.stride, &mut cols);
                let mut out = arena.take_f32(&[m, pc.cout]);
                gemm_f32(&cols, &pc.wf, &mut out);
                arena.put_f32(cols);
                add_bias_relu(&mut out, &pc.bias, *relu);
                out.reshape(&[n, oh, ow, pc.cout])
            }
            Op::Add { relu } => {
                let (a, b) = (
                    vals[node.inputs[0]].as_ref().unwrap(),
                    vals[node.inputs[1]].as_ref().unwrap(),
                );
                anyhow::ensure!(a.dims() == b.dims(), "add dims");
                let mut out = arena.take_f32(a.dims());
                add_into(a, b, *relu, &mut out);
                out
            }
            Op::Concat => {
                let inputs: Vec<&TensorF> =
                    node.inputs.iter().map(|&i| vals[i].as_ref().unwrap()).collect();
                let mut out = arena.take_f32(&concat_dims(&inputs));
                concat_into(&inputs, &mut out);
                out
            }
            Op::MaxPool | Op::AvgPool => {
                let src = vals[node.inputs[0]].as_ref().unwrap();
                let mut out = arena.take_f32(&pool2_dims(src));
                pool2_into(src, matches!(node.op, Op::MaxPool), &mut out);
                out
            }
            Op::Gap => {
                let src = vals[node.inputs[0]].as_ref().unwrap();
                let mut out = arena.take_f32(&[src.dims()[0], src.dims()[3]]);
                gap_into(src, &mut out);
                out
            }
            Op::Dense { .. } => {
                let pd = &self.denses[&node.id];
                let src = vals[node.inputs[0]].as_ref().unwrap();
                let m = src.dims()[0];
                let mut out = arena.take_f32(&[m, pd.w.dims()[1]]);
                gemm_f32(src, &pd.w, &mut out);
                add_bias_relu(&mut out, &pd.bias, false);
                out
            }
        })
    }

    /// OverQ hardware-path forward: encode at enc points, bit-pack, run
    /// the packed integer GEMM, dequant. Bit-exact (codes/states) with
    /// the AOT JAX model.
    ///
    /// Planned + arena-pooled by default; logits are bit-identical to
    /// [`Engine::forward_quant_unplanned`] (same kernels either way —
    /// `tests/kernel_diff.rs` pins the equality).
    pub fn forward_quant(&self, x: &TensorF, qc: &QuantConfig) -> Result<TensorF> {
        let plan = self.plan_for(x.dims())?;
        let mut arena = self.arena_take();
        let r = self.forward_quant_planned(x, qc, &plan, &mut arena);
        self.arena_put(arena);
        r
    }

    /// [`Engine::forward_quant`] against an explicit plan + arena.
    pub fn forward_quant_planned(
        &self,
        x: &TensorF,
        qc: &QuantConfig,
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<TensorF> {
        anyhow::ensure!(
            qc.layers.len() >= self.graph.num_enc_points(),
            "need {} enc-point configs, got {}",
            self.graph.num_enc_points(),
            qc.layers.len()
        );
        anyhow::ensure!(plan.in_dims == x.dims(), "plan input shape mismatch");
        let mut vals: Vec<Option<TensorF>> = vec![None; self.graph.nodes.len()];
        let mut encoded: HashMap<usize, Encoded> = HashMap::new();
        for (step, &nid) in plan.order.iter().enumerate() {
            let node = &self.graph.nodes[nid];
            let out = match &node.op {
                Op::Conv { relu, quant: true, enc, .. } => {
                    self.eval_conv_quant(node, *relu, enc, &vals, qc, &mut encoded, arena)?
                }
                _ => self.eval_f32_arena(node, &vals, x, arena)?,
            };
            vals[nid] = Some(out);
            for &dead in &plan.flush[step] {
                if let Some(t) = vals[dead].take() {
                    arena.put_f32(t);
                }
            }
        }
        let logits_id = *plan.order.last().context("empty graph")?;
        let logits = vals[logits_id].as_ref().context("missing logits")?.clone();
        for v in vals.iter_mut() {
            if let Some(t) = v.take() {
                arena.put_f32(t);
            }
        }
        Ok(logits)
    }

    /// The original allocate-per-layer quant forward — the differential
    /// oracle for the planned path (one throwaway arena per call, so the
    /// conv kernels themselves are shared and identical).
    pub fn forward_quant_unplanned(&self, x: &TensorF, qc: &QuantConfig) -> Result<TensorF> {
        anyhow::ensure!(
            qc.layers.len() >= self.graph.num_enc_points(),
            "need {} enc-point configs, got {}",
            self.graph.num_enc_points(),
            qc.layers.len()
        );
        let mut arena = Arena::new();
        let mut vals: Vec<Option<TensorF>> = vec![None; self.graph.nodes.len()];
        let mut encoded: HashMap<usize, Encoded> = HashMap::new();
        for node in &self.graph.nodes {
            let out = match &node.op {
                Op::Conv { relu, quant: true, enc, .. } => {
                    self.eval_conv_quant(node, *relu, enc, &vals, qc, &mut encoded, &mut arena)?
                }
                _ => self.eval_f32(node, &vals, x)?,
            };
            vals[node.id] = Some(out);
        }
        vals.last().and_then(|v| v.clone()).context("empty graph")
    }

    /// One quantized conv: encode (cached per enc point), im2col the
    /// (codes, state) lanes, bit-pack, packed OverQ GEMM, dequant.
    /// Shared by the planned and unplanned paths, so their numerics are
    /// identical by construction; spans and counters fire exactly as the
    /// pre-plan engine did (`execute.layer` per conv, `encode` per
    /// encode, enc/mac-slot counters when a registry is pinned).
    fn eval_conv_quant(
        &self,
        node: &Node,
        relu: bool,
        enc: &Option<usize>,
        vals: &[Option<TensorF>],
        qc: &QuantConfig,
        encoded: &mut HashMap<usize, Encoded>,
        arena: &mut Arena,
    ) -> Result<TensorF> {
        let pc = &self.convs[&node.id];
        let e = enc.context("quant conv without enc")?;
        let d = format!("node={} enc={e}", node.id);
        let _layer = span::here("execute.layer", d);
        let src = vals[node.inputs[0]].as_ref().unwrap();
        let (n, h, w) = (src.dims()[0], src.dims()[1], src.dims()[2]);
        let (oh, ow) = (same_out(h, pc.stride), same_out(w, pc.stride));
        let m = n * oh * ow;
        let lq = qc.layers[e];
        let scale = lq.scale;
        let kdim = pc.kh * pc.kw * pc.gather.as_ref().map(|g| g.len()).unwrap_or(pc.cin);
        let mut ccols = arena.take_i32(&[m, kdim]);
        let mut scols = arena.take_u8(&[m, kdim]);
        if let Some(gather) = &pc.gather {
            // OCS: expand channels on the raw tensor, then encode the
            // expanded stream (hardware sees the duplicated channels as
            // real channels).
            let exp = expand_channels(src, gather);
            let encx = {
                let _s = span::here("encode", format!("enc={e} ocs=1"));
                encode_tensor(&exp, scale, &lq.overq)
            };
            if counters::active() {
                counters::record(e, &observe_encode(&exp, &encx, &lq.overq));
            }
            im2col_into(&encx.codes, pc.kh, pc.kw, pc.stride, &mut ccols);
            im2col_into(&encx.state, pc.kh, pc.kw, pc.stride, &mut scols);
        } else {
            let encx = encoded.entry(e).or_insert_with(|| {
                let _s = span::here("encode", format!("enc={e} ocs=0"));
                let encx = encode_tensor(src, scale, &lq.overq);
                if counters::active() {
                    counters::record(e, &observe_encode(src, &encx, &lq.overq));
                }
                encx
            });
            im2col_into(&encx.codes, pc.kh, pc.kw, pc.stride, &mut ccols);
            im2col_into(&encx.state, pc.kh, pc.kw, pc.stride, &mut scols);
        }
        // bit-pack the im2col'd lanes into the u64 wire format
        let bits = lq.overq.bits;
        let words = {
            let mut words = arena.take_u64(overq::encode::packed_len(m, kdim, bits));
            overq::encode::pack_slots_into(&ccols.data, &scols.data, m, kdim, bits, &mut words);
            words
        };
        let packed = overq::encode::PackedSlots {
            words,
            rows: m,
            cols: kdim,
            bits,
        };
        if counters::active() {
            counters::record_mac_slots(e, overq::dotprod::slot_histogram_packed(&packed));
        }
        let prepared = if lq.wbits != WBITS_DEFAULT {
            Some(self.prepared_weights(node.id, pc, lq.wbits)?)
        } else {
            None
        };
        let (qw, wroll) = match &prepared {
            Some(p) => (&p.qw, &p.wroll),
            None => (
                pc.qw.as_ref().context("quant conv missing qweights")?,
                pc.wroll.as_ref().unwrap(),
            ),
        };
        anyhow::ensure!(qw.codes.dims()[0] == kdim, "n{} K mismatch", node.id);
        let mut acc = arena.take_i32(&[m, pc.cout]);
        overq::dotprod::gemm_overq_packed(&packed, &qw.codes, wroll, &lq.overq, &mut acc);
        // dequant: acc * act_scale * w_scale / B + bias (+relu)
        let inv_b = 1.0f32 / lq.overq.b() as f32;
        let mut out = arena.take_f32(&[m, pc.cout]);
        for i in 0..m {
            let arow = &acc.data[i * pc.cout..(i + 1) * pc.cout];
            let orow = &mut out.data[i * pc.cout..(i + 1) * pc.cout];
            for j in 0..pc.cout {
                let mut v = arow[j] as f32 * (scale * qw.scales[j] * inv_b) + pc.bias[j];
                if relu && v < 0.0 {
                    v = 0.0;
                }
                orow[j] = v;
            }
        }
        arena.put_i32(ccols);
        arena.put_u8(scols);
        arena.put_u64(packed.words);
        arena.put_i32(acc);
        Ok(out.reshape(&[n, oh, ow, pc.cout]))
    }

    /// Classification accuracy over a labeled batch (fp32 path).
    pub fn accuracy_f32(&self, images: &TensorF, labels: &[i32], batch: usize) -> Result<f64> {
        self.accuracy_with(images, labels, batch, |xb| {
            Ok(self.forward_f32(xb, &[])?.0)
        })
    }

    /// Classification accuracy over a labeled batch (quant path).
    pub fn accuracy_quant(
        &self,
        images: &TensorF,
        labels: &[i32],
        batch: usize,
        qc: &QuantConfig,
    ) -> Result<f64> {
        self.accuracy_with(images, labels, batch, |xb| self.forward_quant(xb, qc))
    }

    fn accuracy_with<F>(&self, images: &TensorF, labels: &[i32], batch: usize, fwd: F) -> Result<f64>
    where
        F: Fn(&TensorF) -> Result<TensorF>,
    {
        let n = images.dims()[0];
        anyhow::ensure!(labels.len() >= n, "labels too short");
        let img_sz: usize = images.dims()[1..].iter().product();
        let mut correct = 0usize;
        let mut i = 0;
        while i < n {
            let bsz = batch.min(n - i);
            let mut dims = vec![bsz];
            dims.extend_from_slice(&images.dims()[1..]);
            let xb = TensorF::from_vec(
                &dims,
                images.data[i * img_sz..(i + bsz) * img_sz].to_vec(),
            );
            let logits = fwd(&xb)?;
            let classes = logits.dims()[1];
            for b in 0..bsz {
                let row = &logits.data[b * classes..(b + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 == labels[i + b] {
                    correct += 1;
                }
            }
            i += bsz;
        }
        Ok(correct as f64 / n as f64)
    }
}

fn add_bias_relu(out: &mut TensorF, bias: &[f32], relu: bool) {
    let n = bias.len();
    for row in 0..out.dims()[0] {
        let orow = &mut out.data[row * n..(row + 1) * n];
        for j in 0..n {
            orow[j] += bias[j];
            if relu && orow[j] < 0.0 {
                orow[j] = 0.0;
            }
        }
    }
}

/// `out = a + b` (optionally ReLU-clamped), written fully — safe for
/// recycled buffers.
fn add_into(a: &TensorF, b: &TensorF, relu: bool, out: &mut TensorF) {
    for ((o, &av), &bv) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
        let mut v = av + bv;
        if relu && v < 0.0 {
            v = 0.0;
        }
        *o = v;
    }
}

fn concat_dims(inputs: &[&TensorF]) -> Vec<usize> {
    let d = inputs[0].dims();
    let ctotal: usize = inputs.iter().map(|t| t.dims()[3]).sum();
    vec![d[0], d[1], d[2], ctotal]
}

fn concat_into(inputs: &[&TensorF], out: &mut TensorF) {
    let (n, h, w) = (
        inputs[0].dims()[0],
        inputs[0].dims()[1],
        inputs[0].dims()[2],
    );
    let rows = n * h * w;
    for r in 0..rows {
        let dst = out.row_mut(r);
        let mut off = 0;
        for t in inputs {
            let c = t.dims()[3];
            dst[off..off + c].copy_from_slice(t.row(r));
            off += c;
        }
    }
}

fn pool2_dims(x: &TensorF) -> Vec<usize> {
    let d = x.dims();
    vec![d[0], d[1] / 2, d[2] / 2, d[3]]
}

fn pool2_into(x: &TensorF, is_max: bool, out: &mut TensorF) {
    let (n, h, w, c) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (oh, ow) = (h / 2, w / 2);
    for img in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let vals = [
                        x.at(&[img, oy * 2, ox * 2, ch]),
                        x.at(&[img, oy * 2, ox * 2 + 1, ch]),
                        x.at(&[img, oy * 2 + 1, ox * 2, ch]),
                        x.at(&[img, oy * 2 + 1, ox * 2 + 1, ch]),
                    ];
                    *out.at_mut(&[img, oy, ox, ch]) = if is_max {
                        vals.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
                    } else {
                        vals.iter().sum::<f32>() / 4.0
                    };
                }
            }
        }
    }
}

fn gap_into(x: &TensorF, out: &mut TensorF) {
    let (n, h, w, c) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    out.data.fill(0.0);
    for img in 0..n {
        for y in 0..h {
            for xx in 0..w {
                for ch in 0..c {
                    out.data[img * c + ch] += x.at(&[img, y, xx, ch]);
                }
            }
        }
        for ch in 0..c {
            out.data[img * c + ch] /= (h * w) as f32;
        }
    }
}

/// Reconstruct what the encoder saw at one enc point: zero/outlier
/// classification (re-deriving the integer codes exactly as
/// [`encode_tensor`] does), the RO cascade depths read back off the
/// state lane, and single-pass Welford moments of the raw activations
/// for drift tracking. Telemetry only — never on the numeric path; the
/// quant forward calls it solely when [`counters::active`] says a
/// serving worker pinned a counter context to this thread.
fn observe_encode(x: &TensorF, encx: &Encoded, cfg: &OverQConfig) -> EncSample {
    let qmax = cfg.qmax();
    let inv = 1.0f32 / encx.scale;
    let (mut zeros, mut outliers) = (0u64, 0u64);
    let (mut act_n, mut act_mean, mut act_m2) = (0u64, 0f64, 0f64);
    for &xv in &x.data {
        let v = (xv * inv + 0.5).floor() as i32;
        if v == 0 {
            zeros += 1;
        } else if v > qmax {
            outliers += 1;
        }
        act_n += 1;
        let d = xv as f64 - act_mean;
        act_mean += d / act_n as f64;
        act_m2 += d * (xv as f64 - act_mean);
    }
    // The state lane records what the encoder did with them: each MSB
    // heads one covered outlier's chain, depth = 1 + trailing SHIFTs;
    // each LSB is one precision-overwrite park. Chains never span the
    // encoder's row boundary, so one flat scan suffices.
    let st = &encx.state.data;
    let (mut covered_ro, mut covered_pr) = (0u64, 0u64);
    let mut cascade = [0u64; CASCADE_BUCKETS];
    let mut i = 0;
    while i < st.len() {
        match st[i] {
            MSB => {
                let mut t = 0usize;
                while i + 1 + t < st.len() && st[i + 1 + t] == SHIFT {
                    t += 1;
                }
                covered_ro += 1;
                cascade[(t + 1).min(CASCADE_BUCKETS) - 1] += 1;
                i += t + 1;
            }
            LSB => {
                covered_pr += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    EncSample {
        values: x.numel() as u64,
        zeros,
        outliers,
        covered_ro,
        covered_pr,
        dropped: outliers.saturating_sub(covered_ro),
        cascade,
        act_n,
        act_mean,
        act_m2,
    }
}

/// Duplicate channels of an (N,H,W,C) tensor according to a gather index.
fn expand_channels(x: &TensorF, gather: &[usize]) -> TensorF {
    let (n, h, w, c) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let cg = gather.len();
    let mut out = TensorF::zeros(&[n, h, w, cg]);
    let rows = n * h * w;
    for r in 0..rows {
        let src = &x.data[r * c..(r + 1) * c];
        let dst = out.row_mut(r);
        for (gi, &g) in gather.iter().enumerate() {
            dst[gi] = src[g];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tensorfile::{AnyTensor, TensorMap};
    use crate::util::json::parse;
    use crate::util::rng::Rng;

    fn toy_engine(quant: bool) -> Engine {
        let graph = Graph::from_json(
            &parse(&format!(
                r#"{{
          "name": "toy",
          "nodes": [
            {{"id": 0, "op": "input", "in": []}},
            {{"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
             "cin": 3, "cout": 4, "relu": true, "quant": false}},
            {{"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 2,
             "cin": 4, "cout": 6, "relu": true, "quant": {quant}, "enc": 0}},
            {{"id": 3, "op": "gap", "in": [2]}},
            {{"id": 4, "op": "dense", "in": [3], "cin": 6, "cout": 5}}
          ]
        }}"#
            ))
            .unwrap(),
        )
        .unwrap();
        let mut rng = Rng::new(42);
        let mut weights = TensorMap::new();
        let mut add_w = |name: &str, dims: &[usize]| {
            let mut t = TensorF::zeros(dims);
            for v in t.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            weights.insert(name.into(), AnyTensor::F32(t));
        };
        add_w("n1.w", &[3, 3, 3, 4]);
        add_w("n1.b", &[4]);
        add_w("n2.w", &[3, 3, 4, 6]);
        add_w("n2.b", &[6]);
        add_w("n4.w", &[6, 5]);
        add_w("n4.b", &[5]);
        Engine::new(graph, &weights).unwrap()
    }

    fn rand_input(seed: u64, n: usize) -> TensorF {
        let mut rng = Rng::new(seed);
        let mut x = TensorF::zeros(&[n, 8, 8, 3]);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        x
    }

    #[test]
    fn f32_forward_shapes() {
        let e = toy_engine(false);
        let x = rand_input(1, 2);
        let (logits, taps) = e.forward_f32(&x, &[1, 2]).unwrap();
        assert_eq!(logits.dims(), &[2, 5]);
        assert_eq!(taps[0].dims(), &[2, 8, 8, 4]);
        assert_eq!(taps[1].dims(), &[2, 4, 4, 6]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quant_approaches_f32_at_fine_scale() {
        let e = toy_engine(true);
        let x = rand_input(2, 2);
        let (fp, taps) = e.forward_f32(&x, &[1]).unwrap();
        let max = taps[0].max_abs();
        // bits=6 with scale covering the whole range: small act error
        let qc = QuantConfig::uniform(OverQConfig::baseline(6), vec![max / 63.0]);
        let q = e.forward_quant(&x, &qc).unwrap();
        for (a, b) in fp.data.iter().zip(&q.data) {
            assert!((a - b).abs() < 0.25 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn overq_no_worse_than_baseline_on_aggressive_clip() {
        let e = toy_engine(true);
        let x = rand_input(3, 4);
        let (fp, taps) = e.forward_f32(&x, &[1]).unwrap();
        let std = taps[0].std();
        let scale = 2.0 * std / 15.0; // aggressive 4-bit clip → many outliers
        let l2 = |a: &TensorF, b: &TensorF| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum()
        };
        let base = e
            .forward_quant(
                &x,
                &QuantConfig::uniform(OverQConfig::baseline(4), vec![scale]),
            )
            .unwrap();
        let ovq = e
            .forward_quant(
                &x,
                &QuantConfig::uniform(OverQConfig::full(4, 4), vec![scale]),
            )
            .unwrap();
        assert!(
            l2(&ovq, &fp) <= l2(&base, &fp),
            "overq {} vs base {}",
            l2(&ovq, &fp),
            l2(&base, &fp)
        );
    }

    #[test]
    fn ocs_preserves_behavior() {
        let mut e = toy_engine(true);
        let x = rand_input(4, 2);
        let (_, taps) = e.forward_f32(&x, &[1]).unwrap();
        let scale = taps[0].max_abs() / 15.0;
        let qc = QuantConfig::uniform(OverQConfig::baseline(4), vec![scale]);
        let before = e.forward_quant(&x, &qc).unwrap();
        e.apply_ocs(0.25);
        let after = e.forward_quant(&x, &qc).unwrap();
        // OCS changes quantization error but not the function: outputs
        // stay close to the unsplit quantized outputs.
        for (a, b) in before.data.iter().zip(&after.data) {
            assert!((a - b).abs() < 0.5 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    fn toy_engine_two_enc() -> Engine {
        let graph = Graph::from_json(
            &parse(
                r#"{
          "name": "toy2",
          "nodes": [
            {"id": 0, "op": "input", "in": []},
            {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
             "cin": 3, "cout": 4, "relu": true, "quant": true, "enc": 0},
            {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 2,
             "cin": 4, "cout": 6, "relu": true, "quant": true, "enc": 1},
            {"id": 3, "op": "gap", "in": [2]},
            {"id": 4, "op": "dense", "in": [3], "cin": 6, "cout": 5}
          ]
        }"#,
            )
            .unwrap(),
        )
        .unwrap();
        let mut rng = Rng::new(77);
        let mut weights = TensorMap::new();
        let mut add_w = |name: &str, dims: &[usize]| {
            let mut t = TensorF::zeros(dims);
            for v in t.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            weights.insert(name.into(), AnyTensor::F32(t));
        };
        add_w("n1.w", &[3, 3, 3, 4]);
        add_w("n1.b", &[4]);
        add_w("n2.w", &[3, 3, 4, 6]);
        add_w("n2.b", &[6]);
        add_w("n4.w", &[6, 5]);
        add_w("n4.b", &[5]);
        Engine::new(graph, &weights).unwrap()
    }

    #[test]
    fn mixed_precision_per_enc_point() {
        let e = toy_engine_two_enc();
        let x = rand_input(6, 3);
        let (fp, taps) = e.forward_f32(&x, &[0, 1]).unwrap();
        // enc 0 sees the raw input, enc 1 the first conv's output
        let s0 = x.max_abs();
        let s1 = taps[1].max_abs();
        let l2 = |a: &TensorF, b: &TensorF| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum()
        };
        // uniform A4 vs mixed A8(enc0)/A4(enc1): widening one layer must
        // not hurt, and the per-layer scales must be honored per point.
        let qc4 = QuantConfig::uniform(OverQConfig::baseline(4), vec![s0 / 15.0, s1 / 15.0]);
        let mixed = QuantConfig {
            layers: vec![
                LayerQuant {
                    overq: OverQConfig::baseline(8),
                    scale: s0 / 255.0,
                    wbits: 0,
                },
                LayerQuant {
                    overq: OverQConfig::baseline(4),
                    scale: s1 / 15.0,
                    wbits: 0,
                },
            ],
        };
        let out4 = e.forward_quant(&x, &qc4).unwrap();
        let outm = e.forward_quant(&x, &mixed).unwrap();
        assert!(
            l2(&outm, &fp) <= l2(&out4, &fp) + 1e-9,
            "mixed {} vs uniform {}",
            l2(&outm, &fp),
            l2(&out4, &fp)
        );
        // uniform() is just sugar for identical per-layer entries
        let by_hand = QuantConfig {
            layers: vec![
                LayerQuant {
                    overq: OverQConfig::baseline(4),
                    scale: s0 / 15.0,
                    wbits: 0,
                },
                LayerQuant {
                    overq: OverQConfig::baseline(4),
                    scale: s1 / 15.0,
                    wbits: 0,
                },
            ],
        };
        assert_eq!(
            e.forward_quant(&x, &by_hand).unwrap().data,
            out4.data,
            "uniform() diverged from explicit per-layer construction"
        );
    }

    #[test]
    fn per_layer_weight_bits() {
        let e = toy_engine(true);
        let x = rand_input(8, 2);
        let (_, taps) = e.forward_f32(&x, &[1]).unwrap();
        let scale = taps[0].max_abs() / 63.0;
        let mk = |wbits: u32| QuantConfig {
            layers: vec![LayerQuant {
                overq: OverQConfig::baseline(6),
                scale,
                wbits,
            }],
        };
        // the toy engine has no artifact codes, so its prepared weights
        // ARE quantize_weights_mmse(wf, 8): the default path and an
        // explicit wbits=8 must agree bit-for-bit
        let d0 = e.forward_quant(&x, &mk(WBITS_DEFAULT)).unwrap();
        let d8 = e.forward_quant(&x, &mk(8)).unwrap();
        assert_eq!(d0.data, d8.data);
        // narrower weights actually requantize (outputs change), and the
        // cached second run is bit-identical to the first
        let d3 = e.forward_quant(&x, &mk(3)).unwrap();
        assert_ne!(d3.data, d8.data);
        assert!(d3.data.iter().all(|v| v.is_finite()));
        assert_eq!(e.forward_quant(&x, &mk(3)).unwrap().data, d3.data);
        // out-of-range widths fail with an error, not a bad kernel
        assert!(e.forward_quant(&x, &mk(1)).is_err());
        assert!(e.forward_quant(&x, &mk(9)).is_err());
    }

    #[test]
    fn weight_bits_follow_ocs_expansion() {
        let mut e = toy_engine(true);
        let x = rand_input(9, 2);
        let (_, taps) = e.forward_f32(&x, &[1]).unwrap();
        let scale = taps[0].max_abs() / 15.0;
        e.apply_ocs(0.25);
        // explicit wbits requantizes the OCS-expanded weights — kdim
        // must match the gathered channel count, not the original cin
        let qc = QuantConfig {
            layers: vec![LayerQuant {
                overq: OverQConfig::baseline(4),
                scale,
                wbits: 6,
            }],
        };
        let out = e.forward_quant(&x, &qc).unwrap();
        assert!(out.data.iter().all(|v| v.is_finite()));
        // cin 4 at ratio 0.25 → one split channel → 5 effective channels
        assert_eq!(e.conv_in_channels(2), Some(5));
        assert_eq!(e.conv_in_channels(1), Some(3)); // non-quant conv: unsplit
        assert_eq!(e.conv_in_channels(3), None); // gap node
    }

    #[test]
    fn weight_rel_mse_orders_by_bits() {
        let e = toy_engine(true);
        let m4 = e.weight_quant_rel_mse(0, 4);
        let m8 = e.weight_quant_rel_mse(0, 8);
        assert!(m4 > m8, "{m4} vs {m8}");
        assert!(m8 > 0.0);
        // nothing consumes enc 7 → no weight-side error term
        assert_eq!(e.weight_quant_rel_mse(7, 4), 0.0);
    }

    #[test]
    fn forward_quant_feeds_pinned_counters() {
        use crate::obs::counters::{set_ctx, Registry};
        let e = toy_engine(true);
        let x = rand_input(3, 4);
        let (_, taps) = e.forward_f32(&x, &[1]).unwrap();
        let std = taps[0].std();
        let scale = 2.0 * std / 15.0; // aggressive clip → many outliers
        let qc = QuantConfig::uniform(OverQConfig::full(4, 4), vec![scale]);
        let reg = Registry::new();
        {
            let _g = set_ctx(reg.variant("plan:t"));
            e.forward_quant(&x, &qc).unwrap();
        }
        let snaps = reg.snapshot();
        assert_eq!(snaps.len(), 1);
        let v = &snaps[0];
        assert!(v.outliers > 0, "aggressive clip must produce outliers");
        assert!(v.covered_ro > 0, "RO with zeros around must cover some");
        assert_eq!(v.outliers, v.covered_ro + v.dropped);
        let enc0 = &v.enc[0];
        assert!(enc0.totals.values > 0);
        assert!(enc0.totals.zeros > 0, "post-ReLU input must have zeros");
        assert!(enc0.mac_slots[1] > 0, "MSB lanes must reach the GEMM");
        let depths: u64 = enc0.cascade.iter().map(|&(_, c)| c).sum();
        assert_eq!(depths, v.covered_ro, "every covered outlier has a depth");
        // without a pinned context the same forward records nothing
        let reg2 = Registry::new();
        e.forward_quant(&x, &qc).unwrap();
        assert!(reg2.snapshot().is_empty());
    }

    #[test]
    fn planned_matches_unplanned_exactly() {
        let e = toy_engine(true);
        let x = rand_input(11, 3);
        let (f1, t1) = e.forward_f32(&x, &[1, 2]).unwrap();
        let (f2, t2) = e.forward_f32_unplanned(&x, &[1, 2]).unwrap();
        assert_eq!(f1.data, f2.data, "planned f32 logits diverged");
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.data, b.data, "planned f32 taps diverged");
        }
        let scale = t1[0].max_abs() / 15.0;
        let qc = QuantConfig::uniform(OverQConfig::full(4, 3), vec![scale]);
        let q1 = e.forward_quant(&x, &qc).unwrap();
        let q2 = e.forward_quant_unplanned(&x, &qc).unwrap();
        assert_eq!(q1.data, q2.data, "planned quant logits diverged");
        // a second planned run reuses the pooled arena and plan cache —
        // recycled buffers must not leak state into the result
        assert_eq!(e.forward_quant(&x, &qc).unwrap().data, q1.data);
        assert_eq!(e.forward_f32(&x, &[]).unwrap().0.data, f1.data);
    }

    #[test]
    fn accuracy_counts() {
        let e = toy_engine(false);
        let x = rand_input(5, 4);
        let (logits, _) = e.forward_f32(&x, &[]).unwrap();
        let labels: Vec<i32> = (0..4)
            .map(|i| {
                let row = &logits.data[i * 5..(i + 1) * 5];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32
            })
            .collect();
        let acc = e.accuracy_f32(&x, &labels, 2).unwrap();
        assert_eq!(acc, 1.0);
    }
}
