//! Graph IR — parse the JSON exported by python (`graphs/<model>.json`).

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Node operation.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv {
        kh: usize,
        kw: usize,
        stride: usize,
        cin: usize,
        cout: usize,
        relu: bool,
        quant: bool,
        /// Enc-point index of the input tensor (quant convs only).
        enc: Option<usize>,
    },
    Add {
        relu: bool,
    },
    Concat,
    MaxPool,
    AvgPool,
    Gap,
    Dense {
        cin: usize,
        cout: usize,
    },
}

/// One SSA node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
}

/// The model graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn from_json(v: &Value) -> Result<Graph> {
        let name = v
            .at(&["name"])
            .as_str()
            .context("graph missing name")?
            .to_string();
        let nodes_json = v.at(&["nodes"]).as_arr().context("graph missing nodes")?;
        let mut nodes = Vec::with_capacity(nodes_json.len());
        for (i, n) in nodes_json.iter().enumerate() {
            let id = n.at(&["id"]).as_usize().context("node missing id")?;
            if id != i {
                bail!("node ids must be dense SSA order (got {id} at {i})");
            }
            let inputs: Vec<usize> = n
                .at(&["in"])
                .as_arr()
                .context("node missing in")?
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect();
            for &src in &inputs {
                if src >= i {
                    bail!("node {i}: input {src} violates SSA order");
                }
            }
            let op = match n.at(&["op"]).as_str().context("node missing op")? {
                "input" => Op::Input,
                "conv" => Op::Conv {
                    kh: n.at(&["kh"]).as_usize().context("conv kh")?,
                    kw: n.at(&["kw"]).as_usize().context("conv kw")?,
                    stride: n.at(&["stride"]).as_usize().context("conv stride")?,
                    cin: n.at(&["cin"]).as_usize().context("conv cin")?,
                    cout: n.at(&["cout"]).as_usize().context("conv cout")?,
                    relu: n.at(&["relu"]).as_bool().unwrap_or(false),
                    quant: n.at(&["quant"]).as_bool().unwrap_or(false),
                    enc: n.at(&["enc"]).as_usize(),
                },
                "add" => Op::Add {
                    relu: n.at(&["relu"]).as_bool().unwrap_or(false),
                },
                "concat" => Op::Concat,
                "maxpool" => Op::MaxPool,
                "avgpool" => Op::AvgPool,
                "gap" => Op::Gap,
                "dense" => Op::Dense {
                    cin: n.at(&["cin"]).as_usize().context("dense cin")?,
                    cout: n.at(&["cout"]).as_usize().context("dense cout")?,
                },
                other => bail!("unknown op {other}"),
            };
            nodes.push(Node { id, op, inputs });
        }
        Ok(Graph { name, nodes })
    }

    pub fn load(path: &std::path::Path) -> Result<Graph> {
        Graph::from_json(&crate::util::json::parse_file(path)?)
    }

    /// Number of enc points (distinct tensors feeding quantized convs).
    pub fn num_enc_points(&self) -> usize {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Conv { enc: Some(e), .. } => Some(*e + 1),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    /// Node id producing each enc-point tensor.
    pub fn enc_point_sources(&self) -> Vec<usize> {
        let mut srcs = vec![usize::MAX; self.num_enc_points()];
        for n in &self.nodes {
            if let Op::Conv { enc: Some(e), .. } = &n.op {
                srcs[*e] = n.inputs[0];
            }
        }
        srcs
    }

    /// Quantized conv node ids in execution order.
    pub fn quant_convs(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { quant: true, .. }))
            .map(|n| n.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    const SAMPLE: &str = r#"{
      "name": "toy",
      "nodes": [
        {"id": 0, "op": "input", "in": []},
        {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
         "cin": 3, "cout": 8, "relu": true, "quant": false},
        {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 2,
         "cin": 8, "cout": 16, "relu": true, "quant": true, "enc": 0},
        {"id": 3, "op": "gap", "in": [2]},
        {"id": 4, "op": "dense", "in": [3], "cin": 16, "cout": 10}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let g = Graph::from_json(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(g.name, "toy");
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.num_enc_points(), 1);
        assert_eq!(g.enc_point_sources(), vec![1]);
        assert_eq!(g.quant_convs(), vec![2]);
        match &g.nodes[2].op {
            Op::Conv { stride, quant, enc, .. } => {
                assert_eq!(*stride, 2);
                assert!(quant);
                assert_eq!(*enc, Some(0));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_ssa() {
        let bad = SAMPLE.replace("\"in\": [1],", "\"in\": [9],");
        assert!(Graph::from_json(&parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn real_artifact_graphs_parse() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/graphs");
        if !dir.exists() {
            return; // artifacts not built yet
        }
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            let g = Graph::load(&p).unwrap();
            assert!(g.num_enc_points() > 0, "{}", g.name);
        }
    }
}
