//! Native inference engine executing the graph IR exported by
//! `python/compile/model.py`.
//!
//! Two execution paths over the same graph:
//! * **fp32** — folded conv+bias forward (reference accuracy, activation
//!   profiling taps).
//! * **quant** — the hardware path: OverQ-encode each enc-point tensor,
//!   im2col the (codes, state) planes, run the OverQ integer GEMM
//!   (`overq::dotprod::gemm_overq`, numerically identical to the Pallas
//!   kernel), dequantize, bias, ReLU.
//!
//! Codes and states are bit-exact with the JAX path (verified against
//! dumped test vectors in `tests/integration_crosslang.rs`).

pub mod conv;
pub mod engine;
pub mod gemm;
pub mod graph;

pub use engine::{AffineBounds, Engine, LayerQuant, QuantConfig, WBITS_DEFAULT};
pub use graph::{Graph, Node, Op};
