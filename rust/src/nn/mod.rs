//! Native inference engine executing the graph IR exported by
//! `python/compile/model.py`.
//!
//! Two execution paths over the same graph:
//! * **fp32** — folded conv+bias forward (reference accuracy, activation
//!   profiling taps) lowered to im2col + the blocked-parallel
//!   [`gemm::gemm_f32`].
//! * **quant** — the hardware path: OverQ-encode each enc-point tensor,
//!   im2col the (codes, state) planes, bit-pack them
//!   ([`crate::overq::encode::PackedSlots`]), run the packed OverQ
//!   integer GEMM (`overq::dotprod::gemm_overq_packed`, bit-identical to
//!   the value-at-a-time kernel and numerically identical to the Pallas
//!   kernel), dequantize, bias, ReLU.
//!
//! Both run through a precomputed [`plan::ExecPlan`] with a recycled
//! [`plan::Arena`] by default; `forward_*_unplanned` keep the
//! allocate-per-layer originals as differential oracles (see
//! `docs/runtime.md`). Codes and states are bit-exact with the JAX path
//! (verified against dumped test vectors in
//! `tests/integration_crosslang.rs`).

pub mod conv;
pub mod engine;
pub mod gemm;
pub mod graph;
pub mod plan;

pub use engine::{AffineBounds, Engine, LayerQuant, QuantConfig, WBITS_DEFAULT};
pub use graph::{Graph, Node, Op};
pub use plan::{Arena, ExecPlan};
