//! Datasets: artifact-backed eval/profile splits (see [`crate::models`])
//! and a native synthetic load generator for serving benchmarks.

pub mod shapes;
