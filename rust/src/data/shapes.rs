//! Native synthetic "shapes" image generator — the serving-path load
//! generator.
//!
//! This mirrors the *distribution* of `python/compile/data.py` (same
//! classes, palette, jitter ranges) but uses the crate's xoshiro RNG, so
//! images are NOT bit-identical to the python splits. Accuracy
//! experiments therefore always use the dumped artifact datasets; this
//! generator exists to drive the coordinator with unbounded, cheap,
//! realistic traffic (latency/throughput benches, soak tests).

use crate::tensor::TensorF;
use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const CH: usize = 3;
pub const NUM_CLASSES: usize = 10;

pub const MEAN: [f32; 3] = [0.28, 0.28, 0.28];
pub const STD: [f32; 3] = [0.27, 0.27, 0.27];

const PALETTE: [[f32; 3]; 7] = [
    [0.95, 0.25, 0.20],
    [0.20, 0.90, 0.30],
    [0.25, 0.35, 0.95],
    [0.95, 0.85, 0.20],
    [0.85, 0.25, 0.90],
    [0.20, 0.90, 0.90],
    [0.95, 0.60, 0.20],
];

/// Shape mask predicate shared by the main and distractor shapes.
#[allow(clippy::too_many_arguments)]
fn inside_mask(
    cls: usize,
    y: usize,
    x: usize,
    cy: f32,
    cx: f32,
    r: f32,
    period: i64,
    phase: i64,
) -> bool {
    let (dy, dx) = (y as f32 - cy, x as f32 - cx);
    let (ady, adx) = (dy.abs(), dx.abs());
    match cls {
        0 => dy * dy + dx * dx <= r * r,
        1 => ady.max(adx) <= r * 0.85,
        2 => dy >= -r && dy <= r * 0.8 && adx <= (dy + r) * 0.6,
        3 => {
            let w = (r * 0.35).max(1.0);
            (ady <= w || adx <= w) && ady.max(adx) <= r
        }
        4 => (y as i64 + phase).rem_euclid(period) < (period / 2).max(1),
        5 => (x as i64 + phase).rem_euclid(period) < (period / 2).max(1),
        6 => ((y as i64 / period) + (x as i64 / period)) % 2 == 0,
        7 => {
            let d2 = dy * dy + dx * dx;
            d2 <= r * r && d2 >= (r * 0.55) * (r * 0.55)
        }
        8 => ady + adx <= r,
        _ => (y % (period as usize + 1)) < 2 && (x % (period as usize + 1)) < 2,
    }
}

/// Generate one normalized image + label, keyed by (seed, index).
/// Difficulty knobs mirror the hardened python generator: low-contrast
/// foregrounds, a faint distractor shape of another class, heavy noise.
pub fn gen_image(seed: u64, index: u64) -> (TensorF, i32) {
    let mut rng = Rng::new(seed).fork(index);
    let cls = rng.index(NUM_CLASSES);
    let cy = IMG as f32 / 2.0 + (rng.f32() * 4.0 - 2.0);
    let cx = IMG as f32 / 2.0 + (rng.f32() * 4.0 - 2.0);
    let r = 3.5 + rng.f32() * 2.0;
    let mut fg = PALETTE[rng.index(PALETTE.len())];
    for c in fg.iter_mut() {
        *c += rng.f32() * 0.3 - 0.15;
    }
    let contrast = 0.45 + rng.f32() * 0.55;
    let bg = 0.05 + rng.f32() * 0.30;
    let period = 3 + rng.index(2) as i64;
    let phase = rng.range(0, period);

    // optional distractor from a different class
    let distract = rng.bool(0.5);
    let dcls = (cls + 1 + rng.index(NUM_CLASSES - 1)) % NUM_CLASSES;
    let dcy = IMG as f32 / 2.0 + (rng.f32() * 4.0 - 2.0);
    let dcx = IMG as f32 / 2.0 + (rng.f32() * 4.0 - 2.0);
    let dr = 3.5 + rng.f32() * 2.0;
    let dfg = PALETTE[rng.index(PALETTE.len())];
    let dalpha = 0.3 + rng.f32() * 0.2;
    let dperiod = 3 + rng.index(2) as i64;
    let dphase = rng.range(0, dperiod);

    let mut img = TensorF::zeros(&[IMG, IMG, CH]);
    for y in 0..IMG {
        for x in 0..IMG {
            let inside = inside_mask(cls, y, x, cy, cx, r, period, phase);
            let dinside =
                distract && inside_mask(dcls, y, x, dcy, dcx, dr, dperiod, dphase);
            for c in 0..CH {
                let mut v = bg + rng.normal() * 0.05;
                if dinside {
                    v = (1.0 - dalpha) * v + dalpha * dfg[c];
                }
                if inside {
                    v = fg[c] * contrast;
                }
                v += rng.normal() * 0.12;
                let v = v.clamp(0.0, 1.0);
                *img.at_mut(&[y, x, c]) = (v - MEAN[c]) / STD[c];
            }
        }
    }
    (img, cls as i32)
}

/// Generate a normalized batch (N, IMG, IMG, CH) with labels.
pub fn gen_batch(seed: u64, start: u64, count: usize) -> (TensorF, Vec<i32>) {
    let mut images = TensorF::zeros(&[count, IMG, IMG, CH]);
    let mut labels = Vec::with_capacity(count);
    let stride = IMG * IMG * CH;
    for i in 0..count {
        let (img, l) = gen_image(seed, start + i as u64);
        images.data[i * stride..(i + 1) * stride].copy_from_slice(&img.data);
        labels.push(l);
    }
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, la) = gen_batch(5, 0, 4);
        let (b, lb) = gen_batch(5, 0, 4);
        assert_eq!(a.data, b.data);
        assert_eq!(la, lb);
    }

    #[test]
    fn index_addressable() {
        let (batch, labels) = gen_batch(7, 10, 5);
        let (img, l) = gen_image(7, 12);
        let stride = IMG * IMG * CH;
        assert_eq!(&batch.data[2 * stride..3 * stride], &img.data[..]);
        assert_eq!(labels[2], l);
    }

    #[test]
    fn labels_cover_classes() {
        let (_, labels) = gen_batch(1, 0, 500);
        let mut seen = [0usize; NUM_CLASSES];
        for &l in &labels {
            seen[l as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 10), "{seen:?}");
    }

    #[test]
    fn normalized_range() {
        let (batch, _) = gen_batch(2, 0, 8);
        // normalized values live in roughly [-1.1, 3.6]
        for &v in &batch.data {
            assert!(v > -1.5 && v < 4.0, "{v}");
        }
    }
}
