//! Uniform quantizers.
//!
//! Activations: unsigned affine with zero-point 0 (inputs are post-ReLU),
//! `scale = clip / qmax`, rounding `floor(x * (1/scale) + 0.5)` — the
//! exact convention shared with JAX (see DESIGN.md §7).
//! Weights: symmetric per-output-channel int8 with MMSE scale search.

use crate::tensor::{TensorF, TensorI};

/// Fake-quantize one value: quantize to `bits` unsigned, dequantize.
#[inline]
pub fn fake_quant(x: f32, inv_scale: f32, scale: f32, bits: u32) -> f32 {
    let qmax = ((1u32 << bits) - 1) as f32;
    let v = (x * inv_scale + 0.5).floor().clamp(0.0, qmax);
    v * scale
}

/// Fake-quantize a tensor with a per-tensor scale.
pub fn fake_quant_tensor(x: &TensorF, scale: f32, bits: u32) -> TensorF {
    let inv = 1.0 / scale;
    x.map(|v| fake_quant(v, inv, scale, bits))
}

/// Quantized weight matrix for one layer: int codes + per-column scales.
#[derive(Clone, Debug)]
pub struct QuantWeights {
    /// (K, N) codes in [-(qmax+1), qmax].
    pub codes: TensorI,
    /// (N,) scales.
    pub scales: Vec<f32>,
}

/// Per-output-channel symmetric MMSE weight quantization of a (K, N)
/// matrix. Bit-compatible with the python exporter (same 31-point grid).
pub fn quantize_weights_mmse(w: &TensorF, wbits: u32) -> QuantWeights {
    let (k, n) = (w.dims()[0], w.dims()[1]);
    let qmax = ((1i32 << (wbits - 1)) - 1) as f32;
    let mut codes = TensorI::zeros(&[k, n]);
    let mut scales = vec![0f32; n];
    let mut col = vec![0f32; k];
    for j in 0..n {
        for i in 0..k {
            col[i] = w.data[i * n + j];
        }
        let amax = col.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
        let mut best = amax / qmax;
        let mut best_err = f64::INFINITY;
        for step in 0..31 {
            let frac = 0.4 + 0.6 * step as f32 / 30.0;
            let s = amax * frac / qmax;
            let inv = 1.0f32 / s;
            let mut err = 0f64;
            for &x in &col {
                let q = (x * inv + 0.5).floor().clamp(-qmax - 1.0, qmax);
                let d = (q * s - x) as f64;
                err += d * d;
            }
            if err < best_err {
                best_err = err;
                best = s;
            }
        }
        scales[j] = best;
        let inv = 1.0f32 / best;
        for i in 0..k {
            codes.data[i * n + j] =
                (col[i] * inv + 0.5).floor().clamp(-qmax - 1.0, qmax) as i32;
        }
    }
    QuantWeights { codes, scales }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn fake_quant_basics() {
        // scale 0.1, 4 bits: qmax 15 → clip at 1.5
        assert!((fake_quant(0.32, 10.0, 0.1, 4) - 0.3).abs() < 1e-6);
        assert!((fake_quant(99.0, 10.0, 0.1, 4) - 1.5).abs() < 1e-6);
        assert_eq!(fake_quant(0.0, 10.0, 0.1, 4), 0.0);
        assert_eq!(fake_quant(-0.3, 10.0, 0.1, 4), 0.0); // unsigned clamps below
    }

    #[test]
    fn prop_fake_quant_error_bound() {
        check("fq error <= scale/2 inside range", 200, |rng: &mut Rng| {
            let scale = 0.05 + rng.f32() * 0.5;
            let bits = 3 + rng.index(4) as u32;
            let clip = scale * ((1u32 << bits) - 1) as f32;
            let x = rng.f32() * clip;
            let q = fake_quant(x, 1.0 / scale, scale, bits);
            assert!((q - x).abs() <= scale / 2.0 + 1e-6);
        });
    }

    #[test]
    fn mmse_weights_roundtrip() {
        let mut rng = Rng::new(5);
        let (k, n) = (32, 6);
        let mut w = TensorF::zeros(&[k, n]);
        for v in w.data.iter_mut() {
            *v = rng.normal() * 0.1;
        }
        let qw = quantize_weights_mmse(&w, 8);
        assert_eq!(qw.codes.dims(), &[k, n]);
        for j in 0..n {
            assert!(qw.scales[j] > 0.0);
            for i in 0..k {
                let deq = qw.codes.data[i * n + j] as f32 * qw.scales[j];
                assert!((deq - w.data[i * n + j]).abs() < 0.01);
                assert!(qw.codes.data[i * n + j].abs() <= 128);
            }
        }
    }

    #[test]
    fn mmse_not_worse_than_max_scaling() {
        let mut rng = Rng::new(9);
        let mut w = TensorF::zeros(&[64, 1]);
        for v in w.data.iter_mut() {
            *v = rng.normal() * 0.02;
        }
        w.data[0] = 0.5; // outlier
        let qw = quantize_weights_mmse(&w, 8);
        let qmax = 127f32;
        let s_max = 0.5 / qmax;
        let err_max: f64 = w
            .data
            .iter()
            .map(|&x| {
                let q = (x / s_max + 0.5).floor().clamp(-128.0, 127.0);
                ((q * s_max - x) as f64).powi(2)
            })
            .sum();
        let err_mmse: f64 = w
            .data
            .iter()
            .enumerate()
            .map(|(i, &x)| ((qw.codes.data[i] as f32 * qw.scales[0] - x) as f64).powi(2))
            .sum();
        assert!(err_mmse <= err_max + 1e-12);
    }
}
