//! Outlier channel splitting (OCS, Zhao et al. ICML 2019) — weight-side
//! baseline used in Table 2.
//!
//! Input channels whose weights contain the largest magnitudes are
//! duplicated with both copies halved: the layer output is unchanged in
//! fp32 but the per-channel weight range (and thus quantization error)
//! shrinks. The activation side replays the duplicated channels via a
//! gather index, exactly like the original implementation's channel
//! duplication. Splitting needs *static* outlier locations, which is why
//! it applies to weights only (paper §2.1).

use crate::tensor::TensorF;

/// Result of splitting a (K, N) weight matrix.
#[derive(Clone, Debug)]
pub struct OcsSplit {
    /// Expanded weights (K + S, N).
    pub weights: TensorF,
    /// Gather index: row k of the expanded matrix reads activation
    /// channel `gather[k]` of the original K channels.
    pub gather: Vec<usize>,
}

/// Split the `expand_ratio` fraction of input channels with the largest
/// absolute weight (paper used 5 %). `expand_ratio` in [0, 1).
pub fn split_weights(w: &TensorF, expand_ratio: f64) -> OcsSplit {
    let (k, n) = (w.dims()[0], w.dims()[1]);
    let splits = ((k as f64 * expand_ratio).ceil() as usize).min(k);
    // rank channels by max |w| across output channels
    let mut mags: Vec<(f32, usize)> = (0..k)
        .map(|i| {
            let m = (0..n).fold(0f32, |m, j| m.max(w.data[i * n + j].abs()));
            (m, i)
        })
        .collect();
    mags.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let split_set: Vec<usize> = mags[..splits].iter().map(|&(_, i)| i).collect();
    let is_split = {
        let mut v = vec![false; k];
        for &i in &split_set {
            v[i] = true;
        }
        v
    };

    let mut weights = TensorF::zeros(&[k + splits, n]);
    let mut gather = Vec::with_capacity(k + splits);
    let mut row = 0;
    for i in 0..k {
        if is_split[i] {
            // two half copies, adjacent rows, same activation channel
            for _ in 0..2 {
                for j in 0..n {
                    weights.data[row * n + j] = w.data[i * n + j] * 0.5;
                }
                gather.push(i);
                row += 1;
            }
        } else {
            for j in 0..n {
                weights.data[row * n + j] = w.data[i * n + j];
            }
            gather.push(i);
            row += 1;
        }
    }
    debug_assert_eq!(row, k + splits);
    OcsSplit { weights, gather }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn preserves_fp32_output() {
        check("ocs preserves dot product", 100, |rng: &mut Rng| {
            let (k, n) = (2 + rng.index(30), 1 + rng.index(8));
            let mut w = TensorF::zeros(&[k, n]);
            for v in w.data.iter_mut() {
                *v = rng.normal();
            }
            let mut x = vec![0f32; k];
            for v in x.iter_mut() {
                *v = rng.normal();
            }
            let split = split_weights(&w, 0.1 + rng.f64() * 0.3);
            for j in 0..n {
                let want: f32 = (0..k).map(|i| x[i] * w.data[i * n + j]).sum();
                let got: f32 = split
                    .gather
                    .iter()
                    .enumerate()
                    .map(|(r, &src)| x[src] * split.weights.data[r * n + j])
                    .sum();
                assert!((want - got).abs() < 1e-4 * (1.0 + want.abs()), "{want} vs {got}");
            }
        });
    }

    #[test]
    fn reduces_max_magnitude() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 4);
        let mut w = TensorF::zeros(&[k, n]);
        for v in w.data.iter_mut() {
            *v = rng.normal() * 0.05;
        }
        w.data[5 * n] = 2.0; // big outlier in channel 5
        let split = split_weights(&w, 0.05);
        assert!(split.weights.max_abs() <= 1.0 + 1e-6);
        assert_eq!(split.weights.dims()[0], k + (k as f64 * 0.05).ceil() as usize);
    }

    #[test]
    fn zero_ratio_is_identity() {
        let w = TensorF::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = split_weights(&w, 0.0);
        assert_eq!(s.weights.data, w.data);
        assert_eq!(s.gather, vec![0, 1, 2]);
    }
}
