//! Clipping-threshold selection (paper §2.1 / Table 2 baselines).
//!
//! Each method maps profiled activation samples → a clip value; the
//! activation scale is then `clip / qmax`.

use super::histogram::Histogram;
use crate::tensor::TensorF;

/// Supported clipping methods.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClipMethod {
    /// Minimize Σ (x - Q(x))² over a grid of clip candidates
    /// (Sung et al. 2015, Shin et al. 2016).
    Mmse,
    /// Fixed percentile of values (McKinstry et al. 2018), e.g. 0.999.
    Percentile(f64),
    /// KL-divergence calibration (Migacz, TensorRT 2017).
    Kl,
    /// mean + t·std (the paper's STD sweep unit).
    StdMul(f64),
    /// Plain max (no clipping).
    Max,
}

/// Profile summary of one activation tensor (enc point).
#[derive(Clone, Copy, Debug, Default)]
pub struct ActStats {
    pub mean: f32,
    pub std: f32,
    pub max: f32,
}

impl ActStats {
    /// Summarize one activation tensor (the shared profiling primitive
    /// for calibration, the policy engine and the synthetic zoo).
    pub fn from_tensor(t: &TensorF) -> ActStats {
        ActStats {
            mean: t.mean(),
            std: t.std(),
            max: t.data.iter().fold(0f32, |m, &x| m.max(x)),
        }
    }
}

impl ClipMethod {
    /// Pick the clip threshold. `samples` are raw (non-negative)
    /// activation values; `stats` the precomputed summary; `bits` the
    /// activation bitwidth the quantizer will use.
    pub fn clip(&self, samples: &[f32], stats: ActStats, bits: u32) -> f32 {
        match *self {
            ClipMethod::Max => stats.max.max(1e-6),
            ClipMethod::StdMul(t) => {
                (stats.mean + (t as f32) * stats.std).clamp(1e-6, stats.max.max(1e-6))
            }
            ClipMethod::Percentile(p) => {
                let h = Histogram::from_samples(samples, 2048);
                h.percentile(p).max(1e-6)
            }
            ClipMethod::Mmse => mmse_clip(samples, stats.max, bits),
            ClipMethod::Kl => kl_clip(samples, bits),
        }
    }
}

/// Grid-search the clip that minimizes total squared quantization error.
fn mmse_clip(samples: &[f32], max: f32, bits: u32) -> f32 {
    if samples.is_empty() || max <= 0.0 {
        return 1e-6;
    }
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut best = max;
    let mut best_err = f64::INFINITY;
    for step in 1..=60 {
        let clip = max * step as f32 / 60.0;
        let scale = clip / qmax;
        let inv = 1.0 / scale;
        let mut err = 0f64;
        for &x in samples {
            let q = (x * inv + 0.5).floor().clamp(0.0, qmax) * scale;
            let d = (q - x) as f64;
            err += d * d;
        }
        if err < best_err {
            best_err = err;
            best = clip;
        }
    }
    best
}

/// TensorRT-style KL calibration: choose the threshold whose clipped+
/// requantized distribution minimizes KL(P ‖ Q) against the reference.
fn kl_clip(samples: &[f32], bits: u32) -> f32 {
    const BINS: usize = 1024;
    if samples.is_empty() {
        return 1e-6;
    }
    let h = Histogram::from_samples(samples, BINS);
    let levels = 1usize << bits; // quantization levels
    if h.total == 0 {
        return 1e-6;
    }
    let mut best = h.max;
    let mut best_kl = f64::INFINITY;
    // candidate thresholds from `levels` bins upward
    let start = levels.max(BINS / 16);
    for t in (start..=BINS).step_by(8) {
        // P: reference distribution clipped at bin t (outliers folded
        // into the last bin, as TensorRT does)
        let mut p: Vec<f64> = h.bins[..t].iter().map(|&c| c as f64).collect();
        let tail: f64 = h.bins[t..].iter().map(|&c| c as f64).sum();
        *p.last_mut().unwrap() += tail;
        // Q: quantize bins [0, t) to `levels` levels then expand
        let mut q = vec![0f64; t];
        let chunk = t as f64 / levels as f64;
        for level in 0..levels {
            let lo = (level as f64 * chunk) as usize;
            let hi = (((level + 1) as f64 * chunk) as usize).min(t).max(lo + 1);
            let mass: f64 = p[lo..hi].iter().sum();
            let nonzero = p[lo..hi].iter().filter(|&&x| x > 0.0).count();
            if nonzero > 0 {
                let share = mass / nonzero as f64;
                for qq in q[lo..hi].iter_mut().zip(&p[lo..hi]) {
                    if *qq.1 > 0.0 {
                        *qq.0 = share;
                    }
                }
            }
        }
        // KL(P||Q) over non-zero P bins
        let psum: f64 = p.iter().sum();
        let qsum: f64 = q.iter().sum();
        if psum <= 0.0 || qsum <= 0.0 {
            continue;
        }
        let mut kl = 0f64;
        for i in 0..t {
            if p[i] > 0.0 && q[i] > 0.0 {
                let pi = p[i] / psum;
                let qi = q[i] / qsum;
                kl += pi * (pi / qi).ln();
            } else if p[i] > 0.0 {
                kl += 1e3; // unmatched mass penalty
            }
        }
        if kl < best_kl {
            best_kl = kl;
            best = h.edge(t - 1);
        }
    }
    best.max(1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bell_with_outliers(n: usize, seed: u64) -> (Vec<f32>, ActStats) {
        let mut rng = Rng::new(seed);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let x = if rng.bool(0.01) {
                rng.normal().abs() * 3.0 + 6.0
            } else {
                rng.normal().abs()
            };
            v.push(x);
        }
        let mean = v.iter().sum::<f32>() / n as f32;
        let var = v.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        let max = v.iter().fold(0f32, |m, &x| m.max(x));
        (
            v.clone(),
            ActStats {
                mean,
                std: var.sqrt(),
                max,
            },
        )
    }

    #[test]
    fn all_methods_clip_below_max_on_heavy_tail() {
        let (samples, stats) = bell_with_outliers(20_000, 1);
        for m in [
            ClipMethod::Mmse,
            ClipMethod::Percentile(0.999),
            ClipMethod::Kl,
            ClipMethod::StdMul(4.0),
        ] {
            let clip = m.clip(&samples, stats, 4);
            assert!(clip > 0.0);
            assert!(
                clip < stats.max,
                "{m:?} did not clip: {clip} vs max {}",
                stats.max
            );
        }
        assert_eq!(ClipMethod::Max.clip(&samples, stats, 4), stats.max);
    }

    #[test]
    fn mmse_reduces_error_vs_max() {
        let (samples, stats) = bell_with_outliers(20_000, 2);
        let bits = 4;
        let qmax = 15.0f32;
        let err = |clip: f32| -> f64 {
            let scale = clip / qmax;
            samples
                .iter()
                .map(|&x| {
                    let q = (x / scale + 0.5).floor().clamp(0.0, qmax) * scale;
                    ((q - x) as f64).powi(2)
                })
                .sum()
        };
        let clip = ClipMethod::Mmse.clip(&samples, stats, bits);
        assert!(err(clip) < err(stats.max) * 0.8);
    }

    #[test]
    fn std_mul_monotone_in_t() {
        let (samples, stats) = bell_with_outliers(5000, 3);
        let c1 = ClipMethod::StdMul(2.0).clip(&samples, stats, 4);
        let c2 = ClipMethod::StdMul(4.0).clip(&samples, stats, 4);
        assert!(c2 >= c1);
    }

    #[test]
    fn percentile_tracks_distribution() {
        let samples: Vec<f32> = (1..=10_000).map(|i| i as f32 / 10_000.0).collect();
        let stats = ActStats {
            mean: 0.5,
            std: 0.29,
            max: 1.0,
        };
        let c = ClipMethod::Percentile(0.9).clip(&samples, stats, 4);
        assert!((c - 0.9).abs() < 0.02, "{c}");
    }

    #[test]
    fn empty_samples_do_not_panic() {
        let stats = ActStats::default();
        for m in [ClipMethod::Mmse, ClipMethod::Kl, ClipMethod::Percentile(0.99)] {
            assert!(m.clip(&[], stats, 4) > 0.0);
        }
    }
}
