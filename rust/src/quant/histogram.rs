//! Activation histograms for calibration (KL / percentile clipping).

/// Fixed-bin histogram over [0, max] of non-negative activations.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub bins: Vec<u64>,
    pub max: f32,
    pub total: u64,
}

impl Histogram {
    pub fn new(num_bins: usize, max: f32) -> Self {
        Histogram {
            bins: vec![0; num_bins],
            max: max.max(1e-12),
            total: 0,
        }
    }

    /// Build from samples in one pass (max must be known up front).
    pub fn from_samples(samples: &[f32], num_bins: usize) -> Self {
        let max = samples.iter().fold(0f32, |m, &x| m.max(x));
        let mut h = Histogram::new(num_bins, max);
        for &x in samples {
            h.add(x);
        }
        h
    }

    pub fn add(&mut self, x: f32) {
        if x < 0.0 {
            return;
        }
        let idx = ((x / self.max) * self.bins.len() as f32) as usize;
        let idx = idx.min(self.bins.len() - 1);
        self.bins[idx] += 1;
        self.total += 1;
    }

    /// Upper edge of bin i.
    pub fn edge(&self, i: usize) -> f32 {
        self.max * (i + 1) as f32 / self.bins.len() as f32
    }

    /// Smallest threshold covering fraction `p` of the mass.
    pub fn percentile(&self, p: f64) -> f32 {
        let target = (self.total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.edge(i);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_percentiles() {
        let samples: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let h = Histogram::from_samples(&samples, 100);
        assert_eq!(h.total, 1000);
        assert!((h.percentile(0.5) - 0.5).abs() < 0.02);
        assert!((h.percentile(0.999) - 0.999).abs() < 0.02);
        assert!(h.percentile(1.0) <= h.max + 1e-6);
    }

    #[test]
    fn negative_values_ignored() {
        let mut h = Histogram::new(10, 1.0);
        h.add(-0.5);
        assert_eq!(h.total, 0);
    }

    #[test]
    fn overflow_goes_to_last_bin() {
        let mut h = Histogram::new(10, 1.0);
        h.add(5.0);
        assert_eq!(h.bins[9], 1);
    }
}
