//! Post-training quantization substrate (paper §2.1 baselines + weights).
//!
//! * [`uniform`] — affine/symmetric uniform quantizers and the
//!   per-output-channel MMSE weight quantizer (mirrors
//!   `python/compile/model.py::quantize_weights`).
//! * [`histogram`] — activation histograms for calibration.
//! * [`clip`] — clipping-threshold selection: MMSE, percentile,
//!   KL-divergence (TensorRT-style) and STD-multiple sweeping.
//! * [`ocs`] — outlier channel splitting (Zhao et al. 2019) for weights.
//! * [`zeroq`] — ZeroQ-style data-free calibration input generator.

pub mod clip;
pub mod histogram;
pub mod ocs;
pub mod uniform;
pub mod zeroq;

pub use clip::ClipMethod;
pub use histogram::Histogram;
pub use uniform::{fake_quant, fake_quant_tensor, quantize_weights_mmse, QuantWeights};
