//! ZeroQ-style data-free calibration (Cai et al., CVPR 2020).
//!
//! ZeroQ calibrates quantizers without training data by synthesizing
//! "distilled" inputs that match the network's BatchNorm statistics. Our
//! exported graphs have BN folded away, so we use the closest equivalent
//! that exercises the same code path (DESIGN.md §2): synthetic inputs
//! drawn to match the *input* distribution (channelwise normalized
//! images), optionally smoothed to have natural spatial correlation.
//! Downstream, the fp32 engine forwards these synthetic images and the
//! resulting activation taps calibrate the clips — no real data touched.

use crate::tensor::TensorF;
use crate::util::rng::Rng;

/// Generate `n` synthetic calibration images of shape (n, h, w, c),
/// matching a zero-mean/unit-std normalized input distribution with
/// local spatial smoothing (box blur) to mimic natural image statistics.
pub fn synthetic_calibration_batch(n: usize, h: usize, w: usize, c: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed ^ 0x5A5A_0001);
    let mut x = TensorF::zeros(&[n, h, w, c]);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    // 3x3 box blur per channel: correlated patches drive realistic
    // conv activations (pure white noise under-excites deep layers).
    let mut out = TensorF::zeros(&[n, h, w, c]);
    for img in 0..n {
        for ch in 0..c {
            for y in 0..h {
                for xx in 0..w {
                    let mut s = 0f32;
                    let mut cnt = 0f32;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let yy = y as i64 + dy;
                            let xc = xx as i64 + dx;
                            if yy >= 0 && yy < h as i64 && xc >= 0 && xc < w as i64 {
                                s += x.at(&[img, yy as usize, xc as usize, ch]);
                                cnt += 1.0;
                            }
                        }
                    }
                    *out.at_mut(&[img, y, xx, ch]) = s / cnt * 1.8; // re-amplify post-blur
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let a = synthetic_calibration_batch(2, 8, 8, 3, 7);
        let b = synthetic_calibration_batch(2, 8, 8, 3, 7);
        assert_eq!(a.dims(), &[2, 8, 8, 3]);
        assert_eq!(a.data, b.data);
        let c = synthetic_calibration_batch(2, 8, 8, 3, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn roughly_standardized() {
        let x = synthetic_calibration_batch(8, 16, 16, 3, 1);
        assert!(x.mean().abs() < 0.1, "mean {}", x.mean());
        let s = x.std();
        assert!(s > 0.4 && s < 1.2, "std {s}");
    }

    #[test]
    fn spatially_correlated() {
        // adjacent pixels correlate far more than distant ones
        let x = synthetic_calibration_batch(4, 16, 16, 1, 2);
        let mut near = 0f64;
        let mut far = 0f64;
        let mut n = 0f64;
        for img in 0..4 {
            for y in 0..16 {
                for xx in 0..15 {
                    near += (x.at(&[img, y, xx, 0]) * x.at(&[img, y, xx + 1, 0])) as f64;
                    far += (x.at(&[img, y, xx, 0]) * x.at(&[img, 15 - y, 15 - xx, 0])) as f64;
                    n += 1.0;
                }
            }
        }
        assert!(near / n > (far / n).abs() + 0.05);
    }
}
