//! Model-checked coordinator concurrency protocols.
//!
//! `util::sync::model` explores *every* distinguishable thread
//! interleaving of these small protocol models (DFS over scheduling
//! decisions at each lock/channel/atomic operation), so the properties
//! below are checked exhaustively, not probabilistically. Each model
//! mirrors one protocol of `coordinator::server` / `coordinator::batcher`:
//!
//! * **plan publication** — `install_plan` writes the plan body into
//!   the shared plan map FIRST and makes the alias submit-visible
//!   SECOND; `submit_leaf` fail-fast-checks the alias and the replica
//!   reads the body strictly later. The `_races` twin publishes in the
//!   reverse order and must be caught by the checker — that is the
//!   regression test for the checker itself.
//! * **sleep registration (no lost wakeup)** — `SubmitQueue::next_batch`
//!   decides to sleep *while holding the state lock* (the condvar wait
//!   hands the lock back atomically), so a concurrent `push` always
//!   either sees the sleeper and wakes it or the sleeper-to-be sees the
//!   item. The twin re-checks emptiness after dropping the lock and the
//!   checker finds the classic lost wakeup.
//! * **shed-vs-enqueue** — admission (depth check) and enqueue happen
//!   under one critical section, so the bound holds exactly under
//!   racing producers; the check-then-push twin overshoots it.
//! * **shutdown drain** — `close()` flips the closed flag under the
//!   same lock pushes take, so a push either sheds (`Closed`) or its
//!   item is in the queue for the post-close drain: admitted work is
//!   never lost.
//! * **bandit/metrics ordering** — `account_chunk` and
//!   `set_routing_policy` take the bandit and metrics locks
//!   sequentially in the same order, never nested in reverse.
//!
//! The nightly ThreadSanitizer CI job runs the real coordinator tests
//! (including `integration_load`) under TSan for the complementary
//! dynamic check (docs/static_analysis.md).

use overq::util::sync::model;

/// The real publication protocol: plan body lands in the plan map
/// before the alias becomes submit-visible, so a request that passed
/// the fail-fast alias check always finds its plan body at execution.
#[test]
fn plan_publication_order_holds() {
    model::check(|| {
        let plan_map = model::Arc::new(model::Mutex::new(false)); // body present
        let aliases = model::Arc::new(model::Mutex::new(false)); // submit-visible

        let (pm, al) = (plan_map.clone(), aliases.clone());
        let admin = model::thread::spawn(move || {
            // install_plan: body FIRST, alias SECOND
            *pm.lock() = true;
            *al.lock() = true;
        });
        let (pm, al) = (plan_map.clone(), aliases.clone());
        let client = model::thread::spawn(move || {
            // submit_leaf checks the alias; the replica reads the plan
            // body strictly after that check (the queue sits between)
            let visible = { *al.lock() };
            if visible {
                assert!(*pm.lock(), "executed request missed its plan body");
            }
        });
        admin.join().unwrap();
        client.join().unwrap();
    });
}

/// The buggy variant: publishing the alias before the body. There is an
/// interleaving where the check passes but execution reads an absent
/// plan — the checker must find it.
#[test]
#[should_panic(expected = "model check failed")]
fn plan_publication_reversed_races() {
    model::check(|| {
        let plan_map = model::Arc::new(model::Mutex::new(false));
        let aliases = model::Arc::new(model::Mutex::new(false));

        let (pm, al) = (plan_map.clone(), aliases.clone());
        let admin = model::thread::spawn(move || {
            // BUG under test: alias first, body second
            *al.lock() = true;
            *pm.lock() = true;
        });
        let (pm, al) = (plan_map.clone(), aliases.clone());
        let client = model::thread::spawn(move || {
            let visible = { *al.lock() };
            if visible {
                assert!(*pm.lock(), "executed request missed its plan body");
            }
        });
        admin.join().unwrap();
        client.join().unwrap();
    });
}

/// Queue state shared by the bounded-queue models: a miniature
/// `batcher::QState`.
#[derive(Default)]
struct QState {
    items: usize,
    sleeping: bool,
    wake_token: bool,
}

/// The real sleep protocol: `next_batch` sees the queue empty and
/// registers as a sleeper in the SAME critical section (the condvar
/// wait atomically releases the state lock), so `push` either finds
/// the sleeper and wakes it, or the worker saw the item and never
/// slept. In no interleaving does a worker sleep on a non-empty queue
/// without a pending wake.
#[test]
fn queue_sleep_registration_never_loses_a_wakeup() {
    model::check(|| {
        let q = model::Arc::new(model::Mutex::new(QState::default()));

        let qw = q.clone();
        let worker = model::thread::spawn(move || {
            // Phase 1 of next_batch: emptiness check and sleep
            // registration under one lock hold
            let mut g = qw.lock();
            if g.items == 0 {
                g.sleeping = true;
            }
        });
        let qp = q.clone();
        let producer = model::thread::spawn(move || {
            // push: enqueue and notify under the same lock
            let mut g = qp.lock();
            g.items += 1;
            if g.sleeping {
                g.sleeping = false;
                g.wake_token = true;
            }
        });
        worker.join().unwrap();
        producer.join().unwrap();

        let g = q.lock();
        assert!(
            !(g.sleeping && g.items > 0 && !g.wake_token),
            "lost wakeup: worker asleep on a non-empty queue with no wake pending"
        );
    });
}

/// The buggy variant: the worker re-checks emptiness, drops the lock,
/// then registers as a sleeper in a second critical section. The push
/// can land in the gap — its notify sees no sleeper, the worker then
/// sleeps forever on a non-empty queue. The checker must find it.
#[test]
#[should_panic(expected = "model check failed")]
fn queue_sleep_registration_outside_the_lock_races() {
    model::check(|| {
        let q = model::Arc::new(model::Mutex::new(QState::default()));

        let qw = q.clone();
        let worker = model::thread::spawn(move || {
            // BUG under test: check and sleep in separate critical
            // sections
            let empty = { qw.lock().items == 0 };
            if empty {
                qw.lock().sleeping = true;
            }
        });
        let qp = q.clone();
        let producer = model::thread::spawn(move || {
            let mut g = qp.lock();
            g.items += 1;
            if g.sleeping {
                g.sleeping = false;
                g.wake_token = true;
            }
        });
        worker.join().unwrap();
        producer.join().unwrap();

        let g = q.lock();
        assert!(
            !(g.sleeping && g.items > 0 && !g.wake_token),
            "lost wakeup: worker asleep on a non-empty queue with no wake pending"
        );
    });
}

/// The real admission protocol: `push` checks the depth bound and
/// enqueues in one critical section, so racing producers against a
/// 1-deep queue admit exactly one request and shed the other — the
/// bound holds exactly, never approximately.
#[test]
fn queue_bound_holds_exactly_under_racing_producers() {
    model::check(|| {
        let q = model::Arc::new(model::Mutex::new(0usize)); // depth
        let shed = model::Arc::new(model::Mutex::new(0usize));

        let mk = |q: model::Arc<model::Mutex<usize>>, s: model::Arc<model::Mutex<usize>>| {
            model::thread::spawn(move || {
                // push: admission check + enqueue under one lock
                let mut depth = q.lock();
                if *depth < 1 {
                    *depth += 1;
                } else {
                    *s.lock() += 1;
                }
            })
        };
        let a = mk(q.clone(), shed.clone());
        let b = mk(q.clone(), shed.clone());
        a.join().unwrap();
        b.join().unwrap();

        let depth = *q.lock();
        let shed = *shed.lock();
        assert!(depth <= 1, "bounded queue overshot its depth: {depth}");
        assert_eq!(depth + shed, 2, "a push neither enqueued nor shed");
        assert_eq!(depth, 1, "one of the two pushes must win the slot");
    });
}

/// The buggy variant: check the bound in one critical section, enqueue
/// in another. Both producers pass the check before either enqueues and
/// the 1-deep queue ends up holding 2 — the checker must find it.
#[test]
#[should_panic(expected = "model check failed")]
fn queue_bound_check_then_push_races() {
    model::check(|| {
        let q = model::Arc::new(model::Mutex::new(0usize));
        let shed = model::Arc::new(model::Mutex::new(0usize));

        let mk = |q: model::Arc<model::Mutex<usize>>, s: model::Arc<model::Mutex<usize>>| {
            model::thread::spawn(move || {
                // BUG under test: TOCTOU between the check and the push
                let ok = { *q.lock() < 1 };
                if ok {
                    *q.lock() += 1;
                } else {
                    *s.lock() += 1;
                }
            })
        };
        let a = mk(q.clone(), shed.clone());
        let b = mk(q.clone(), shed.clone());
        a.join().unwrap();
        b.join().unwrap();

        let depth = *q.lock();
        assert!(depth <= 1, "bounded queue overshot its depth: {depth}");
    });
}

/// Shutdown protocol: `close()` flips the closed flag under the same
/// lock `push` takes, so a racing submit either sheds with `Closed` or
/// its request is in the queue when the post-close drain runs — every
/// admitted request is drained, none are lost.
#[test]
fn shutdown_drains_every_admitted_request() {
    model::check(|| {
        #[derive(Default)]
        struct S {
            closed: bool,
            items: usize,
            admitted: usize,
        }
        let q = model::Arc::new(model::Mutex::new(S::default()));

        let qc = q.clone();
        let client = model::thread::spawn(move || {
            // push: closed check and enqueue under one lock; admission
            // is counted the instant the enqueue succeeds
            let mut g = qc.lock();
            if !g.closed {
                g.items += 1;
                g.admitted += 1;
            }
        });
        let qs = q.clone();
        let closer = model::thread::spawn(move || {
            qs.lock().closed = true;
        });
        client.join().unwrap();
        closer.join().unwrap();

        // worker drain after close: everything admitted is still there
        let mut g = q.lock();
        let drained = g.items;
        g.items = 0;
        assert_eq!(drained, g.admitted, "admitted request lost at shutdown");
    });
}

/// `account_chunk` (worker) and `set_routing_policy` (admin) both take
/// the bandit lock, release it, then take the metrics lock — same
/// order, never nested. The checker proves every interleaving of that
/// protocol is deadlock-free and leaves the two sides consistent once
/// both finish.
#[test]
fn bandit_then_metrics_sequential_locking_is_deadlock_free() {
    model::check(|| {
        let bandit = model::Arc::new(model::Mutex::new(None::<&'static str>));
        let metrics = model::Arc::new(model::Mutex::new(None::<&'static str>));

        let (ba, me) = (bandit.clone(), metrics.clone());
        let admin = model::thread::spawn(move || {
            // set_routing_policy(Bandit): install router, then pin control
            *ba.lock() = Some("control");
            *me.lock() = Some("control");
        });
        // account_chunk: observe rewards under the bandit lock, then
        // record under the metrics lock — sequentially, never nested
        let routed = { bandit.lock().is_some() };
        {
            let _m = metrics.lock();
            // recording happens here; `routed` only decides reward rows
            let _ = routed;
        }
        admin.join().unwrap();

        assert_eq!(*bandit.lock(), Some("control"));
        assert_eq!(*metrics.lock(), Some("control"));
    });
}
