//! Model-checked coordinator concurrency protocols.
//!
//! `util::sync::model` explores *every* distinguishable thread
//! interleaving of these small protocol models (DFS over scheduling
//! decisions at each lock/channel/atomic operation), so the properties
//! below are checked exhaustively, not probabilistically. Each model
//! mirrors one protocol of `coordinator::server`:
//!
//! * **swap/submit publication** — `install_plan` inserts the alias
//!   into the fail-fast set AND sends the worker's control message
//!   under the shard queue lock; `submit_leaf` checks + sends under the
//!   same lock. The FIFO channel then guarantees the worker sees the
//!   install before any request that passed the check. The `_races`
//!   twin drops the shared lock and must be caught by the checker —
//!   that is the regression test for the checker itself.
//! * **shutdown drain** — `Coordinator::drop` closes the queue under
//!   the same lock that submits take, so every accepted request is
//!   still in the channel for the worker to drain: none are lost.
//! * **bandit/metrics ordering** — `account_chunk` and
//!   `set_routing_policy` take the bandit and metrics locks
//!   sequentially in the same order, never nested in reverse.
//!
//! The nightly ThreadSanitizer CI job runs the real coordinator tests
//! under TSan for the complementary dynamic check (docs/static_analysis.md).

use overq::util::sync::model;

#[derive(Clone, Copy, PartialEq, Debug)]
enum Msg {
    Install,
    Infer,
}

/// The real protocol: alias publication and the control-message send
/// share one critical section with the submit-side check + send.
#[test]
fn swap_submit_publication_protocol_holds() {
    model::check(|| {
        let tx_lock = model::Arc::new(model::Mutex::new(()));
        let plans = model::Arc::new(model::Mutex::new(false));
        let chan = model::Arc::new(model::Channel::new());

        let (tl, pl, ch) = (tx_lock.clone(), plans.clone(), chan.clone());
        let admin = model::thread::spawn(move || {
            // install_plan: insert alias + send InstallPlan under tx lock
            let _g = tl.lock();
            *pl.lock() = true;
            ch.send(Msg::Install);
        });
        let (tl, pl, ch) = (tx_lock.clone(), plans.clone(), chan.clone());
        let client = model::thread::spawn(move || {
            // submit_leaf: fail-fast check + send under the same lock
            let _g = tl.lock();
            if *pl.lock() {
                ch.send(Msg::Infer);
            }
        });
        admin.join().unwrap();
        client.join().unwrap();

        // worker: drains the FIFO; a request that passed the fail-fast
        // check must find its plan already installed
        let mut installed = false;
        while let Some(m) = chan.try_recv() {
            match m {
                Msg::Install => installed = true,
                Msg::Infer => assert!(installed, "worker saw infer before install"),
            }
        }
    });
}

/// The buggy variant: the client checks + sends WITHOUT the shared
/// queue lock. There is an interleaving where the check passes (alias
/// already inserted) but the request overtakes the control message in
/// the channel — the checker must find it.
#[test]
#[should_panic(expected = "model check failed")]
fn swap_submit_without_the_shared_lock_races() {
    model::check(|| {
        let tx_lock = model::Arc::new(model::Mutex::new(()));
        let plans = model::Arc::new(model::Mutex::new(false));
        let chan = model::Arc::new(model::Channel::new());

        let (tl, pl, ch) = (tx_lock.clone(), plans.clone(), chan.clone());
        let admin = model::thread::spawn(move || {
            let _g = tl.lock();
            *pl.lock() = true;
            ch.send(Msg::Install);
        });
        let (pl, ch) = (plans.clone(), chan.clone());
        let client = model::thread::spawn(move || {
            // BUG under test: no tx_lock around check + send
            if *pl.lock() {
                ch.send(Msg::Infer);
            }
        });
        admin.join().unwrap();
        client.join().unwrap();

        let mut installed = false;
        while let Some(m) = chan.try_recv() {
            match m {
                Msg::Install => installed = true,
                Msg::Infer => assert!(installed, "worker saw infer before install"),
            }
        }
    });
}

/// Shutdown protocol: `Coordinator::drop` takes the queue sender out
/// under the same lock submits use, so a submit either fails fast
/// ("coordinator stopped") or its request is in the channel before the
/// close — the drain then sees every accepted request.
#[test]
fn shutdown_never_loses_accepted_requests() {
    model::check(|| {
        let chan = model::Arc::new(model::Channel::new());
        let open = model::Arc::new(model::Mutex::new(true));
        let sent = model::Arc::new(model::Mutex::new(0usize));

        let (op, ch, se) = (open.clone(), chan.clone(), sent.clone());
        let client = model::thread::spawn(move || {
            // submit_leaf: check the queue is open and send under one lock
            let g = op.lock();
            if *g {
                ch.send(Msg::Infer);
                *se.lock() += 1;
            }
        });
        // Coordinator::drop: close the queue under the same lock
        {
            let mut g = open.lock();
            *g = false;
        }
        client.join().unwrap();

        // worker drain after close: everything accepted is still there
        let mut got = 0usize;
        while chan.try_recv().is_some() {
            got += 1;
        }
        assert_eq!(got, *sent.lock(), "accepted request lost at shutdown");
    });
}

/// `account_chunk` (worker) and `set_routing_policy` (admin) both take
/// the bandit lock, release it, then take the metrics lock — same
/// order, never nested. The checker proves every interleaving of that
/// protocol is deadlock-free and leaves the two sides consistent once
/// both finish.
#[test]
fn bandit_then_metrics_sequential_locking_is_deadlock_free() {
    model::check(|| {
        let bandit = model::Arc::new(model::Mutex::new(None::<&'static str>));
        let metrics = model::Arc::new(model::Mutex::new(None::<&'static str>));

        let (ba, me) = (bandit.clone(), metrics.clone());
        let admin = model::thread::spawn(move || {
            // set_routing_policy(Bandit): install router, then pin control
            *ba.lock() = Some("control");
            *me.lock() = Some("control");
        });
        // account_chunk: observe rewards under the bandit lock, then
        // record under the metrics lock — sequentially, never nested
        let routed = { bandit.lock().is_some() };
        {
            let _m = metrics.lock();
            // recording happens here; `routed` only decides reward rows
            let _ = routed;
        }
        admin.join().unwrap();

        assert_eq!(*bandit.lock(), Some("control"));
        assert_eq!(*metrics.lock(), Some("control"));
    });
}
