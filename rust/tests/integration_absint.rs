//! The static-certification harness (`analysis::absint`): soundness of
//! the proven intervals against real executions, exact-code fixtures
//! for OQ020–OQ025 (`rust/tests/lint_corpus/`), clean certificates for
//! every plan the tuner ships, and the serving gate refusing a
//! statically-unsound plan while the old plan keeps serving.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use overq::analysis::{self, AbsintConfig, GraphBounds, Interval, Severity, DEFAULT_INPUT_RANGE};
use overq::coordinator::Coordinator;
use overq::data::shapes;
use overq::io::tensorfile::{AnyTensor, TensorMap};
use overq::models::{synth_model, LoadedModel};
use overq::nn::{Engine, Graph};
use overq::policy::{AutotuneConfig, DeploymentPlan};
use overq::tensor::TensorF;
use overq::util::json::parse;
use overq::util::rng::Rng;

fn corpus() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_corpus")
}

fn codes(r: &analysis::Report, sev: Severity) -> BTreeSet<&'static str> {
    r.diagnostics
        .iter()
        .filter(|d| d.severity == sev)
        .map(|d| d.code)
        .collect()
}

/// Assert the finding set is exactly `{code}` at `sev` with nothing
/// else at any severity.
fn assert_exactly(report: &analysis::Report, code: &str, sev: Severity) {
    assert_eq!(
        codes(report, sev),
        BTreeSet::from([code]),
        "fixture {code}:\n{}",
        report.render_human()
    );
    let other = report
        .diagnostics
        .iter()
        .filter(|d| d.severity != sev)
        .count();
    assert_eq!(
        other,
        0,
        "fixture {code} has collateral findings:\n{}",
        report.render_human()
    );
}

/// Every value the reference execution actually produces at an enc
/// point must fall inside that enc point's proven interval (up to f32
/// vs f64 accumulation-order slack).
fn assert_sound(model: &LoadedModel, images: &TensorF, input: Interval) {
    let gb = GraphBounds::from_model(model).unwrap();
    let ranges = gb.analyze(input);
    assert_eq!(ranges.len(), gb.num_enc_points(), "{}: missing ranges", model.name);
    let srcs = model.engine.graph.enc_point_sources();
    let (_, taps) = model.engine.forward_f32(images, &srcs).unwrap();
    for r in &ranges {
        let iv = Interval::new(r.lo, r.hi);
        for &v in &taps[r.enc].data {
            assert!(
                iv.contains(v as f64, 1e-4),
                "{} enc {}: activation {v} escapes proven [{}, {}]",
                model.name,
                r.enc,
                r.lo,
                r.hi
            );
        }
    }
}

#[test]
fn soundness_synth_zoo() {
    for name in ["synth-tiny", "synth-cnn"] {
        let model = synth_model(name, 42).unwrap();
        let (images, _) = shapes::gen_batch(42, 0, 16);
        assert_sound(&model, &images, DEFAULT_INPUT_RANGE);
    }
}

/// Build a model from a graph JSON with He-random weights — the same
/// recipe as the synthetic zoo, but over topologies the zoo doesn't
/// ship. `doctor` gets each (node id, bias tensor) before the engine is
/// built, so tests can plant provable pathologies.
fn random_model(
    name: &str,
    graph_json: &str,
    seed: u64,
    doctor: impl Fn(usize, &mut TensorF),
) -> LoadedModel {
    let graph = Graph::from_json(&parse(graph_json).unwrap()).unwrap();
    let mut rng = Rng::new(seed ^ 0x5F37_59DF);
    let mut weights = TensorMap::new();
    for node in &graph.nodes {
        use overq::nn::graph::Op;
        let (wdims, bdim): (Vec<usize>, usize) = match &node.op {
            Op::Conv {
                kh, kw, cin, cout, ..
            } => (vec![*kh, *kw, *cin, *cout], *cout),
            Op::Dense { cin, cout } => (vec![*cin, *cout], *cout),
            _ => continue,
        };
        let fan_in: usize = wdims[..wdims.len() - 1].iter().product();
        let std = (2.0 / fan_in as f32).sqrt();
        let mut w = TensorF::zeros(&wdims);
        for v in w.data.iter_mut() {
            *v = rng.normal() * std;
        }
        let mut b = TensorF::zeros(&[bdim]);
        for v in b.data.iter_mut() {
            *v = rng.normal() * 0.05;
        }
        doctor(node.id, &mut b);
        weights.insert(format!("n{}.w", node.id), AnyTensor::F32(w));
        weights.insert(format!("n{}.b", node.id), AnyTensor::F32(b));
    }
    LoadedModel {
        name: name.to_string(),
        engine: Engine::new(graph, &weights).unwrap(),
        enc_stats: Vec::new(),
        fp32_acc: 0.0,
    }
}

/// Property test: random weights, a topology exercising every transfer
/// function (affine, residual add, concat, max/avg pool, gap), random
/// inputs inside the declared domain — no activation may escape its
/// proven interval.
#[test]
fn soundness_random_graphs() {
    let graph_json = r#"{
      "name": "absint-prop",
      "nodes": [
        {"id": 0, "op": "input", "in": []},
        {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
         "cin": 3, "cout": 6, "relu": true, "quant": false},
        {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 1,
         "cin": 6, "cout": 6, "relu": true, "quant": true, "enc": 0},
        {"id": 3, "op": "add", "in": [1, 2], "relu": true},
        {"id": 4, "op": "maxpool", "in": [3]},
        {"id": 5, "op": "conv", "in": [4], "kh": 3, "kw": 3, "stride": 2,
         "cin": 6, "cout": 8, "relu": true, "quant": true, "enc": 1},
        {"id": 6, "op": "avgpool", "in": [5]},
        {"id": 7, "op": "concat", "in": [6, 6]},
        {"id": 8, "op": "gap", "in": [7]},
        {"id": 9, "op": "dense", "in": [8], "cin": 16, "cout": 10}
      ]
    }"#;
    for seed in 0..5u64 {
        let model = random_model("absint-prop", graph_json, seed, |_, _| {});
        let mut rng = Rng::new(seed.wrapping_mul(77) ^ 0xA5A5);
        let mut images = TensorF::zeros(&[2, 8, 8, 3]);
        for v in images.data.iter_mut() {
            *v = rng.f32() * 4.0 - 2.0;
        }
        assert_sound(&model, &images, Interval::new(-2.0, 2.0));
    }
}

/// Every plan the tuner ships must certify clean — the serving gate
/// (`register_plan`) runs this exact check, so a warning here is a
/// tuner/analyzer disagreement and an error would brick deployment.
#[test]
fn autotuned_plans_certify_clean() {
    for name in ["synth-tiny", "synth-cnn"] {
        let model = synth_model(name, 42).unwrap();
        let (images, _) = shapes::gen_batch(42, 0, 16);
        let plan = overq::policy::autotune(&model, &images, &AutotuneConfig::default())
            .unwrap()
            .plan;
        let cert =
            analysis::verify_plan(&plan, &model, DEFAULT_INPUT_RANGE, &AbsintConfig::default())
                .unwrap();
        assert!(
            cert.report.is_clean(),
            "{name} autotuned plan:\n{}",
            cert.report.render_human()
        );
        assert_eq!(cert.encs.len(), plan.layers.len());
        for c in &cert.encs {
            assert!(c.quant_hi > 0.0 && c.capacity > 0.0 && c.err_bound >= 0.0);
        }
    }
}

/// synth-tiny with one provably dead channel in the enc-0 source conv
/// (node 1): channel 0's bias is forced to -1e3, so its pre-ReLU upper
/// bound is `<= 0` under any input bounded by the declared domain. The
/// OQ023 fixture is judged against this model.
fn dead_channel_tiny() -> LoadedModel {
    let graph_json = r#"{
      "name": "synth-tiny",
      "nodes": [
        {"id": 0, "op": "input", "in": []},
        {"id": 1, "op": "conv", "in": [0], "kh": 3, "kw": 3, "stride": 1,
         "cin": 3, "cout": 8, "relu": true, "quant": false},
        {"id": 2, "op": "conv", "in": [1], "kh": 3, "kw": 3, "stride": 2,
         "cin": 8, "cout": 12, "relu": true, "quant": true, "enc": 0},
        {"id": 3, "op": "conv", "in": [2], "kh": 3, "kw": 3, "stride": 2,
         "cin": 12, "cout": 16, "relu": true, "quant": true, "enc": 1},
        {"id": 4, "op": "gap", "in": [3]},
        {"id": 5, "op": "dense", "in": [4], "cin": 16, "cout": 10}
      ]
    }"#;
    random_model("synth-tiny", graph_json, 42, |id, b| {
        if id == 1 {
            b.data[0] = -1e3;
        }
    })
}

/// Each OQ020–OQ025 fixture triggers exactly its code at its severity
/// under `overq verify` semantics.
#[test]
fn verify_fixtures_trigger_exactly_their_code() {
    let model = synth_model("synth-tiny", 42).unwrap();
    let cases: [(&str, Severity, Option<f64>); 5] = [
        ("OQ020", Severity::Error, None),
        ("OQ021", Severity::Warn, None),
        ("OQ022", Severity::Warn, None),
        ("OQ024", Severity::Warn, None),
        ("OQ025", Severity::Warn, Some(1e-9)),
    ];
    for (code, sev, budget) in cases {
        let plan = DeploymentPlan::load(&corpus().join(format!("{code}.plan.json"))).unwrap();
        let cfg = AbsintConfig {
            error_budget: budget,
            ..AbsintConfig::default()
        };
        let cert = analysis::verify_plan(&plan, &model, DEFAULT_INPUT_RANGE, &cfg).unwrap();
        assert_exactly(&cert.report, code, sev);
    }

    // the clean fixture certifies clean under the same defaults
    let plan = DeploymentPlan::load(&corpus().join("clean.plan.json")).unwrap();
    let cert =
        analysis::verify_plan(&plan, &model, DEFAULT_INPUT_RANGE, &AbsintConfig::default())
            .unwrap();
    assert!(cert.report.is_clean(), "{}", cert.report.render_human());
}

/// OQ023 needs a model with a provably dead channel; the stock zoo has
/// none (and must keep having none — that's asserted by the soundness
/// tests), so the fixture is judged against a doctored synth-tiny.
#[test]
fn verify_oq023_fixture_on_dead_channel_model() {
    let model = dead_channel_tiny();
    let gb = GraphBounds::from_model(&model).unwrap();
    let ranges = gb.analyze(DEFAULT_INPUT_RANGE);
    assert!(
        ranges[0].dead_channels > 0,
        "doctored model has no dead channel (got {:?})",
        ranges[0]
    );
    let plan = DeploymentPlan::load(&corpus().join("OQ023.plan.json")).unwrap();
    let cert = analysis::verify_plan(&plan, &model, DEFAULT_INPUT_RANGE, &AbsintConfig::default())
        .unwrap();
    assert_exactly(&cert.report, "OQ023", Severity::Warn);
}

fn img_of(src: &TensorF, i: usize) -> TensorF {
    let sz = 16 * 16 * 3;
    TensorF::from_vec(&[16, 16, 3], src.data[i * sz..(i + 1) * sz].to_vec())
}

/// The serving gate: a statically-unsound plan (seeded overflow — the
/// OQ020 fixture) is refused at `register_plan` with the stable code in
/// the error, and the previously registered plan keeps serving its
/// exact numerics.
#[test]
fn register_plan_refuses_statically_unsound_plan() {
    let tiny = synth_model("synth-tiny", 42).unwrap();
    let (images, _) = shapes::gen_batch(42, 0, 8);
    let plan = overq::policy::autotune(&tiny, &images, &AutotuneConfig::default())
        .unwrap()
        .plan;
    let qc = plan.to_quant_config();
    let (load, _) = shapes::gen_batch(43, 0, 2);
    let want = tiny.engine.forward_quant(&load, &qc).unwrap();
    let classes = tiny.engine.num_classes().unwrap();

    let coord = Coordinator::builder().model_local(tiny).build().unwrap();
    let h = coord.model("synth-tiny").unwrap();
    h.register_plan(plan.clone()).unwrap();

    // the OQ020 corpus plan parses and passes the schema loader — only
    // the static certification gate can catch it
    let bad = DeploymentPlan::load(&corpus().join("OQ020.plan.json")).unwrap();
    let err = h.register_plan(bad).unwrap_err();
    assert!(format!("{err:#}").contains("OQ020"), "{err:#}");

    // ...and the refusal leaves the registered plan untouched
    let resp = h
        .infer_variant(img_of(&load, 0), &format!("plan:{}", plan.name))
        .unwrap();
    assert_eq!(resp.logits, want.data[0..classes].to_vec());
    coord.shutdown();
}
